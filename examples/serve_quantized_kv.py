"""Serving with the guaranteed-error-bounded quantized KV cache: batch
decode of a small GQA model, raw bf16 cache vs int8+outlier cache —
compares output divergence (bounded!) and cache footprint.

    PYTHONPATH=src python examples/serve_quantized_kv.py

--disaggregate additionally simulates prefill→decode disaggregation
(DESIGN.md §8) on a two-device CPU mesh: the quantized cache is packed
to the `PackedCache` wire, moved rank 0 → rank 1 with
`Transport.send_pages`, unpacked bit-exactly, and decode continues from
the transferred cache with bit-identical logits.  Prints the measured
wire bytes vs moving raw f32 pages.
"""
import argparse
import os
import sys

if "--disaggregate" in sys.argv:            # must precede the jax import
    _flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in _flags:
        # append, don't setdefault: a pre-existing XLA_FLAGS (e.g. a dump
        # path) must not silently swallow the 2-device requirement
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=2").strip()

import numpy as np

import jax
import jax.numpy as jnp

from repro.compression.kv import kv_quantizer_config
from repro.configs import registry
from repro.core.transport import TRANSPORT
from repro.models import build
from repro.models import serve as S


def cache_bytes(tree):
    return sum(np.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def _shard_map(f, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names={"wire"},
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def disaggregate(quant, stages="zero"):
    """Move the cache rank 0 (prefill) -> rank 1 (decode) over a real
    two-device mesh axis; return rank 1's received QuantCache."""
    from jax.sharding import PartitionSpec as P

    assert jax.device_count() >= 2, (
        "--disaggregate needs 2 devices; XLA_FLAGS must include "
        "--xla_force_host_platform_device_count=2 (set before jax init)")
    mesh = jax.make_mesh((2,), ("wire",))

    def send(c):
        moved = S.transfer_cache(c, 0, 1, "wire", stages=stages)
        return jax.tree.map(lambda a: a[None], moved)

    out = jax.jit(_shard_map(send, mesh, P(), P("wire")))(quant)
    return jax.tree.map(lambda a: a[1], out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=192)   # crosses a page
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--disaggregate", action="store_true",
                    help="prefill→decode cache transfer over a 2-device "
                         "mesh via Transport.send_pages")
    args = ap.parse_args()

    cfg = registry.get("deepseek-67b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    seq = 256
    kv_cfg = kv_quantizer_config()                      # eb_rel = 2^-6

    raw = bundle.make_cache(args.batch, seq)
    quant = bundle.make_cache(args.batch, seq, quantized=True)
    # at toy S the fixed-size hot page dominates; report the history-only
    # ratio too (what a 32k-context serving cache actually sees)
    hist = cache_bytes(quant) - cache_bytes((quant.hot_k, quant.hot_v))
    print(f"cache footprint: raw {cache_bytes(raw)/2**20:.2f} MiB, "
          f"quantized {cache_bytes(quant)/2**20:.2f} MiB; history-only "
          f"{cache_bytes(raw)/hist:.2f}x smaller (hot page amortizes away "
          f"at production context lengths)")

    step_raw = jax.jit(lambda p, c, t, i: bundle.serve_step(p, c, t, i))
    step_q = jax.jit(lambda p, c, t, i: bundle.serve_step(
        p, c, t, i, kv_cfg=kv_cfg))

    key = jax.random.PRNGKey(1)
    tok_r = tok_q = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)
    agree = 0
    for pos in range(args.tokens):
        lr, raw = step_raw(params, raw, tok_r, jnp.int32(pos))
        lq, quant = step_q(params, quant, tok_q, jnp.int32(pos))
        nr = np.asarray(jnp.argmax(lr, -1))
        nq = np.asarray(jnp.argmax(lq, -1))
        agree += int((nr == nq).sum())
        # greedy decode continues from each variant's own choice
        tok_r = jnp.asarray(nr[:, None])
        tok_q = jnp.asarray(nq[:, None])
        if pos % 64 == 63:
            drift = float(jnp.max(jnp.abs(lr - lq)))
            print(f"  pos {pos:4d}: max logit delta {drift:.4f}")

    total = args.tokens * args.batch
    print(f"greedy agreement: {agree}/{total} tokens "
          f"({100*agree/total:.1f}%) — bounded KV error keeps the decode "
          f"on-distribution while the cache is ~4x smaller")

    if not args.disaggregate:
        return

    # --- prefill→decode disaggregation over the Transport layer ----------
    received = disaggregate(quant, stages="zero")
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(quant),
                               jax.tree.leaves(received)))
    wire = S.pack_cache(quant, stages="zero")
    moved = float(TRANSPORT.bytes_moved(wire, op="send_pages"))
    raw_pages = 2 * quant.k.bins.size * 4        # K+V history as f32
    raw_pages += cache_bytes((quant.hot_k, quant.hot_v))
    print(f"disaggregation: cache moved rank 0 → 1 as PackedKV wires via "
          f"Transport.send_pages: {moved/2**20:.2f} MiB on the wire "
          f"({raw_pages/moved:.2f}x less than raw f32 pages); "
          f"bit-exact={same}")
    assert same, "transferred cache must be bit-identical"

    # decode continues from the transferred cache with identical logits
    l_orig, _ = step_q(params, quant, tok_q, jnp.int32(args.tokens))
    l_recv, _ = step_q(params, received, tok_q, jnp.int32(args.tokens))
    identical = np.array_equal(np.asarray(l_orig), np.asarray(l_recv))
    print(f"decode-after-transfer logits bit-identical: {identical}")
    assert identical


if __name__ == "__main__":
    main()
