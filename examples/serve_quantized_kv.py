"""Serving with the guaranteed-error-bounded quantized KV cache: batch
decode of a small GQA model, raw bf16 cache vs int8+outlier cache —
compares output divergence (bounded!) and cache footprint.

    PYTHONPATH=src python examples/serve_quantized_kv.py
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.compression.kv import kv_quantizer_config
from repro.configs import registry
from repro.models import build


def cache_bytes(tree):
    return sum(np.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=192)   # crosses a page
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = registry.get("deepseek-67b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    seq = 256
    kv_cfg = kv_quantizer_config()                      # eb_rel = 2^-6

    raw = bundle.make_cache(args.batch, seq)
    quant = bundle.make_cache(args.batch, seq, quantized=True)
    # at toy S the fixed-size hot page dominates; report the history-only
    # ratio too (what a 32k-context serving cache actually sees)
    hist = cache_bytes(quant) - cache_bytes((quant.hot_k, quant.hot_v))
    print(f"cache footprint: raw {cache_bytes(raw)/2**20:.2f} MiB, "
          f"quantized {cache_bytes(quant)/2**20:.2f} MiB; history-only "
          f"{cache_bytes(raw)/hist:.2f}x smaller (hot page amortizes away "
          f"at production context lengths)")

    step_raw = jax.jit(lambda p, c, t, i: bundle.serve_step(p, c, t, i))
    step_q = jax.jit(lambda p, c, t, i: bundle.serve_step(
        p, c, t, i, kv_cfg=kv_cfg))

    key = jax.random.PRNGKey(1)
    tok_r = tok_q = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)
    agree = 0
    for pos in range(args.tokens):
        lr, raw = step_raw(params, raw, tok_r, jnp.int32(pos))
        lq, quant = step_q(params, quant, tok_q, jnp.int32(pos))
        nr = np.asarray(jnp.argmax(lr, -1))
        nq = np.asarray(jnp.argmax(lq, -1))
        agree += int((nr == nq).sum())
        # greedy decode continues from each variant's own choice
        tok_r = jnp.asarray(nr[:, None])
        tok_q = jnp.asarray(nq[:, None])
        if pos % 64 == 63:
            drift = float(jnp.max(jnp.abs(lr - lq)))
            print(f"  pos {pos:4d}: max logit delta {drift:.4f}")

    total = args.tokens * args.batch
    print(f"greedy agreement: {agree}/{total} tokens "
          f"({100*agree/total:.1f}%) — bounded KV error keeps the decode "
          f"on-distribution while the cache is ~4x smaller")


if __name__ == "__main__":
    main()
