import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Cross-pod compressed-gradient training (the paper's quantizer on the
wire) on an emulated (2 pods x 2 data x 2 model) mesh: trains the same
model with full-precision DP and with guaranteed-error-bounded compressed
DP + error feedback, and compares the loss curves.

    PYTHONPATH=src python examples/train_grad_compression.py [--steps 40]
"""
import argparse
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compression.grads import GradCompressionConfig
from repro.configs import registry
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.train import (init_residuals, make_train_step,
                                make_train_step_compressed)
from repro.models import build
from repro.optim import optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = registry.get("stablelm-3b").reduced()
    bundle = build(cfg)
    opt_cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=5,
                              total_steps=args.steps)
    # the cross-pod wire is a compression pipeline (DESIGN.md §7): ABS
    # quantizer (eb overridden per-tensor by eb_rel * rms), §4 bit-pack,
    # then the chunked zero-suppression/narrowing lossless stage
    gc_cfg = GradCompressionConfig(
        eb_rel=2.0 ** -8, pipeline="abs:1.0:cap=0.015625|pack:8|narrow")
    pipe = TokenPipeline(DataConfig(cfg.vocab, args.seq, args.batch))

    def batches():
        for i in range(args.steps):
            b = pipe.batch(i)
            yield {k: jax.device_put(
                jnp.asarray(v), NamedSharding(mesh, P(("pod", "data"),
                                                      None)))
                for k, v in b.items()}

    with jax.set_mesh(mesh):
        params = bundle.init(jax.random.PRNGKey(0))
        ostate = opt.init(params, opt_cfg)

        # --- full-precision DP baseline ---
        step = jax.jit(make_train_step(bundle, mesh, opt_cfg))
        state = (params, ostate)
        base_losses = []
        for batch in batches():
            state, m = step(state, batch)
            base_losses.append(float(m["loss"]))

        # --- compressed-DP with error feedback ---
        stepc = jax.jit(make_train_step_compressed(bundle, mesh, opt_cfg,
                                                   gc_cfg))
        resid = init_residuals(params, n_pods=2)
        statec = (params, opt.init(params, opt_cfg), resid)
        comp_losses = []
        for batch in batches():
            statec, m = stepc(statec, batch)
            comp_losses.append(float(m["loss"]))

    print("step   full-DP   compressed-DP (int8 + exact outliers + EF)")
    for i in range(0, args.steps, max(1, args.steps // 10)):
        print(f"{i:4d}   {base_losses[i]:.4f}    {comp_losses[i]:.4f}")
    print(f"final  {base_losses[-1]:.4f}    {comp_losses[-1]:.4f}")
    gap = abs(comp_losses[-1] - base_losses[-1])
    print(f"\nfinal-loss gap {gap:.4f} — compressed DP tracks full "
          f"precision (per-step gradient error elementwise <= "
          f"{gc_cfg.eb_rel:g} * rms(g), wire traffic ~3.9x lower)")


if __name__ == "__main__":
    main()
