"""End-to-end training driver: a small LM trained for a few hundred steps
with the full substrate — deterministic data pipeline, AdamW, fault-
tolerant checkpointing (kill it anytime; rerun resumes exactly), straggler
monitoring, and optional lossy (guaranteed-error-bounded) checkpoints.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--lossy-ckpt]
    # kill it mid-run and run again: it resumes from the last checkpoint

~100M-parameter preset: --d-model 512 --layers 12 (default is a fast
~20M CPU-friendly config; same code path).
"""
import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.core import QuantizerConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import build
from repro.optim import optimizer as opt
from repro.runtime.train_loop import TrainLoopConfig, run, StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-e2e-ckpt")
    ap.add_argument("--lossy-ckpt", action="store_true")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        registry.get("internlm2-20b").reduced(),
        d_model=args.d_model, n_layers=args.layers,
        d_ff=args.d_model * 3, vocab=8192, n_heads=8, n_kv_heads=4,
        head_dim=args.d_model // 8)
    bundle = build(cfg)
    print(f"model: {bundle.n_params()/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    opt_cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=20,
                              total_steps=args.steps)
    pipe = TokenPipeline(DataConfig(cfg.vocab, args.seq, args.batch))
    lossy = (QuantizerConfig(mode="abs", error_bound=1e-6)
             if args.lossy_ckpt else None)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2, lossy=lossy)

    def init():
        params = bundle.init(jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params, opt_cfg)}

    template = jax.eval_shape(init)
    state, start = ckpt.restore(template)
    if state is None:
        state, start = init(), 0
        print("fresh start")
    else:
        state = jax.tree.map(jnp.asarray, state)
        print(f"resumed from step {start}")

    @jax.jit
    def step_fn(state, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(state["params"], batch)
        params, ostate, m = opt.apply(state["params"], grads, state["opt"],
                                      opt_cfg)
        m["loss"] = loss
        return {"params": params, "opt": ostate}, m

    losses = []

    def on_metrics(step, m, dt, straggle):
        losses.append(float(m["loss"]))
        flag = "  STRAGGLER" if straggle else ""
        print(f"step {step:4d} loss={float(m['loss']):.4f} "
              f"gnorm={float(m['grad_norm']):.2f} {dt*1e3:6.0f}ms{flag}")

    batch_fn = lambda i: jax.tree.map(jnp.asarray, pipe.batch(i))
    loop_cfg = TrainLoopConfig(total_steps=args.steps, checkpoint_every=50,
                               log_every=10)
    t0 = time.time()
    state, last, interrupted = run(step_fn, state, batch_fn, ckpt, loop_cfg,
                                   start_step=start,
                                   on_metrics=on_metrics)
    print(f"\n{'interrupted' if interrupted else 'finished'} at step {last} "
          f"({time.time()-t0:.0f}s); loss {losses[0] if losses else 0:.3f} "
          f"-> {losses[-1] if losses else 0:.3f}")
    assert interrupted or len(losses) < 2 or losses[-1] < losses[0]


if __name__ == "__main__":
    main()
