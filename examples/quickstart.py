"""Quickstart: the guaranteed-error-bound compression pipeline in five
minutes.

    PYTHONPATH=src python examples/quickstart.py

One spec string builds the whole LC-style chain (DESIGN.md §7):
value-domain predictor stages (DESIGN.md §9) -> quantizer -> bit-pack ->
lossless word stages.  Every decoded value is within the bound or
bit-identical to the original, whatever the chain.
"""
import numpy as np

import jax.numpy as jnp

from repro.core import (QuantizerConfig, compression_ratio, deserialize,
                        parse_pipeline, serialize)

rng = np.random.default_rng(0)

# a "scientific" field with specials sprinkled in
x = (np.sin(np.linspace(0, 60, 1 << 20)) * 40
     + rng.standard_normal(1 << 20)).astype(np.float32)
x[123] = np.nan
x[456] = np.inf
x[789] = 1e-42                      # denormal

# the last spec is a two-domain chain (DESIGN.md §9): `delta` predicts
# each value from its decoded predecessor — an exact bijection on the
# bin plane, so the bound survives untouched while the smooth sinusoid
# collapses to near-zero residuals the word stages then crush
for spec in ("abs:1e-3|pack:16|narrow",
             "rel:1e-3|pack:32|shuffle|narrow",
             "noa:1e-4|pack:16|zero",
             "delta|abs:1e-3|pack:16|narrow|ent"):
    pipe = parse_pipeline(spec)
    mode, eb = pipe.quant.mode, pipe.quant.eb

    # 1) one Pipeline object: encode -> Encoded wire container -> decode
    enc = pipe.encode(jnp.asarray(x))
    y = np.asarray(pipe.decode(enc, shape=x.shape))
    fin = np.isfinite(x)
    if mode == "abs":
        err = np.abs(x[fin].astype(np.float64) - y[fin]).max()
        bound_txt = f"abs err {err:.2e} <= {eb:g}"
        assert err <= eb
    elif mode == "rel":
        m = fin & (x != 0)
        err = (np.abs(x[m].astype(np.float64) - y[m])
               / np.abs(x[m].astype(np.float64))).max()
        bound_txt = f"rel err {err:.2e} <= {eb:g}"
        assert err <= eb
    else:
        r = x[fin].max() - x[fin].min()
        err = np.abs(x[fin].astype(np.float64) - y[fin]).max()
        bound_txt = f"noa err {err:.2e} <= {eb:g}*R={eb * r:.2e}"
    # NaN/Inf restored bit-for-bit; the denormal is either bit-exact (REL
    # flags it as an outlier) or within the bound like any normal value
    # (ABS/NOA bin it — the paper's "denormals treated like normals")
    assert np.isnan(y[123]) and np.isinf(y[456])
    if mode == "rel":
        assert y[789].view(np.uint32) == x[789].view(np.uint32)

    # 2) honest wire accounting: the transmitted bits, per chain prefix
    wire = x.nbytes * 8 / float(pipe.wire_bits(enc, x.size))
    stages = " -> ".join(f"{label} {x.nbytes * 8 / float(bits):.2f}x"
                         for label, bits in pipe.stage_report(
                             jnp.asarray(x))[1:])
    print(f"{spec:34s}: {bound_txt}; wire {wire:.2f}x smaller "
          f"({stages}); specials bit-exact ✓")

# 3) host byte stream (zlib archival coder, LC-style inline outliers)
cfg = QuantizerConfig(mode="abs", error_bound=1e-3)
stream = serialize(x, cfg)
x2, _ = deserialize(stream)
host, device = compression_ratio(x, cfg, stream=stream, wire="both")
print(f"\nhost stream {host:.2f}x smaller (zlib archival coder); "
      f"device wire {device:.2f}x (same accounting as the collectives)")
print("The guarantee is unconditional: every decoded value is within the "
      "bound or bit-identical to the original.")
