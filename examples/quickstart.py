"""Quickstart: the guaranteed-error-bound quantizer in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import (QuantizerConfig, compression_ratio, deserialize,
                        roundtrip_dense, serialize)

rng = np.random.default_rng(0)

# a "scientific" field with specials sprinkled in
x = (np.sin(np.linspace(0, 60, 1 << 20)) * 40
     + rng.standard_normal(1 << 20)).astype(np.float32)
x[123] = np.nan
x[456] = np.inf
x[789] = 1e-42                      # denormal

for mode, eb in (("abs", 1e-3), ("rel", 1e-3), ("noa", 1e-4)):
    cfg = QuantizerConfig(mode=mode, error_bound=eb)

    # 1) jit-safe roundtrip with the guarantee
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    fin = np.isfinite(x)
    if mode == "abs":
        err = np.abs(x[fin].astype(np.float64) - y[fin]).max()
        bound_txt = f"abs err {err:.2e} <= {eb:g}"
        assert err <= eb
    elif mode == "rel":
        m = fin & (x != 0)
        err = (np.abs(x[m].astype(np.float64) - y[m])
               / np.abs(x[m].astype(np.float64))).max()
        bound_txt = f"rel err {err:.2e} <= {eb:g}"
        assert err <= eb
    else:
        r = x[fin].max() - x[fin].min()
        err = np.abs(x[fin].astype(np.float64) - y[fin]).max()
        bound_txt = f"noa err {err:.2e} <= {eb:g}*R={eb * r:.2e}"
    # NaN/Inf restored bit-for-bit; the denormal is either bit-exact (REL
    # flags it as an outlier) or within the bound like any normal value
    # (ABS/NOA bin it — the paper's "denormals treated like normals")
    assert np.isnan(y[123]) and np.isinf(y[456])
    if mode == "rel":
        assert y[789].view(np.uint32) == x[789].view(np.uint32)

    # 2) LC-style byte stream (inline outliers + lossless stage)
    stream = serialize(x, cfg)
    x2, _ = deserialize(stream)
    ratio = compression_ratio(x, cfg, stream=stream)
    print(f"{mode:4s} eb={eb:g}: {bound_txt}; stream {ratio:.2f}x smaller; "
          f"NaN/Inf/denormal bit-exact ✓")

print("\nThe guarantee is unconditional: every decoded value is within the "
      "bound or bit-identical to the original.")
