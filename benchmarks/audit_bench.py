"""Guarantee-audit bench (DESIGN.md §12): detection coverage + overhead.

Two tables, both written to the committed BENCH_audit.json artifact:

  detection  the fault-injection matrix: every `runtime.guard` fault
             class against every registry pipeline preset, every `auto`
             selector set, and every KV page chain (static and
             selected).  Each applicable wire fault must flip the §12
             checksum verdict; `nan_input` must surface in the
             `verify=` audit report (`n_nonfinite > 0`); and the CLEAN
             wire must pass its own checksum (zero false positives).
             Any miss makes the process exit nonzero, so the CI smoke
             step doubles as a gate.

  overhead   `encode(verify=True)` vs plain encode on the lossless
             GRAD_SUITES rows (the `benchmarks.run lossless` chains at
             eb = 2^-8 * rms).  The audit fuses decode-and-check into
             planes the encoder already computed, so the target is
             <= 5% — the acceptance bound the artifact is committed
             under.

Usage: PYTHONPATH=src python -m benchmarks.audit_bench
           [--smoke] [--out PATH]

--smoke shrinks datasets/repeats for CI; --out defaults to the repo
root's BENCH_audit.json.  Render the artifact as markdown via
`benchmarks.roofline --audit-bench`.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import (KV_PAGE_CHAINS, PIPELINES,
                                    SELECTOR_SETS, get_pipeline)
from repro.core.pipeline import parse_pipeline
from repro.core.select import get_kv_selector, get_selector
from repro.compression.kv import kv_quantizer_config, pack_kv, quantize_kv
from repro.runtime import guard

from . import datasets

OUT_DEFAULT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_audit.json")
OVERHEAD_BOUND = 0.05          # the committed acceptance bound


def _time_pair(f0, f1, x, repeats=5):
    """Paired-difference ABBA timing: run the two variants back to back,
    alternating the order each pair, and estimate the overhead as the
    MEDIAN of per-pair deltas over the fastest plain run.  Adjacent runs
    share the machine state, so the delta distribution centers on the
    true audit cost (~ms) even when absolute run times drift 10-20% over
    the sweep, and the ABBA order flip cancels within-pair drift (a
    slowdown ramping through a pair penalizes whichever member runs
    second — fixed-order pairs turned that into a +10% phantom overhead
    on whole rows).  Separate min/median estimates were even worse,
    swinging -14%..+31% on a cost the isolated audit pass puts at <1%."""
    for _ in range(3):             # compile + shake off first-window drift
        jax.block_until_ready(f0(x))
        jax.block_until_ready(f1(x))
    t0s, diffs = [], []
    for i in range(repeats):
        first, second = (f0, f1) if i % 2 == 0 else (f1, f0)
        t = time.perf_counter()
        jax.block_until_ready(first(x))
        ta = time.perf_counter() - t
        t = time.perf_counter()
        jax.block_until_ready(second(x))
        tb = time.perf_counter() - t
        t0, t1 = (ta, tb) if i % 2 == 0 else (tb, ta)
        t0s.append(t0)
        diffs.append(t1 - t0)
    base = float(min(t0s))
    return base, base + float(np.median(diffs))


def _grad(n):
    return jnp.asarray(datasets.GRAD_SUITES["gradsmooth"]()[:n])


def _detection_row(kind, name, matrix, clean_ok):
    ok = clean_ok and all(matrix.values())
    print(f"detection.{kind}.{name}: "
          + " ".join(f"{k}={'ok' if v else 'MISS'}"
                     for k, v in matrix.items())
          + ("" if clean_ok else " CLEAN-FALSE-POSITIVE"))
    return dict(kind=kind, name=name, matrix=matrix, clean_ok=clean_ok,
                all_detected=ok)


def detection(smoke: bool) -> list:
    """The coverage matrix: corrupt, then ask the checksum."""
    n = 1 << 16 if smoke else 1 << 20
    rows = []

    # every registry pipeline preset -> an Encoded wire.  Data matches
    # the quantizer: REL chains get the mixed-sign REL suite (gradient
    # noise at rel:0.001|pack:8 is all-outlier — empty payloads would
    # make length faults vacuous no-ops); ABS chains get the gradient
    # suite with the lossless rows' rms-scaled bound for placeholder
    # (eb=1.0) presets.
    g = _grad(n)
    relmix = jnp.asarray(datasets.rel_mixed()[:n])
    rms = float(jnp.sqrt(jnp.mean(g * g)))
    for preset in sorted(PIPELINES):
        pipe = parse_pipeline(get_pipeline(preset))
        x = relmix if pipe.quant.mode == "rel" else g
        eb = rms * 2.0 ** -8 if pipe.quant.eb == 1.0 else None
        enc = pipe.encode(x, eb=eb, integrity=True)
        matrix = guard.detection_matrix(enc, suite=preset)
        plan = guard.FaultPlan(preset, "nan_input")
        _, rep = pipe.encode(plan.corrupt_input(x), eb=eb, verify=True,
                             integrity=True)
        matrix["nan_input"] = int(rep.n_nonfinite) > 0
        rows.append(_detection_row("pipeline", preset, matrix, True))

    # every auto selector set -> a SelectedWire (suite data the set was
    # autotuned for: gradients for grad-wire, the NYX field for
    # sci-plane's abs:64.0 bound)
    nyx = jnp.asarray(datasets.SUITES["NYX"]()[:n])
    for set_name, entry in SELECTOR_SETS.items():
        if entry["base"] is None:        # kv-page: fragments, covered below
            continue
        sel = get_selector(set_name)
        x = nyx if set_name == "sci-plane" else g
        eb = rms * 2.0 ** -8 if sel.qcfg().error_bound == 1.0 else None
        wire = sel.encode(x, eb=eb, integrity=True)
        matrix = guard.detection_matrix(wire, suite=set_name,
                                        n_chains=len(entry["chains"]))
        plan = guard.FaultPlan(set_name, "nan_input")
        _, rep = sel.encode(plan.corrupt_input(x), eb=eb, verify=True,
                            integrity=True)
        matrix["nan_input"] = int(rep.n_nonfinite) > 0
        rows.append(_detection_row("selector", f"auto:{set_name}", matrix,
                                   True))

    # KV page chains: static presets + the per-page auto selector
    r = datasets._rng("audit-kv-cache")
    s = 256 if smoke else 1024
    cache = r.standard_normal((2, 2, s, 64)).astype(np.float32)
    cache[:, :, int(s * 0.6):, :] = 0.0
    q = quantize_kv(jnp.asarray(cache), kv_quantizer_config())
    for preset, frag in KV_PAGE_CHAINS.items():
        p = pack_kv(q, stages=frag, integrity=True)
        rows.append(_detection_row(
            "kv", preset, guard.detection_matrix(p, suite=preset), True))
    ksel = get_kv_selector("kv-page")
    p = pack_kv(q, stages=ksel, integrity=True)
    rows.append(_detection_row(
        "kv", "auto:kv-page",
        guard.detection_matrix(p, suite="kv-page", n_chains=3), True))
    rows.append(ring_detection())
    return rows


# in-flight §12 coverage: the per-hop plane checksums of the verified
# ring reduce (Transport.reduce_mean(integrity='drop')) against a
# `hop_bitflip` fault hook.  Runs in a subprocess so XLA_FLAGS can
# emulate a 2-device mesh regardless of this process's backend state.
_RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compression.grads import GradCompressionConfig, compress_shard
    from repro.core.transport import TRANSPORT, Transport
    from repro.runtime.guard import FaultPlan

    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((2,), ("pod",))
    if hasattr(jax, "shard_map"):
        def smap(f):
            return jax.shard_map(f, mesh=mesh, in_specs=P("pod", None),
                                 out_specs=(P("pod", None), P("pod")),
                                 axis_names={"pod"}, check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        def smap(f):
            return _shard_map(f, mesh=mesh, in_specs=P("pod", None),
                              out_specs=(P("pod", None), P("pod")),
                              check_rep=False)

    # bin_bits=16 keeps the shards outlier-free so the §8 ring fires
    # (outliers would route the reduce to the gather fallback instead)
    cfg = GradCompressionConfig(eb_rel=2.0 ** -6, bin_bits=16,
                                outlier_cap_frac=1 / 16)
    pipe, n = cfg.pipe(), 4096

    def run(tp, g):
        def f(v):
            shard, _ = compress_shard(v, cfg, integrity=True)
            mean, nv = tp.reduce_mean(shard.enc, pipe, n, "pod",
                                      integrity="drop", return_valid=True)
            return mean, nv[None]
        gd = jax.device_put(jnp.asarray(g),
                            NamedSharding(mesh, P("pod", None)))
        mean, nv = jax.jit(smap(f))(gd)
        return np.asarray(mean), np.asarray(nv).tolist()

    r = np.random.default_rng(__import__("zlib").crc32(b"ring-hop"))
    g = np.broadcast_to((r.standard_normal(n) * 1e-2).astype(np.float32),
                        (2, n)).copy()
    mean_c, valid_c = run(TRANSPORT, g)
    plan = FaultPlan("ring", "hop_bitflip")
    mean_f, valid_f = run(Transport(fault=plan.corrupt_hop), g)
    print("CLEAN", *valid_c)
    print("FAULT", *valid_f)
    assert np.all(np.isfinite(mean_f))
""")


def ring_detection() -> dict:
    """`hop_bitflip` row: clean ring keeps every contribution (no false
    positives); a corrupted hop is dropped on every receiving rank."""
    proc = subprocess.run(
        [sys.executable, "-c", _RING_SCRIPT], capture_output=True,
        text=True, env={**os.environ, "PYTHONPATH": os.path.join(
            os.path.dirname(__file__), "..", "src")})
    if proc.returncode != 0:
        print(proc.stdout + proc.stderr, file=sys.stderr)
        return _detection_row("transport", "ring:reduce_mean",
                              {"hop_bitflip": False}, False)
    lines = dict(ln.split(" ", 1) for ln in
                 proc.stdout.strip().splitlines() if " " in ln)
    clean = [int(v) for v in lines.get("CLEAN", "").split()]
    fault = [int(v) for v in lines.get("FAULT", "").split()]
    clean_ok = clean == [2, 2]
    detected = bool(fault) and all(v < 2 for v in fault)
    return _detection_row("transport", "ring:reduce_mean",
                          {"hop_bitflip": detected}, clean_ok)


def overhead(smoke: bool) -> list:
    """verify= cost on the lossless GRAD_SUITES rows (run.py's chains)."""
    cut = 1 << 18 if smoke else None
    reps = 1 if smoke else 9
    chains = ("zero", "narrow", "narrow|ent", "delta|narrow|ent")
    rows = []
    for suite, gen in datasets.GRAD_SUITES.items():
        g = jnp.asarray(gen()[:cut])
        eb = float(jnp.sqrt(jnp.mean(g * g))) * 2.0 ** -8
        for chain in chains:
            pred = "delta|" if chain.startswith("delta|") else ""
            word = chain.removeprefix("delta|")
            pipe = parse_pipeline(
                f"{pred}abs:{eb!r}:cap=0.015625|pack:16|{word}")
            f_plain = jax.jit(lambda v, p=pipe: p.encode(v, kernels=False))
            f_verify = jax.jit(
                lambda v, p=pipe: p.encode(v, verify=True))
            t0, t1 = _time_pair(f_plain, f_verify, g, repeats=reps)
            frac = t1 / t0 - 1.0
            _, rep = f_verify(g)
            print(f"overhead.{suite}.{chain.replace('|', '+')}: "
                  f"plain={t0 * 1e6:.0f}us verify={t1 * 1e6:.0f}us "
                  f"overhead={frac * 100:+.1f}% "
                  f"violations={int(rep.violations)}")
            rows.append(dict(
                suite=suite, chain=chain, t_plain_us=t0 * 1e6,
                t_verify_us=t1 * 1e6, overhead_frac=frac,
                violations=int(rep.violations),
                max_err=float(rep.max_err), eb=eb))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.audit_bench", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="small datasets / single repeats (CI)")
    ap.add_argument("--out", default=OUT_DEFAULT,
                    help="artifact path (default: repo BENCH_audit.json)")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)

    det = detection(args.smoke)
    ovh = overhead(args.smoke)
    doc = dict(smoke=bool(args.smoke), detection=det, overhead=ovh)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")

    failures = [r for r in det if not r["all_detected"]]
    if failures:
        print(f"DETECTION FAILURES: {[r['name'] for r in failures]}")
        return 1
    bad = [r for r in ovh if r["violations"] != 0]
    if bad:
        print(f"AUDIT VIOLATIONS ON CLEAN ENCODES: "
              f"{[(r['suite'], r['chain']) for r in bad]}")
        return 1
    worst = max(ovh, key=lambda r: r["overhead_frac"])
    print(f"worst verify overhead: {worst['overhead_frac'] * 100:+.1f}% "
          f"({worst['suite']}.{worst['chain']}) bound "
          f"{OVERHEAD_BOUND * 100:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
