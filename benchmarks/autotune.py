"""Offline autotuner for the adaptive chain selector (DESIGN.md §11).

Sweeps every registered `SELECTOR_SETS` candidate over its
representative suites (the `exhaustive_sweep` discipline applied to the
chain space instead of the value space: measure EVERYTHING, then let the
cheap runtime statistics only have to rank, not predict), and produces:

  * per-suite rows — exact transmitted bits for every candidate, the
    statistics-chosen chain, the true best chain, and the auto-vs-best
    ratio — written to `BENCH_select.json` (consumed by
    `benchmarks.roofline --select-bench`);
  * bias calibration — the median measured-minus-estimated gap per
    candidate in bits per 1024 words; `--write` rewrites the `bias`
    tuples between the AUTOTUNED markers in `configs/registry.py` so the
    runtime scoring rule inherits the measurement.

Every dataset comes from the crc32-seeded `benchmarks.datasets`
registry, so tuning reproduces bit-for-bit across processes.

Usage: PYTHONPATH=src python -m benchmarks.autotune
           [--smoke] [--full] [--write] [--out BENCH_select.json]

--smoke shrinks the suites for CI (same flag grammar as run.py);
default size is 2^20 values per suite; --full uses the suites' native
~4M size.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import re
import sys
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import select as SEL

from . import datasets

GRAD_EB_REL = 2.0 ** -8      # the gradient wire's runtime bound policy
_REPO_ROOT = Path(__file__).resolve().parent.parent


def _zero_bias(sel):
    """Measure with bias off so the calibration is absolute."""
    return dataclasses.replace(sel, bias=tuple(0.0 for _ in sel.chains))


def _cut(smoke: bool, full: bool) -> int | None:
    return 1 << 16 if smoke else (None if full else 1 << 20)


# ------------------------------------------------- full-pipeline sets ----

def _pipeline_suites(name: str, smoke: bool, full: bool):
    """(suite name -> array, eb policy) for a full-pipeline set."""
    cut = _cut(smoke, full)
    if name == "grad-wire":
        suites = dict(datasets.GRAD_SUITES, iid=datasets.iid)
        data = {k: jnp.asarray(gen()[:cut]) for k, gen in suites.items()}
        # the wire's runtime per-tensor bound, like compress_shard
        ebs = {k: jnp.float32(GRAD_EB_REL) * jnp.sqrt(jnp.mean(v * v))
               for k, v in data.items()}
        return data, ebs
    if name == "sci-plane":
        grid = 256 if smoke else (1024 if full else 512)
        data = {"nyxplane": jnp.asarray(datasets.nyx_plane(grid))}
        return data, {"nyxplane": None}       # the spec's own bound
    raise KeyError(name)


def tune_pipeline_set(name: str, smoke: bool, full: bool):
    sel = _zero_bias(SEL.get_selector(name))
    data, ebs = _pipeline_suites(name, smoke, full)
    rows, deltas = [], [[] for _ in sel.chains]
    for suite, x in data.items():
        eb = ebs[suite]
        n = x.size
        n_words = sel.n_words(n)
        est = np.asarray(sel.score(x, eb))
        actual = []
        for pipe in sel.chains:
            enc = pipe.encode(x, eb, kernels=False)
            actual.append(float(pipe.wire_bits(enc, n)))
        wire = sel.encode(x, eb)
        auto_bits = float(sel.wire_bits(wire, n))
        cid = int(wire.chain_id)
        best = int(np.argmin(actual))
        for i in range(len(sel.chains)):
            deltas[i].append((actual[i] - float(est[i]))
                             / (n_words / 1024.0))
        rows.append({
            "set": name, "suite": suite, "n": int(n),
            "chosen": sel.chains[cid].spec(),
            "best": sel.chains[best].spec(),
            "auto_ratio": round(n * 32 / auto_bits, 3),
            "best_ratio": round(n * 32 / actual[best], 3),
            "auto_vs_best": round(actual[best] / auto_bits, 4),
            "chains": {sel.chains[i].spec(): round(n * 32 / actual[i], 3)
                       for i in range(len(sel.chains))},
        })
    return rows, _relative_bias(deltas)


# ------------------------------------------------------- KV page set ----

def _kv_caches(smoke: bool, full: bool):
    """Representative serving caches (crc32-seeded): a mid-decode cache
    (unwritten tail pages) and a token-correlated one (kvdelta's case)."""
    s, d = (256, 64) if smoke else ((2048, 64) if full else (1024, 64))
    r = datasets._rng("kvtune")
    mid = r.standard_normal((2, 2, s, d)).astype(np.float32)
    mid[:, :, int(s * 0.6):, :] = 0.0
    steps = r.standard_normal((2, 2, s, d)).astype(np.float32)
    corr = np.cumsum(steps, axis=2).astype(np.float32) * 0.05
    return {"kv": mid, "kvcorr": corr}


def tune_kv_set(name: str, smoke: bool, full: bool):
    from repro.compression import kv as KVC

    sel = _zero_bias(SEL.get_kv_selector(name))
    from repro.configs.registry import SELECTOR_SETS
    frags = SELECTOR_SETS[name]["chains"]
    page = 128
    rows, deltas = [], [[] for _ in sel.chains]
    for suite, cache in _kv_caches(smoke, full).items():
        q = KVC.quantize_kv(jnp.asarray(cache), KVC.kv_quantizer_config(),
                            page=page)
        *lead, s, d = q.bins.shape
        n_pages_total = int(np.prod(lead)) * (s // page)
        per = page * d
        wpp = per // 4
        # statics identical across fragments: eb2/outlier/overflow
        # planes + the per-page chain-id byte (page_costs already counts
        # each fragment's header content and transmitted length)
        statics = (q.eb2.size * 32 + q.out_idx.size * 32
                   + q.out_val.size * 32 + q.overflow.size * 8
                   + n_pages_total * 8)
        flat = q.bins.reshape(-1, per).astype(jnp.int32)
        costs = np.asarray(jax.vmap(
            lambda b: sel.page_costs(b, (page, d), 8, wpp))(flat))
        est = costs.sum(axis=0) + statics               # [n_chains]
        actual = []
        for frag in frags:
            w = KVC.pack_kv(q, page=page, stages=frag)
            # +1 byte/page chain id so static wires compare to auto
            actual.append(float(w.wire_nbytes()) * 8 + n_pages_total * 8)
        auto = KVC.pack_kv(q, page=page, stages=sel)
        auto_bits = float(auto.wire_nbytes()) * 8
        best = int(np.argmin(actual))
        raw_bits = cache.size * 32
        total_words = n_pages_total * wpp
        for i in range(len(sel.chains)):
            deltas[i].append((actual[i] - est[i])
                             / (total_words / 1024.0))
        chosen_ids, counts = np.unique(np.asarray(auto.chain_id),
                                       return_counts=True)
        rows.append({
            "set": name, "suite": suite, "n": int(cache.size),
            "chosen": frags[int(chosen_ids[int(np.argmax(counts))])],
            "chosen_pages": {frags[int(c)]: int(k)
                             for c, k in zip(chosen_ids, counts)},
            "best": frags[best],
            "auto_ratio": round(raw_bits / auto_bits, 3),
            "best_ratio": round(raw_bits / actual[best], 3),
            "auto_vs_best": round(actual[best] / auto_bits, 4),
            "chains": {frags[i]: round(raw_bits / actual[i], 3)
                       for i in range(len(frags))},
        })
    return rows, _relative_bias(deltas)


def _relative_bias(deltas) -> tuple:
    """Per-chain median measured-minus-estimated gap, shifted so the
    smallest is 0 — a shared constant (e.g. the §4 outlier-table statics
    every candidate pays identically) cancels in the argmin, so only the
    RELATIVE offsets carry calibration signal."""
    med = [float(np.median(d)) for d in deltas]
    lo = min(med)
    return tuple(round(m - lo, 3) for m in med)


# ------------------------------------------------------ registry write ---

def rewrite_registry_bias(bias_by_set: dict, path: Path | None = None):
    """Rewrite each set's `bias` tuple between the AUTOTUNED markers in
    configs/registry.py — the only generated values; chain membership
    and comments stay hand-edited."""
    path = path or (_REPO_ROOT / "src" / "repro" / "configs"
                    / "registry.py")
    text = path.read_text()
    begin = text.index("# --- AUTOTUNED BEGIN")
    end = text.index("# --- AUTOTUNED END")
    block = text[begin:end]
    for name, bias in bias_by_set.items():
        lit = "(" + ", ".join(f"{b:g}" for b in bias) + ("," if len(bias) == 1 else "") + ")"
        block, nsub = re.subn(
            r'("%s":\s*\{[^}]*"bias":\s*)\([^)]*\)' % re.escape(name),
            lambda m: m.group(1) + lit, block, count=1)
        if nsub != 1:
            raise RuntimeError(f"could not locate bias tuple for {name!r}")
    path.write_text(text[:begin] + block + text[end:])


# ------------------------------------------------------------- driver ----

def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.autotune", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="small suites for CI")
    ap.add_argument("--full", action="store_true",
                    help="native ~4M-value suites")
    ap.add_argument("--write", action="store_true",
                    help="rewrite the bias tuples in configs/registry.py")
    ap.add_argument("--out", default=str(_REPO_ROOT / "BENCH_select.json"),
                    help="where to write the per-suite rows")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)

    from repro.configs.registry import SELECTOR_SETS

    all_rows, bias_by_set = [], {}
    for name, entry in SELECTOR_SETS.items():
        if entry["base"] is None:
            rows, bias = tune_kv_set(name, args.smoke, args.full)
        else:
            rows, bias = tune_pipeline_set(name, args.smoke, args.full)
        all_rows.extend(rows)
        bias_by_set[name] = bias
        for r in rows:
            print(f"{r['set']}.{r['suite']}: chosen={r['chosen']} "
                  f"best={r['best']} auto={r['auto_ratio']}x "
                  f"best={r['best_ratio']}x "
                  f"auto/best={r['auto_vs_best']}")
        print(f"{name}: bias={bias}")

    Path(args.out).write_text(json.dumps(all_rows, indent=1) + "\n")
    print(f"wrote {args.out}")
    if args.write:
        rewrite_registry_bias(bias_by_set)
        print("rewrote SELECTOR_SETS bias tuples in configs/registry.py")


if __name__ == "__main__":
    main()
