"""Synthetic stand-ins for the paper's 7 SDRBench input suites (Table 2).

The container is offline, so each generator mimics the statistical
character of its suite (smoothness, dynamic range, noise floor) — enough
for compression-ratio and rounding-outlier behavior to be representative.
Sizes are scaled down (~4M values) to fit the CPU time budget; every
generator is deterministic ACROSS PROCESSES: seeds derive from
zlib.crc32 of the suite name, not the salted built-in hash(), so
compression ratios reproduce without pinning PYTHONHASHSEED.
"""
from __future__ import annotations

import zlib

import numpy as np

N = 1 << 22     # ~4M floats per suite (~16 MiB)


def _rng(name):
    return np.random.default_rng(zlib.crc32(name.encode()))


def cesm():     # climate: smooth 2-D fields, strong spatial correlation
    r = _rng("cesm")
    grid = int(np.sqrt(N))
    y, x = np.mgrid[0:grid, 0:grid] / grid
    base = (np.sin(2 * np.pi * 3 * x) * np.cos(2 * np.pi * 2 * y)
            + 0.3 * np.sin(2 * np.pi * 11 * (x + y)))
    field = 240 + 50 * base + r.standard_normal((grid, grid)) * 0.2
    return field.astype(np.float32).ravel()[:N]


def exaalt():   # molecular dynamics: clustered coordinates, wide spread
    r = _rng("exaalt")
    centers = r.uniform(-50, 50, (64, 1))
    pts = (centers[r.integers(0, 64, N)][:, 0]
           + r.standard_normal(N) * 0.8)
    return pts.astype(np.float32)


def hacc():     # cosmology particles: near-uniform positions
    r = _rng("hacc")
    return (r.uniform(0, 256, N) + r.standard_normal(N) * 1e-3).astype(
        np.float32)


def isabel():   # hurricane: smooth vortex + turbulence
    r = _rng("isabel")
    grid = int(np.sqrt(N))
    y, x = np.mgrid[0:grid, 0:grid] / grid - 0.5
    rad = np.sqrt(x * x + y * y) + 1e-3
    v = np.exp(-rad * 6) * np.sin(np.arctan2(y, x) * 2) * 60
    v += r.standard_normal((grid, grid)) * 0.5
    return v.astype(np.float32).ravel()[:N]


def nyx():      # cosmology density: lognormal, heavy tail
    r = _rng("nyx")
    return np.exp(r.standard_normal(N) * 1.4 + 8.0).astype(np.float32)


def qmcpack():  # quantum MC: oscillatory, decaying amplitudes
    r = _rng("qmcpack")
    t = np.arange(N, dtype=np.float64)
    w = (np.sin(t * 0.01) * np.exp(-(t % 4096) / 2000)
         + 0.01 * r.standard_normal(N))
    return w.astype(np.float32)


def scale():    # climate (SCALE-LETKF): smooth + fronts
    r = _rng("scale")
    grid = int(np.sqrt(N))
    y, x = np.mgrid[0:grid, 0:grid] / grid
    f = 300 + 30 * np.tanh((x - 0.5) * 8) + 10 * np.sin(2 * np.pi * 5 * y)
    f += r.standard_normal((grid, grid)) * 0.05
    return f.astype(np.float32).ravel()[:N]


SUITES = {
    "CESM": cesm, "EXAALT": exaalt, "HACC": hacc, "ISABEL": isabel,
    "NYX": nyx, "QMCPACK": qmcpack, "SCALE": scale,
}


# --- gradient-shaped inputs for the wire benchmarks (lossless stage) ------
#
# Real training gradients are row/channel-structured: a few rows carry the
# signal and the rest sit at the noise floor, far inside the quantizer's
# zero bin.  These generators span that spectrum so the lossless stage's
# zero-chunk/narrow wins (and its ~1x floor on dense data) are measured on
# representative shapes, not cherry-picked ones.

def grad_smooth():
    """Post-warmup dense-layer gradient: per-row scales, ~10% live rows,
    dead rows at the numerical noise floor (quantize to the zero bin)."""
    r = _rng("gradsmooth")
    rows = 2048
    live = r.random(rows) < 0.10
    scale = np.where(live, 3e-3, 1e-7).astype(np.float32)
    g = r.standard_normal((rows, N // rows)).astype(np.float32)
    return (g * scale[:, None]).ravel()


def grad_sparse():
    """Embedding-table gradient: ~1% of rows touched, the rest exactly
    zero (the classic sparse all-reduce workload)."""
    r = _rng("gradsparse")
    rows = 8192
    g = np.zeros((rows, N // rows), np.float32)
    touched = r.choice(rows, rows // 100, replace=False)
    g[touched] = r.standard_normal((touched.size, N // rows)) * 3e-3
    return g.ravel()


def grad_adversarial():
    """Worst case for the chunk coder: dense iid values, every bin live,
    no structure — the lossless stage must cost ~nothing here."""
    r = _rng("gradadv")
    return (r.standard_normal(N) * 3e-3).astype(np.float32)


def grad_walk():
    """EMA-smoothed optimizer-state shard: momentum buffers and
    accumulated gradients evolve as a slow random walk along the flat
    layout, so neighbouring values are strongly correlated — the
    representative input for the closed-loop `delta` predictor
    (DESIGN.md §9).  iid suites (gradsmooth/gradadv) carry no
    neighbour correlation and delta mathematically cannot win there;
    this one it must."""
    r = _rng("gradwalk")
    steps = r.standard_normal(N).astype(np.float32)
    walk = np.cumsum(steps, dtype=np.float64)
    walk *= 3e-3 / max(np.sqrt(np.mean(walk * walk)), 1e-30)
    return (walk + 1e-5 * steps).astype(np.float32)


GRAD_SUITES = {
    "gradsmooth": grad_smooth, "gradsparse": grad_sparse,
    "gradadv": grad_adversarial, "gradwalk": grad_walk,
}


def iid(n: int = N):
    """Adversarial iid noise: uniform values, no zeros, no neighbour
    correlation — the suite where the §9 predictors mathematically
    cannot win (delta residuals of white noise are a touch WIDER than
    the raw bins) and the chunk coder finds no dead chunks to drop.
    This is the selector's (DESIGN.md §11) "pred loses on iid" case:
    the auto choice must land on the best plain chain, never the delta
    one."""
    r = _rng("iid")
    return r.uniform(-1.0, 1.0, n).astype(np.float32)


def nyx_plane(grid: int = 1024):
    """2-D smooth cosmology plane (NYX-like slice): a low-pass random
    field with NYX's lognormal amplitude character plus a small noise
    floor — the representative dataset for the 2-D `lorenzo` predictor
    (DESIGN.md §9).  Returned as (grid, grid) float32 so the plane
    structure reaches the pred stage via `pred_shape`."""
    r = _rng("nyxplane")
    white = r.standard_normal((grid, grid))
    ky = np.fft.fftfreq(grid)[:, None]
    kx = np.fft.fftfreq(grid)[None, :]
    lowpass = np.exp(-(kx * kx + ky * ky) / (2 * 0.01 ** 2))
    smooth = np.fft.ifft2(np.fft.fft2(white) * lowpass).real
    smooth /= max(np.sqrt(np.mean(smooth * smooth)), 1e-30)
    field = np.exp(smooth * 1.4 + 8.0) + 2.0 * r.standard_normal(
        (grid, grid))
    return field.astype(np.float32)


def rel_mixed():
    """Mixed-sign REL bins: |x| straddles 1, so the log-domain bins carry
    both signs and two's-complement sign extension sets the high bits of
    every packed word — the case the shuffle stage (DESIGN.md §7) exists
    for (narrow alone sits at its ~1x floor here)."""
    r = _rng("relmix")
    mag = np.exp(r.standard_normal(N) * 1.5)            # log2|x| ~ N(0, 2.2)
    sgn = np.where(r.random(N) < 0.5, -1.0, 1.0)
    return (mag * sgn).astype(np.float32)


def special_values(n=1 << 16):
    """The paper's generated special-value inputs: INF/NaN/denormal mix."""
    r = _rng("specials")
    bits = r.integers(0, 1 << 32, n, dtype=np.uint32)
    x = bits.view(np.float32).copy()
    x[:: 64] = np.inf
    x[1:: 64] = -np.inf
    x[2:: 64] = np.nan
    x[3:: 64] = np.uint32(0x7FC00123).view(np.float32)   # NaN payload
    x[4:: 64] = 1e-42                                    # denormal
    x[5:: 64] = -1e-42
    x[6:: 64] = 0.0
    x[7:: 64] = -0.0
    return x
