"""Continuous-batching decode microbenchmark (DESIGN.md §10).

Drives `models.engine.DecodeEngine` over a stream of requests — prefill,
slot insert through the PackedKV wire, batched generate steps, slot churn
— and reports the three serving numbers the perf trajectory tracks:

    tokens/s                batched decode throughput (greedy, all slots)
    ms/step                 wall time of one vmapped generate_step
    wire bytes vs raw       per-slot hand-off wire vs the raw-bf16 cache

    PYTHONPATH=src python -m benchmarks.engine_bench --smoke
    PYTHONPATH=src python -m benchmarks.engine_bench --stream  # 2-device
                                      # streaming-migration row (sets
                                      # XLA_FLAGS before jax imports)

Writes rows (roofline-style list of dicts, the format
`benchmarks/roofline.py --decode-bench` consumes) to --out; the committed
BENCH_decode.json at the repo root is the `--smoke` artifact — CPU
numbers, there to pin the format and the trajectory's first point, not to
impress.
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import sys
import time
import zlib

if "--stream" in sys.argv:                  # must precede the jax import
    _flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=2").strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                       # noqa: E402
import jax                                               # noqa: E402

from repro.configs import registry                       # noqa: E402
from repro.configs.registry import get_kv_chain          # noqa: E402
from repro.models import build                           # noqa: E402
from repro.models import engine as E                     # noqa: E402
from repro.models import serve as S                      # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _bench_engine(cfg, params, *, n_slots, seq, prompts, new_tokens,
                  stages):
    """Timed continuous-batching loop (the engine.run scheduler with
    phase timers).  Returns the measured row fields."""
    eng = E.DecodeEngine(cfg, params, n_slots=n_slots, seq=seq,
                         stages=stages)
    # warmup: compile prefill step + vmapped generate step outside timers
    pre = eng.prefill(np.zeros(1, np.int32))
    eng.insert(0, pre)
    eng.generate_step()
    eng.release(0)
    base = eng.stats()

    t_prefill = t_decode = 0.0
    pending = collections.deque(enumerate(prompts))
    budget = {}
    while pending or any(r is not None for r in eng.requests):
        while pending:
            slot = eng.allocate()
            if slot is None:
                break
            rid, prompt = pending.popleft()
            t0 = time.perf_counter()
            pre = eng.prefill(prompt)
            eng.insert(slot, pre, request=rid)
            jax.block_until_ready(eng._cache)
            t_prefill += time.perf_counter() - t0
            budget[rid] = new_tokens - 1
        if not any(r is not None for r in eng.requests):
            continue
        t0 = time.perf_counter()
        _, toks = eng.generate_step()
        toks = np.asarray(toks)                 # sync — honest step time
        t_decode += time.perf_counter() - t0
        for slot, rid in enumerate(list(eng.requests)):
            if rid is None:
                continue
            budget[rid] -= 1
            if budget[rid] <= 0 or int(eng._pos[slot]) >= seq:
                eng.release(slot)               # slot churn
    st = eng.stats()
    steps = st["steps"] - base["steps"]
    gen = st["generated_tokens"] - base["generated_tokens"]
    pre_toks = st["prefill_tokens"] - base["prefill_tokens"]
    inserts = st["inserts"] - base["inserts"]
    wire = st["wire_bytes"] - base["wire_bytes"]
    return {
        "decode_steps": steps,
        "generated_tokens": gen + inserts,      # prefill yields token 1
        "tokens_per_s": (gen + inserts) / max(t_decode + t_prefill, 1e-9),
        "decode_tokens_per_s": gen / max(t_decode, 1e-9),
        "ms_per_step": 1e3 * t_decode / max(steps, 1),
        "prefill_tokens_per_s": pre_toks / max(t_prefill, 1e-9),
        "wire_bytes_per_slot": wire / max(inserts, 1),
        "raw_bf16_bytes_per_slot": eng.raw_slot_bytes(),
        "wire_vs_raw": (wire / max(inserts, 1)) / eng.raw_slot_bytes(),
    }


def _bench_stream(cfg, params, *, seq, prompt, stages):
    """Streaming-migration row: prefill on rank 0 of a 2-device mesh with
    per-page sends overlapping the ongoing prefill (DESIGN.md §10)."""
    mesh = jax.make_mesh((2,), ("wire",))
    # warmup compile
    E.stream_prefill(cfg, params, prompt[:S.PAGE + 1], seq=seq, mesh=mesh,
                     axis="wire", stages=stages)
    t0 = time.perf_counter()
    sp = E.stream_prefill(cfg, params, prompt, seq=seq, mesh=mesh,
                          axis="wire", stages=stages)
    jax.block_until_ready(sp.cache)
    dt = time.perf_counter() - t0
    return {
        "pages_streamed": sp.stats["pages_streamed"],
        "prefill_tokens_per_s": sp.stats["prefill_tokens"] / dt,
        "wire_bytes": sp.stats["wire_bytes"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: tiny reduced model, seconds on CPU")
    ap.add_argument("--arch", default="deepseek-67b")
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--new-tokens", type=int, default=None)
    ap.add_argument("--stages", default="kv-page",
                    help="page-chain preset or fragment (registry "
                         "KV_PAGE_CHAINS)")
    ap.add_argument("--stream", action="store_true",
                    help="add the 2-device streaming-migration row")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_decode.json"))
    args = ap.parse_args()

    if args.smoke:
        defaults = dict(slots=2, seq=256, requests=3, prompt_len=130,
                        new_tokens=8)
    else:
        defaults = dict(slots=4, seq=512, requests=8, prompt_len=200,
                        new_tokens=32)
    for k, v in defaults.items():
        if getattr(args, k if k != "prompt_len" else "prompt_len") is None:
            setattr(args, k, v)

    cfg = registry.get(args.arch).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    stages = get_kv_chain(args.stages)
    rng = np.random.default_rng(zlib.crc32(b"engine-prompts"))
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len)
               .astype(np.int32) for _ in range(args.requests)]

    row = {
        "bench": "engine_decode", "arch": args.arch, "reduced": True,
        "backend": jax.default_backend(), "page": S.PAGE,
        "n_slots": args.slots, "seq": args.seq,
        "requests": args.requests, "prompt_len": args.prompt_len,
        "new_tokens": args.new_tokens, "stages": args.stages,
        "smoke": bool(args.smoke),
    }
    row.update(_bench_engine(cfg, params, n_slots=args.slots, seq=args.seq,
                             prompts=prompts, new_tokens=args.new_tokens,
                             stages=stages))
    rows = [row]
    print(f"engine_decode[{args.arch} reduced, {args.slots} slots, "
          f"seq {args.seq}, {args.requests} reqs]: "
          f"{row['tokens_per_s']:.1f} tok/s end-to-end "
          f"({row['decode_tokens_per_s']:.1f} decode-only), "
          f"{row['ms_per_step']:.2f} ms/step, wire/slot "
          f"{row['wire_bytes_per_slot']/2**10:.1f} KiB vs raw "
          f"{row['raw_bf16_bytes_per_slot']/2**10:.1f} KiB "
          f"({1/row['wire_vs_raw']:.2f}x smaller)")

    if args.stream:
        assert jax.device_count() >= 2, "--stream needs 2 devices"
        srow = dict(row, bench="engine_stream")
        srow.update(_bench_stream(cfg, params, seq=args.seq,
                                  prompt=prompts[0], stages=stages))
        rows.append(srow)
        print(f"engine_stream: {srow['pages_streamed']} pages overlapped "
              f"with prefill at {srow['prefill_tokens_per_s']:.1f} tok/s, "
              f"{srow['wire_bytes']/2**10:.1f} KiB on the wire")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.relpath(args.out, ROOT)}")


if __name__ == "__main__":
    main()
