"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh, derive the three terms:

    compute    = HLO_dot_FLOPs_per_device / 197e12          [s]
    memory     = HLO_bytes_per_device / 819e9               [s]
    collective = per-device collective wire bytes / 50e9    [s]

Sources: the SPMD HLO module is PER-DEVICE, so shapes parsed from it are
already per-chip.  hlo_analysis multiplies everything by while-loop trip
counts (XLA's cost_analysis counts loop bodies once — measured 40x low).
`cost_flops`/`cost_bytes` columns keep the raw XLA numbers for contrast.

Memory bytes: sum of materialized op outputs (fusion/dot/copy/...) x trip
multipliers + entry parameters — an upper-ish bound on HBM traffic that
ignores VMEM reuse within fusions (documented approximation).

Collective seconds use kind factors: all-reduce 2x its payload (ring
reduce-scatter + all-gather), others 1x their result size.

MODEL_FLOPS = 6 * N_active * tokens (train; 2x for prefill-only, per-token
for decode) — the `useful/HLO` ratio exposes remat and capacity waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
Writes results/roofline.json and prints the markdown table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import registry                      # noqa: E402
from repro.configs.base import SHAPES                   # noqa: E402
from repro.launch import hlo_analysis as H              # noqa: E402

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# Only op classes whose OUTPUT actually round-trips HBM on TPU: fusion
# roots, dots, explicit copies/slice-updates, gathers/scatters.  Loose
# elementwise/convert/transpose/select ops fuse into consumers and were
# over-counting memory ~10x (validated against analytic weight traffic).
_MEM_OPS = ("fusion", "copy(", " dot(", "scatter", "gather(",
            "dynamic-update-slice", "dynamic-slice", "convolution",
            "custom-call")


def memory_bytes(hlo: str) -> int:
    comps = H.split_computations(hlo)
    mult = H.computation_multipliers(hlo)
    fused = H.fused_computations(comps)
    entry = max((n for n in comps if "main" in n), key=len, default=None)
    total = 0
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0 or name in fused:        # fusion internals stay in VMEM
            continue
        for ln in lines:
            if not any(op in ln for op in _MEM_OPS):
                # parameters: count HBM reads only at the entry (arguments);
                # loop/fusion params alias already-counted buffers
                if "parameter(" not in ln or name != entry:
                    continue
            sm = re.match(r"%?[\w\.\-]+ = \(?(\w+\[[\d,]*\])", ln)
            if sm:
                total += H._shape_bytes(sm.group(1)) * m
    return total


# XLA:CPU's AllReducePromotion pass rewrites every bf16 all-reduce as
# convert->f32 AR->convert (CPU has no bf16 reduction); TPU reduces bf16
# natively.  The dry-run HLO therefore shows activation ARs at 2x their
# v5e wire size — corrected here (the genuinely-f32 ARs, e.g. loss
# scalars and f32 gradient reductions, are second-order at these scales;
# the correction is documented in EXPERIMENTS.md §Roofline).
F32_AR_PROMOTION_CORRECTION = 0.5


def collective_seconds(coll: dict) -> float:
    t = 0.0
    for kind, b in coll.items():
        if kind.startswith("__"):
            continue
        factor = 2.0 if kind == "all-reduce" else 1.0
        if kind == "all-reduce":
            factor *= F32_AR_PROMOTION_CORRECTION
        t += factor * b / LINK_BW
    return t


def model_flops(arch_name: str, shape_name: str) -> float:
    cfg = registry.get(arch_name)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens          # fwd(2) + bwd(4)
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    attn = 0.0
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        attn = (4.0 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
                * shape.seq_len * shape.global_batch)
    return 2.0 * n_active * shape.global_batch + attn


def analyze(mesh="single", with_hlo_mem=True):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "dryrun",
                                              f"{mesh}.*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok" or rec.get("variant",
                                                "baseline") != "baseline":
            continue
        n_dev = rec["n_devices"]
        t_comp = rec["hlo_dot_flops"] / PEAK_FLOPS
        hlo_path = path.replace(".json", ".hlo")
        if with_hlo_mem and os.path.exists(hlo_path):
            mem_b = memory_bytes(open(hlo_path).read())
        else:
            mem_b = rec["cost_bytes"]
        t_mem = mem_b / HBM_BW
        t_coll = collective_seconds(rec["collective_bytes"])
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_global = rec["hlo_dot_flops"] * n_dev
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": mesh,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dom,
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "roofline_frac": (max(t_comp, mf / n_dev / PEAK_FLOPS)
                              / max(sum(terms.values()), 1e-12)),
            "hbm_bytes_per_dev": mem_b,
            "collective_bytes": {k: v for k, v in
                                 rec["collective_bytes"].items()},
            "temp_gib": rec["temp_bytes"] / 2 ** 30,
            "args_gib": rec["arg_bytes"] / 2 ** 30,
        })
    return rows


def markdown(rows):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | useful/HLO | temp GiB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['temp_gib']:.1f} |")
    return "\n".join(out)


def markdown_select(rows):
    """Measured selector rows from benchmarks/autotune.py
    (BENCH_select.json): per suite, the statistics-chosen chain vs the
    true best candidate and the auto-vs-best ratio — the empirical
    evidence that the §11 runtime scoring rule ranks correctly."""
    out = ["| set | suite | chosen | best | auto x | best x | "
           "auto/best |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['set']} | {r['suite']} | {r['chosen']} | {r['best']} "
            f"| {r['auto_ratio']} | {r['best_ratio']} | "
            f"{r['auto_vs_best']} |")
    return "\n".join(out)


def markdown_decode(rows):
    """Measured serving rows from benchmarks/engine_bench.py
    (BENCH_decode.json) — the empirical companion to the analytic
    roofline: tokens/s and ms/step are wall-clock, wire/raw is the
    per-slot PackedCache hand-off vs the raw-bf16 cache."""
    out = ["| bench | arch | slots | seq | tok/s | ms/step | wire/raw |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['bench']} | {r['arch']} | {r['n_slots']} | {r['seq']} "
            f"| {r['tokens_per_s']:.1f} | {r['ms_per_step']:.2f} | "
            f"{r['wire_vs_raw']:.3f} |")
    return "\n".join(out)


def markdown_audit(doc):
    """Measured audit rows from benchmarks/audit_bench.py
    (BENCH_audit.json) — the §12 evidence tables: the fault-injection
    detection matrix (one row per wire, one column per fault class) and
    the `verify=` overhead on the lossless gradient rows."""
    classes = ("payload_bitflip", "header_bitflip", "length_truncate",
               "chainid_swap", "nan_input")
    out = ["| wire | " + " | ".join(c.replace("_", " ") for c in classes)
           + " | clean |",
           "|---|" + "---|" * (len(classes) + 1)]
    for r in doc.get("detection", ()):
        cells = [("ok" if r["matrix"][c] else "MISS")
                 if c in r["matrix"] else "-" for c in classes]
        out.append(f"| {r['kind']}:{r['name']} | " + " | ".join(cells)
                   + f" | {'ok' if r['clean_ok'] else 'FALSE-POSITIVE'} |")
    out += ["",
            "| suite | chain | plain us | verify us | overhead | "
            "violations |",
            "|---|---|---|---|---|---|"]
    for r in doc.get("overhead", ()):
        out.append(
            f"| {r['suite']} | {r['chain']} | {r['t_plain_us']:.0f} | "
            f"{r['t_verify_us']:.0f} | {r['overhead_frac'] * 100:+.1f}% | "
            f"{r['violations']} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--decode-bench", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_decode.json"),
        help="engine_bench artifact to append as a measured-decode table")
    ap.add_argument("--select-bench", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_select.json"),
        help="autotune artifact to append as a selector table (§11)")
    ap.add_argument("--audit-bench", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_audit.json"),
        help="audit_bench artifact to append as the §12 "
             "detection/overhead tables")
    args = ap.parse_args()
    rows = analyze(args.mesh)
    with open(os.path.join(RESULTS, f"roofline.{args.mesh}.json"),
              "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown(rows))
    if os.path.exists(args.decode_bench):
        print()
        print(markdown_decode(json.load(open(args.decode_bench))))
    if os.path.exists(args.select_bench):
        print()
        print(markdown_select(json.load(open(args.select_bench))))
    if os.path.exists(args.audit_bench):
        print()
        print(markdown_audit(json.load(open(args.audit_bench))))


if __name__ == "__main__":
    main()
