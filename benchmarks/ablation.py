"""Paper-faithful ablation quantizers (benchmarks only — NO guarantee
claims; the production codec in repro.core uses pow2-floored steps).

The paper's Fig 1 compares REL with library log/pow vs the bit-trick
approximations, at the NATURAL step w = log2(1+eb).  Our production codec
floors w to a power of two, which (a) makes arithmetic exact (FMA-immune)
and (b) — measured here — absorbs the octave-slope variation of the
piecewise-linear log2approx, so the bit-trick costs NO ratio vs the
library.  To reproduce the paper's ~5% effect we need the free step:
at w = log2(1+eb) the approximate-log bins are up to 2x wider than the
true-log bins near octave tops, those values fail the double-check, and
the outlier rate (= ratio loss) climbs.
"""
from __future__ import annotations

import math

import numpy as np

import jax.numpy as jnp

from repro.core import QuantizerConfig
from repro.core.bitops import float_to_bits, log2approx, pow2approx


def quantize_rel_freestep(x: jnp.ndarray, cfg: QuantizerConfig,
                          library: bool):
    """REL with the paper's natural step w = log2(1+eb) (not pow2-floored)
    and either the bit-trick (library=False) or backend log2/exp2."""
    dt = x.dtype
    eb = dt.type(cfg.error_bound)
    # the TIGHT step the paper's LC uses: centers at bin*w, half-width
    # log2(1+eb) -> an EXACT log accepts (almost) everything, while the
    # bit-trick's piecewise-linear slope error pushes values out
    w = dt.type(2.0 * math.log2(1.0 + cfg.error_bound))
    inv_w = dt.type(1.0) / w
    maxbin = cfg.maxbin

    finite = jnp.isfinite(x)
    ax = jnp.abs(x)
    too_small = ~(ax >= jnp.asarray(cfg.rel_screen_threshold(), dt))
    safe = jnp.where(finite & ~too_small, ax, jnp.ones((), dt))
    lg = jnp.log2(safe) if library else log2approx(safe)
    bin_f = jnp.rint(lg * inv_w)
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f),
                      bin_f).astype(jnp.int32)
    mag = (jnp.exp2(bin_i.astype(dt) * w) if library
           else pow2approx(bin_i.astype(dt) * w))
    neg = float_to_bits(x) < 0
    recon = jnp.where(neg, -mag, mag)
    ebT = jnp.asarray(dt.type(eb) * dt.type(cfg.tighten), dt)
    ok = (jnp.abs(x - recon) <= ebT * ax) & jnp.isfinite(recon)
    ok &= mag >= jnp.asarray(np.finfo(dt).tiny, dt)
    outlier = (~finite) | too_small | range_bad | ~ok
    return jnp.where(outlier, 0, bin_i), outlier
