"""Exhaustive float32 validation — the paper's §6 claim ("we exhaustively
tested it on all roughly 4 billion possible 32-bit floating-point values").

Sweeps ALL 2^32 bit patterns in slabs through the ABS and REL roundtrip
and verifies, in float64, that every decoded value is within the bound or
bit-identical.  ~2^32 values x a few ebs is CPU-hours: `--slabs N` runs N
random-offset slabs (default 64 x 2^20 ~= 67M values, a superset of every
exponent class); `--full` runs the whole space; `--smoke` runs the CI
subset (the exponent-boundary slabs plus a few random ones).

Slab selection shares `benchmarks.datasets`' crc32-seeded registry
(seeds derive from zlib.crc32 of a name, never the salted built-in
hash), so the checked subset reproduces across processes without
pinning PYTHONHASHSEED — the same discipline as every suite generator.

    PYTHONPATH=src python -m benchmarks.exhaustive_sweep [--full|--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig, roundtrip_dense

from .datasets import _rng

SLAB = 1 << 20


def verify_slab(start: int, cfg: QuantizerConfig) -> int:
    bits = (np.arange(start, start + SLAB, dtype=np.int64)
            .astype(np.uint32))
    x = bits.view(np.float32)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    fin = np.isfinite(x)
    if cfg.mode == "abs":
        bad = np.abs(x[fin].astype(np.float64)
                     - y[fin].astype(np.float64)) > cfg.error_bound
    else:
        m = fin & (x != 0)
        xv = x[m].astype(np.float64)
        bad = np.abs(xv - y[m].astype(np.float64)) / np.abs(xv) \
            > cfg.error_bound
        exact_rest = np.array_equal(x[fin & (x == 0)].view(np.uint32),
                                    y[fin & (x == 0)].view(np.uint32))
        if not exact_rest:
            return SLAB
    nf = ~fin
    if not np.array_equal(x[nf].view(np.uint32), y[nf].view(np.uint32)):
        return int(np.sum(nf))
    return int(np.sum(bad))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--slabs", type=int, default=64)
    ap.add_argument("--eb", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: the exponent-boundary slabs plus "
                         "4 random ones (same flag grammar as run.py)")
    args = ap.parse_args()

    total_slabs = (1 << 32) // SLAB
    if args.full:
        starts = [i * SLAB for i in range(total_slabs)]
    else:
        n_slabs = 4 if args.smoke else args.slabs
        # crc32-seeded like every datasets.py generator — the checked
        # subset is identical in every process
        starts = sorted(int(i) * SLAB for i in _rng("sweep").choice(
            total_slabs, size=n_slabs, replace=False))
        # always include the exponent-boundary slabs
        starts = sorted(set(starts) | {0, 0x7F000000, 0x7F800000,
                                       0x80000000, 0xFF000000})

    for mode in ("abs", "rel"):
        cfg = QuantizerConfig(mode=mode, error_bound=args.eb, bin_bits=32)
        viol = 0
        t0 = time.time()
        for i, s in enumerate(starts):
            viol += verify_slab(s, cfg)
            if i % 32 == 31:
                print(f"  {mode}: {i+1}/{len(starts)} slabs, "
                      f"violations={viol}, {time.time()-t0:.0f}s",
                      flush=True)
        n = len(starts) * SLAB
        print(f"{mode} eb={args.eb:g}: {n/2**30:.2f}G values checked, "
              f"violations={viol}")
        if viol:
            sys.exit(1)
    print("exhaustive sweep: GUARANTEE HOLDS on every checked value")


if __name__ == "__main__":
    main()
