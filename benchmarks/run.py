"""Benchmark harness — one function per paper table/figure, plus
beyond-paper system benchmarks.  Prints ``name,us_per_call,derived`` CSV
(derived = the table's metric: ratio, GB/s, %, ...).

  table3   special-value handling matrix (paper Table 3)
  table4   REL ratio: library log/pow vs parity-safe approximations (Fig 1)
  table56  REL codec throughput: original vs replaced fns (Fig 2, T5/T6)
  table7   ABS throughput: protected vs unprotected (Fig 3)
  table8   ABS ratio: protected vs unprotected (Fig 4)
  table9   % values hitting the rounding-error fallback
  ckpt     checkpoint codec ratio (beyond paper)
  kv       KV-cache compression footprint + error (beyond paper)
  gradwire cross-pod gradient wire bytes (beyond paper)
  packedwire packed vs unpacked wire + codec throughput (beyond paper)
  lossless device-side lossless stages: end-to-end ratio vs packed/f32
           on gradient-shaped + scientific data, KV pages, Pallas
           parity, the shuffle stage on mixed-sign REL bins, the
           `ent` entropy stage over surviving chunk payloads, and the
           closed-loop predictor rows (`delta` on the correlated
           gradient walk, 2-D `lorenzo` on the NYX-like plane, §9)
  transfer prefill->decode KV transfer (DESIGN.md §8): PackedCache wire
           bytes per stage chain (incl. the §9 `kvdelta` page chain)
           vs raw pages, pack/unpack throughput, and simulated link
           occupancy under load

Usage: PYTHONPATH=src python -m benchmarks.run [names...]
           [--pipeline SPEC|PRESET] [--smoke]

--pipeline benches an arbitrary pipeline chain (DESIGN.md §7 spec string
like "rel:1e-3|pack:8|zero|narrow", a configs.registry preset name, or
"auto" / "auto:SET" for the §11 adaptive selector — the chosen chain is
reported per suite) in the `lossless` table; --smoke shrinks the
lossless and transfer tables' datasets/repeats for CI.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (QuantizerConfig, compression_ratio, decode_dense,
                        encode_dense, roundtrip_dense, serialize)
from repro.core.quantizer import (quantize_abs, quantize_abs_unprotected,
                                  quantize_rel, quantize_rel_library)

from . import datasets

EB = 1e-3      # the paper's evaluation bound for Figs 1-4


def _time(f, *args, repeats=5):
    f(*args)                                    # compile/warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = f(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------- tables --

def table3():
    """Paper Table 3: which value classes are handled with the bound
    guaranteed.  For LC(ours) every cell must be 'ok'."""
    x = datasets.special_values()
    for mode in ("abs", "rel"):
        cfg = QuantizerConfig(mode=mode, error_bound=EB, bin_bits=32)
        t0 = time.perf_counter()
        y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
        us = (time.perf_counter() - t0) * 1e6
        fin = np.isfinite(x)
        if mode == "abs":
            viol = np.sum(np.abs(x[fin].astype(np.float64) - y[fin]) > EB)
        else:
            m = fin & (x != 0)
            viol = np.sum(np.abs((x[m].astype(np.float64) - y[m])
                                 / x[m].astype(np.float64)) > EB)
        exact = np.array_equal(x[~fin].view(np.uint32),
                               y[~fin].view(np.uint32))
        status = "ok" if viol == 0 and exact else f"VIOLATIONS={viol}"
        _emit(f"table3.{mode}.normal+inf+nan+denormal", us, status)


def _rel_est_ratio(x, outlier):
    # bins+payload+sign cost model (matches the serializer layout)
    n_out = float(jnp.sum(outlier))
    bits = x.size * 16 + n_out * 32 + x.size
    return x.size * 32 / bits


def table4():
    """Fig 1 / Table 4: REL compression ratio, parity-safe bit-trick
    log2/pow2 vs the library functions.

    Two comparisons are reported:
      * freestep — the paper's setting (w = log2(1+eb) exactly): the
        bit-trick's octave-slope error pushes border values to the
        lossless fallback, reproducing the paper's ~5% loss;
      * pow2step — OUR production codec: the pow2-floored step absorbs
        that slope error entirely, so parity costs NO ratio vs the
        library (a beyond-paper improvement; the <=1-bit finer step is
        already included in both sides).
    """
    from .ablation import quantize_rel_freestep

    cfg = QuantizerConfig(mode="rel", error_bound=EB, bin_bits=32)
    fs_ratios, ps_ratios = [], []
    for name, gen in datasets.SUITES.items():
        x = gen()
        xj = jnp.asarray(x)
        t0 = time.perf_counter()
        q_ours = quantize_rel(xj, cfg)
        jax.block_until_ready(q_ours.bins)
        us = (time.perf_counter() - t0) * 1e6
        q_lib = quantize_rel_library(xj, cfg)
        _, out_fs_trick = quantize_rel_freestep(xj, cfg, library=False)
        _, out_fs_lib = quantize_rel_freestep(xj, cfg, library=True)

        fs = (_rel_est_ratio(x, out_fs_trick)
              / _rel_est_ratio(x, out_fs_lib))
        ps = (_rel_est_ratio(x, q_ours.outlier)
              / _rel_est_ratio(x, q_lib.outlier))
        fs_ratios.append(fs)
        ps_ratios.append(ps)
        _emit(f"table4.{name}", us,
              f"freestep_norm={fs:.4f} pow2step_norm={ps:.4f}")
    _emit("table4.geomean.freestep", 0.0,
          f"{np.exp(np.mean(np.log(fs_ratios))):.4f} (paper: ~0.948)")
    _emit("table4.geomean.pow2step", 0.0,
          f"{np.exp(np.mean(np.log(ps_ratios))):.4f} (ours: parity is free)")


def table56():
    """Fig 2 / Tables 5-6: REL throughput with replaced vs library fns
    (paper: within +-1%).  GB/s of the jitted quantize on this CPU."""
    cfg = QuantizerConfig(mode="rel", error_bound=EB, bin_bits=32)
    f_ours = jax.jit(lambda v: quantize_rel(v, cfg).bins)
    f_lib = jax.jit(lambda v: quantize_rel_library(v, cfg).bins)
    for name in ("CESM", "HACC", "QMCPACK"):
        x = jnp.asarray(datasets.SUITES[name]())
        t_ours = _time(f_ours, x)
        t_lib = _time(f_lib, x)
        gbs = x.size * 4 / t_ours / 1e9
        _emit(f"table56.compress.{name}", t_ours * 1e6,
              f"{gbs:.2f}GB/s rel_to_lib={t_lib / t_ours:.3f}")


def table7():
    """Fig 3 / Table 7: ABS compression throughput, double-check protected
    vs unprotected (paper: no significant change on memory-bound GPU; this
    CPU is compute-bound so the checks cost ~10-15% — the TPU VPU roofline
    argument is in EXPERIMENTS.md)."""
    cfg = QuantizerConfig(mode="abs", error_bound=EB, bin_bits=32)
    f_p = jax.jit(lambda v: quantize_abs(v, cfg).bins)
    f_u = jax.jit(lambda v: quantize_abs_unprotected(v, cfg).bins)
    for name in ("CESM", "EXAALT", "SCALE"):
        x = jnp.asarray(datasets.SUITES[name]())
        t_p, t_u = _time(f_p, x), _time(f_u, x)
        _emit(f"table7.{name}", t_p * 1e6,
              f"{x.size*4/t_p/1e9:.2f}GB/s protected/unprotected="
              f"{t_u / t_p:.3f}")


def table8():
    """Fig 4 / Table 8: ABS ratio protected vs unprotected (paper: ~5%
    lower with protection, EXAALT worst)."""
    import zlib

    # bin_bits=32: the suites span O(100) magnitudes, so eb=1e-3 needs
    # ~18-bit bins — int16 would make everything a range outlier
    cfg = QuantizerConfig(mode="abs", error_bound=EB, bin_bits=32)
    rels = []
    for name, gen in datasets.SUITES.items():
        x = gen()
        r_p = compression_ratio(x, cfg)
        q = quantize_abs_unprotected(jnp.asarray(x), cfg)
        n_out = float(jnp.sum(q.outlier))
        bins32 = np.asarray(q.bins, np.int64).astype(np.int32).tobytes()
        stream = zlib.compress(bins32, 6)
        r_u = x.nbytes / (len(stream) + n_out * 4 + 24)
        rels.append(r_p / r_u)
        _emit(f"table8.{name}", 0.0,
              f"protected={r_p:.2f}x unprotected={r_u:.2f}x "
              f"norm={r_p / r_u:.4f}")
    _emit("table8.geomean", 0.0,
          f"{np.exp(np.mean(np.log(rels))):.4f} (paper: ~0.95)")


def table9():
    """Table 9: % of values whose rounding error forces the lossless
    fallback (paper avg 0.00-3.41%, max 11.16%).

    Production codec column is ~0% BY CONSTRUCTION: pow2 steps make the
    quantization arithmetic exact, eliminating the paper's rounding-error
    class entirely (the cost moved into <=1-bit-finer bins).  The REL
    freestep column reproduces the paper's effect."""
    from .ablation import quantize_rel_freestep

    cfg = QuantizerConfig(mode="abs", error_bound=EB, bin_bits=32)
    cfg_r = QuantizerConfig(mode="rel", error_bound=EB, bin_bits=32)
    for name, gen in datasets.SUITES.items():
        x = gen()
        q = quantize_abs(jnp.asarray(x), cfg)
        qu = quantize_abs_unprotected(jnp.asarray(x), cfg)
        extra = float(jnp.sum(q.outlier)) - float(jnp.sum(qu.outlier))
        _, fs_trick = quantize_rel_freestep(jnp.asarray(x), cfg_r, False)
        _, fs_lib = quantize_rel_freestep(jnp.asarray(x), cfg_r, True)
        fs = (float(jnp.sum(fs_trick)) - float(jnp.sum(fs_lib))) / x.size
        _emit(f"table9.{name}", 0.0,
              f"pow2step={100 * extra / x.size:.3f}% "
              f"freestep_rel={100 * fs:.3f}%")


# ------------------------------------------------------- beyond paper ----

def ckpt():
    """Checkpoint codec: LC-serialized f32 master weights vs raw."""
    r = datasets._rng("ckpt-weights")
    w = (r.standard_normal(1 << 21) * 0.02).astype(np.float32)
    for eb in (1e-5, 1e-6, 1e-7):
        cfg = QuantizerConfig(mode="abs", error_bound=eb)
        t0 = time.perf_counter()
        stream = serialize(w, cfg)
        us = (time.perf_counter() - t0) * 1e6
        _emit(f"ckpt.eb{eb:g}", us, f"{w.nbytes / len(stream):.2f}x")


def kv():
    """KV-cache quantization: footprint + worst-page error vs bound, plus
    the packed wire form a cache migration would ship."""
    from repro.compression.kv import (dequantize_kv, kv_quantizer_config,
                                      kv_wire_bytes, pack_kv, quantize_kv)
    r = datasets._rng("kv-cache")
    k = jnp.asarray(r.standard_normal((2, 4, 1024, 128)).astype(np.float32))
    cfg = kv_quantizer_config()
    t0 = time.perf_counter()
    q = quantize_kv(k, cfg)
    jax.block_until_ready(q.bins)
    us = (time.perf_counter() - t0) * 1e6
    comp = (q.bins.size + q.eb2.size * 4 + q.out_idx.size * 4
            + q.out_val.size * 4 + q.overflow.size)
    y = dequantize_kv(q)
    err = float(jnp.max(jnp.abs(k - y)))
    _emit("kv.int8+outliers", us,
          f"{k.size * 4 / comp:.2f}x max_err={err:.4f}")
    p = pack_kv(q)
    assert p.nbytes() == kv_wire_bytes(k.shape)
    _emit("kv.packed_wire", 0.0,
          f"{k.size * 4 / p.nbytes():.2f}x vs f32 on the wire")


def gradwire():
    """Cross-pod gradient wire bytes: packed-words wire vs f32 psum.
    wire_bytes is the MEASURED footprint of CompressedShard (what the
    all-gather moves), not an estimate."""
    from repro.compression.grads import (CompressedShard,  # noqa: F401
                                         GradCompressionConfig, compress_shard,
                                         wire_bytes)
    cfg = GradCompressionConfig()
    n = 1 << 24
    shard, _ = compress_shard(jnp.zeros((n,), jnp.float32), cfg)
    assert shard.nbytes() == wire_bytes(n, cfg)
    _emit("gradwire.packed+outliers", 0.0,
          f"{n * 4 / wire_bytes(n, cfg):.2f}x less traffic")


def packedwire():
    """Packed vs unpacked codec pipeline and wire.

    Honest accounting: encode_compact already narrows bins to bin_bits
    DEVICE-side, so at bin_bits in {8, 16} the packed uint32 words are
    byte-parity with the narrowed bins on the wire (reported below as a
    check, ~1.0x).  What the fused pipeline buys instead:
      * pipeline HBM: the seed quantize kernel emitted int32 bins + bool
        outlier + f32 recon planes (9 B/elem) and narrowing was a separate
        XLA pass; fused quantize+pack emits words + bool (bb/8 + 1 B/elem)
        in ONE pass.
      * wire vs f32 psum: the headline gradient-compression ratio.
      * REL sign plane: 1 bit/value packed vs XLA's byte-wide bool (8x).
    Also times the jitted encode paths — the pack must ride under the same
    memory stream (pack/nopack ~ 1.0).
    """
    from repro.core import (decode_packed, encode_compact, encode_packed,
                            packed_word_count)
    r = datasets._rng("packed-wire")
    n = 1 << 22
    x = jnp.asarray((r.standard_normal(n) * 0.02).astype(np.float32))
    for bb in (8, 16):
        cfg = QuantizerConfig(mode="abs", error_bound=1e-4, bin_bits=bb,
                              outlier_cap_frac=1 / 64)
        k = cfg.outlier_cap(n)
        f_un = jax.jit(lambda v, c=cfg: encode_compact(v, c))
        f_pk = jax.jit(lambda v, c=cfg: encode_packed(v, c))
        f_rt = jax.jit(lambda v, c=cfg: decode_packed(encode_packed(v, c),
                                                      c, n=v.size))
        t_un = _time(f_un, x)
        t_pk = _time(f_pk, x)
        t_rt = _time(f_rt, x)
        seed_hbm = n * (4 + 1 + 4)                 # int32 + bool + f32 recon
        fused_hbm = n * bb // 8 + n                # packed words + bool
        compact_wire = n * bb // 8 + k * 8 + 4     # narrowed bins + table
        pk_bytes = packed_word_count(n, bb) * 4 + k * 8 + 8
        _emit(f"packedwire.abs.bb{bb}", t_pk * 1e6,
              f"pipeline_hbm {seed_hbm / fused_hbm:.2f}x less "
              f"wire {n * 4 / pk_bytes:.2f}x vs f32 "
              f"(parity vs narrowed-compact {compact_wire / pk_bytes:.2f}x) "
              f"enc={x.size * 4 / t_pk / 1e9:.2f}GB/s "
              f"pack/nopack={t_pk / t_un:.3f} roundtrip={t_rt * 1e6:.0f}us")
    cfg = QuantizerConfig(mode="rel", error_bound=1e-3, bin_bits=16,
                          outlier_cap_frac=1 / 8)
    k = cfg.outlier_cap(n)
    f_pk = jax.jit(lambda v: encode_packed(v, cfg))
    t_pk = _time(f_pk, x)
    pk_bytes = (packed_word_count(n, 16) * 4
                + packed_word_count(n, 1) * 4 + k * 8 + 8)
    unpacked_sign = n * 2 + n + k * 8 + 4          # int16 + byte-wide bool sign
    _emit("packedwire.rel.bb16", t_pk * 1e6,
          f"{n * 4 / pk_bytes:.2f}x vs f32, sign plane 8x (1bit vs bool: "
          f"wire {unpacked_sign / pk_bytes:.2f}x smaller) "
          f"enc={x.size * 4 / t_pk / 1e9:.2f}GB/s")


def _bench_pipeline_chain(spec: str, smoke: bool):
    """Bench one arbitrary pipeline chain (--pipeline): transmitted-wire
    ratio vs the packed-only prefix and vs f32, on the gradient suites
    plus the `iid` noise suite and the mixed-sign REL suite.  'auto' /
    'auto:SET' specs (DESIGN.md §11) run the adaptive selector — the
    per-suite chosen chain is emitted alongside the ratios."""
    from repro.core import select as SEL
    from repro.core.pipeline import Pipeline

    pipe = SEL.parse_chain(spec)
    pk_pipe = Pipeline(pipe.quant, pipe.pack)      # packed-only prefix
    cut = 1 << 18 if smoke else None
    suites = dict(datasets.GRAD_SUITES, iid=datasets.iid,
                  relmix=datasets.rel_mixed)
    for name, gen in suites.items():
        x = jnp.asarray(gen()[:cut])
        f = jax.jit(lambda v: pipe.encode(v))
        enc = f(x)
        t = _time(f, x, repeats=1 if smoke else 5)
        bits = float(pipe.wire_bits(enc, x.size))
        pk_bits = pk_pipe.wire_bits(pk_pipe.encode(x, kernels=False), x.size)
        chosen = ""
        if isinstance(pipe, SEL.Selector):
            chosen = f"chosen={pipe.chains[int(enc.chain_id)].spec()} "
        # honest accounting: overflow means the capped table could NOT
        # absorb the outliers — the bound is not met and a real caller
        # must take the lossless fallback; a ratio alone would hide that
        _emit(f"lossless.pipeline.{name}", t * 1e6,
              f"spec={pipe.spec()} {chosen}"
              f"vs_packed={pk_bits / bits:.2f}x "
              f"vs_f32={x.size * 32 / bits:.2f}x "
              f"overflow={bool(enc.overflow)} "
              f"outliers={float(enc.n_outliers) / x.size:.3f}")


def lossless(pipeline: str | None = None, smoke: bool = False):
    """Device-side lossless stages (DESIGN.md §6/§7): end-to-end
    transmitted-wire ratio of the pipeline's `Encoded` vs the packed-only
    wire and vs f32.

    Rows:
      * gradient wire (pack:16, eb = 2^-8 * rms): the realistic
        smooth/sparse gradients must beat the packed wire (zero chunks
        dominate dead rows); the adversarial dense gradient shows the ~1x
        floor — the stage never costs more than the small header plane.
      * scientific suites: NYX (non-negative, wide range) is where
        width-narrowing pays beyond zero suppression; CESM (dense smooth
        field) sits at the ~1x floor.
      * mixed-sign REL bins: narrow alone sits at its floor (sign
        extension sets the high bits of every word); the shuffle stage's
        zigzag fold + byte-plane shuffle is what unlocks the win.
      * closed-loop predictors (DESIGN.md §9): `delta` residual bins on
        the correlated gradient walk (gradwalk) and 2-D `lorenzo` on the
        NYX-like plane — each must beat its plain narrow|ent twin where
        neighbour correlation exists (iid data pays a few % vs ent:
        folded residuals of white noise are a touch wider than raw bins).
      * KV pages: a cache whose tail pages are unwritten (zeros).
      * Pallas parity: the pipeline's fused-kernel dispatch must be
        bit-identical to its jit reference in interpret mode.

    --pipeline SPEC replaces the fixed rows with the given chain.
    """
    from repro.compression.grads import (GradCompressionConfig,
                                         compress_shard, wire_bytes)
    from repro.compression.kv import kv_quantizer_config, pack_kv, quantize_kv
    from repro.core import parse_pipeline

    if pipeline is not None:
        _bench_pipeline_chain(pipeline, smoke)
        return

    cut = 1 << 18 if smoke else None      # --smoke: small data, 1 repeat
    reps = 1 if smoke else 5

    # the pred row (§9): closed-loop `delta` residuals on the bin plane
    # ahead of narrow|ent — must beat plain narrow|ent on the correlated
    # walk (gradwalk) and must not cost anything on the iid suites
    grad_chains = ("zero", "narrow", "narrow|ent", "delta|narrow|ent")
    for name, gen in datasets.GRAD_SUITES.items():
        g = jnp.asarray(gen()[:cut])
        n = g.size
        for stage in grad_chains:
            pred = "delta|" if stage.startswith("delta|") else ""
            word = stage.removeprefix("delta|")
            cfg = GradCompressionConfig(
                bin_bits=16,
                pipeline=f"{pred}abs:1.0:cap=0.015625|pack:16|{word}")
            f = jax.jit(lambda v, c=cfg: compress_shard(v, c)[0])
            shard = f(g)
            t = _time(f, g, repeats=reps)
            lc_b = float(shard.nbytes())
            pk_b = wire_bytes(n, cfg)
            _emit(f"lossless.{name}.{stage.replace('|', '+')}", t * 1e6,
                  f"vs_packed={pk_b / lc_b:.2f}x vs_f32={n * 4 / lc_b:.2f}x "
                  f"(packed_only {n * 4 / pk_b:.2f}x) "
                  f"enc={n * 4 / t / 1e9:.2f}GB/s")

    for name, eb, bb in (("NYX", 64.0, 32), ("CESM", 1e-3, 32)):
        x = jnp.asarray(datasets.SUITES[name]()[:cut])
        pk_pipe = parse_pipeline(f"abs:{eb!r}:cap=0.015625|pack:{bb}")
        pk_bits = pk_pipe.wire_bits(pk_pipe.encode(x, kernels=False), x.size)
        for chain in ("narrow", "narrow|ent"):
            pipe = parse_pipeline(
                f"abs:{eb!r}:cap=0.015625|pack:{bb}|{chain}")
            f = jax.jit(lambda v, p=pipe: p.encode(v))
            lc = f(x)
            t = _time(f, x, repeats=reps)
            lc_bits = float(pipe.wire_bits(lc, x.size))
            _emit(f"lossless.{name}.{chain.replace('|', '+')}", t * 1e6,
                  f"vs_packed={pk_bits / lc_bits:.2f}x "
                  f"vs_f32={x.size * 32 / lc_bits:.2f}x "
                  f"enc={x.size * 4 / t / 1e9:.2f}GB/s")

    # 2-D smooth plane (NYX-like slice): the `lorenzo` predictor's row
    # (§9) — the 2-D input's shape reaches the stage as pred_shape, so
    # residuals are second differences over the plane; must beat the
    # plain narrow|ent chain on the same data
    x2 = jnp.asarray(datasets.nyx_plane(512 if smoke else 1024))
    pk_pipe = parse_pipeline("abs:64.0:cap=0.015625|pack:32")
    pk_bits = pk_pipe.wire_bits(pk_pipe.encode(x2, kernels=False), x2.size)
    for chain in ("abs:64.0:cap=0.015625|pack:32|narrow|ent",
                  "lorenzo|abs:64.0:cap=0.015625|pack:32|narrow|ent"):
        pipe = parse_pipeline(chain)
        f = jax.jit(lambda v, p=pipe: p.encode(v))
        lc = f(x2)
        t = _time(f, x2, repeats=reps)
        lc_bits = float(pipe.wire_bits(lc, x2.size))
        label = "lorenzo+narrow+ent" if pipe.pred else "narrow+ent"
        _emit(f"lossless.nyxplane.{label}", t * 1e6,
              f"vs_packed={pk_bits / lc_bits:.2f}x "
              f"vs_f32={x2.size * 32 / lc_bits:.2f}x "
              f"enc={x2.size * 4 / t / 1e9:.2f}GB/s")

    # mixed-sign REL bins: the shuffle stage's reason to exist (§7), and
    # the entropy stage stacked on top of it
    x = jnp.asarray(datasets.rel_mixed()[:cut])
    pk_pipe = parse_pipeline("rel:0.001|pack:32")
    pk_bits = pk_pipe.wire_bits(pk_pipe.encode(x, kernels=False), x.size)
    for chain, label in (("narrow", "narrow"),
                         ("shuffle|narrow", "shuffle+narrow"),
                         ("shuffle|narrow|ent", "shuffle+narrow+ent")):
        pipe = parse_pipeline(f"rel:0.001|pack:32|{chain}")
        f = jax.jit(lambda v, p=pipe: p.encode(v))
        enc = f(x)
        t = _time(f, x, repeats=reps)
        bits = float(pipe.wire_bits(enc, x.size))
        _emit(f"lossless.relmix.{label}", t * 1e6,
              f"vs_packed={pk_bits / bits:.2f}x "
              f"vs_f32={x.size * 32 / bits:.2f}x "
              f"enc={x.size * 4 / t / 1e9:.2f}GB/s")

    # KV: tail pages unwritten (zeros) — the migration wire drops them,
    # and `ent` squeezes the written pages below narrow's byte floor
    r = datasets._rng("kv-tail-pages")
    cache = r.standard_normal((2, 4, 1024, 64)).astype(np.float32)
    cache[:, :, 600:, :] = 0.0
    q = quantize_kv(jnp.asarray(cache), kv_quantizer_config())
    pk = pack_kv(q)
    for stages in ("zero", "narrow|ent"):
        lc = pack_kv(q, stages=stages)
        _emit(f"lossless.kv.{stages.replace('|', '+')}", 0.0,
              f"vs_packed={pk.nbytes() / float(lc.wire_nbytes()):.2f}x "
              f"vs_f32={cache.nbytes / float(lc.wire_nbytes()):.2f}x")

    # Pallas fused dispatch vs jit reference: bit-identical in interpret
    x = jnp.asarray(datasets.GRAD_SUITES["gradsmooth"]()[:1 << 19])
    pipe = parse_pipeline("abs:1e-05:cap=0.015625|pack:16|narrow")
    ref = pipe.encode(x, kernels=False)
    ker = pipe.encode(x, kernels=True, interpret=True)
    same = all(
        (a is None and b is None) or (np.array_equal(np.asarray(a),
                                                     np.asarray(b))
                                      if not isinstance(a, tuple) else
                                      all(np.array_equal(np.asarray(p),
                                                         np.asarray(q_))
                                          for p, q_ in zip(a, b)))
        for a, b in zip(ref, ker))
    _emit("lossless.pallas_parity", 0.0,
          "bit-identical" if same else "MISMATCH")


def transfer(smoke: bool = False):
    """Prefill->decode KV transfer over the Transport layer (DESIGN.md
    §8): measured `PackedCache` wire bytes per stage chain — via the same
    `Transport.bytes_moved` accessor `models/serve.py` ships with — vs
    moving raw f32 pages, pack+unpack roundtrip time, and simulated
    transfer time / sustainable migration rate on a 100 Gb/s link.

    Two load points: a cache mid-decode (60% written — zero chunks drop
    the unwritten tail) and a fully written one (the stage floor).
    """
    from repro.compression.kv import kv_quantizer_config, quantize_kv
    from repro.core.transport import TRANSPORT
    from repro.models.serve import QuantCache, pack_cache, unpack_cache

    link_gbps = 100.0                       # simulated disaggregation link
    link_bps = link_gbps * 1e9 / 8
    # [L, B, G, S, hd] serving-cache shape (reduced-model scale on CPU)
    l_, b, g_, s, hd = (2, 2, 2, 512, 64) if smoke else (4, 4, 4, 2048, 64)
    reps = 1 if smoke else 3
    r = datasets._rng("serve-cache")
    kv_cfg = kv_quantizer_config()

    for load, written in (("midstream", 0.6), ("full", 1.0)):
        x = r.standard_normal((l_, b, g_, s, hd)).astype(np.float32)
        x[:, :, :, int(s * written):, :] = 0.0       # unwritten tail pages
        qk = quantize_kv(jnp.asarray(x), kv_cfg)
        qv = quantize_kv(jnp.asarray(x[..., ::-1]), kv_cfg)
        hot = jnp.zeros((l_, b, 128, g_, hd), jnp.float32)
        cache = QuantCache(qk, qv, hot, hot)
        raw_pages = 2 * qk.bins.size * 4 + 2 * hot.size * hot.dtype.itemsize

        for stages in ("", "zero", "narrow", "shuffle|narrow",
                       "narrow|ent", "kvdelta|narrow|ent"):
            f_pack = jax.jit(lambda c, st=stages: pack_cache(c, stages=st))
            f_rt = jax.jit(
                lambda c, st=stages: unpack_cache(pack_cache(c, stages=st)))
            wire = f_pack(cache)
            t = _time(f_rt, cache, repeats=reps)
            moved = float(TRANSPORT.bytes_moved(wire, op="send_pages"))
            ms = moved / link_bps * 1e3
            label = stages.replace("|", "+") if stages else "packed"
            _emit(f"transfer.{load}.{label}", t * 1e6,
                  f"wire={moved/2**20:.2f}MiB vs_raw_f32="
                  f"{raw_pages/moved:.2f}x link{link_gbps:g}Gbps="
                  f"{ms:.2f}ms sustainable={link_bps/moved:.1f}migr/s "
                  f"roundtrip={t*1e6:.0f}us")

    # transfer is exact: the unpacked cache must be bit-identical — both
    # for a word-only chain and for the §9 kvdelta page-predictor chain
    for st in ("shuffle|narrow", "kvdelta|zero|narrow"):
        back = unpack_cache(pack_cache(cache, stages=st))
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(cache),
                                   jax.tree.leaves(back)))
        _emit(f"transfer.roundtrip.{st.replace('|', '+')}", 0.0,
              "bit-identical" if same else "MISMATCH")


TABLES = {
    "table3": table3, "table4": table4, "table56": table56,
    "table7": table7, "table8": table8, "table9": table9,
    "ckpt": ckpt, "kv": kv, "gradwire": gradwire, "packedwire": packedwire,
    "lossless": lossless, "transfer": transfer,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("names", nargs="*", default=[],
                    help=f"tables to run (default: all of {list(TABLES)})")
    ap.add_argument("--pipeline", default=None, metavar="SPEC",
                    help="bench this pipeline chain in the `lossless` "
                         "table: a DESIGN.md §7 spec string or a "
                         "configs.registry preset name")
    ap.add_argument("--smoke", action="store_true",
                    help="small datasets / single repeats for the "
                         "`lossless` table (CI)")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)
    names = args.names or list(TABLES)
    unknown = [n for n in names if n not in TABLES]
    if unknown:
        ap.error(f"unknown table(s) {unknown}; have {list(TABLES)}")
    pipeline = args.pipeline
    if pipeline is not None:
        from repro.configs.registry import get_pipeline
        if pipeline == "auto" or pipeline.startswith("auto:"):
            pass              # §11 selector spec — resolved by the bench
        else:
            try:
                pipeline = get_pipeline(pipeline)
            except KeyError as e:
                ap.error(str(e))
        if args.names and args.names != ["lossless"]:
            ap.error("--pipeline applies to the `lossless` table only; "
                     f"drop {[n for n in args.names if n != 'lossless']} "
                     "or run them separately")
        names = ["lossless"]
    print("name,us_per_call,derived")
    for n in names:
        if n == "lossless":
            TABLES[n](pipeline=pipeline, smoke=args.smoke)
        elif n == "transfer":
            TABLES[n](smoke=args.smoke)
        else:
            TABLES[n]()


if __name__ == "__main__":
    main()
