"""Error-bounded KV-cache compression (paper technique applied to serving).

Each (batch, kv_head) cache is split into PAGES of `page` tokens;每 page is
ABS-quantized to int8 bins with a per-page bound eb = eb_rel * max|page|
(the paper's NOA normalization, §2.1.3, with R = page max).  The paper's
guarantee machinery carries over wholesale:

  * double-check + lossless outliers: values the int8 grid cannot represent
    within eb keep their EXACT f32 bits in a per-page (idx, value) side
    table, capped at `cap` slots.  Encoder zeroes outlier bins, so applying
    a correction is a pure ADD of the exact value — bit-exact restore
    without a gather of the reconstruction.
  * pow2-floored steps (FMA immunity) and FTZ screens via core.quantizer.
  * `overflow` flags any page whose outlier count exceeds the cap — the
    guarantee is surfaced, never silently dropped (runtime escalates to an
    uncompressed page).

Why the bound matters here: attention output error from K/V perturbation is
<= eb * (sum of attention weights) = eb per channel, so a guaranteed eb is
a guaranteed output perturbation bound — an UNbounded single outlier (e.g.
an attention-sink token) would be an unbounded output error.

Memory: int8 bins + f32 scale/page + cap*(idx+val) -> ~4x smaller than f32
KV at page=128, cap=8 (25.6% of bf16).

Two representations:

  * QuantizedKV — int8 bins [..., S, D]: the DECODE layout.  The Pallas
    attention kernel (kernels/kv_attention.py) streams these blocks
    directly; int8 lanes are what the VPU dequantizes cheapest.
  * PackedKV — the WIRE layout (DESIGN.md §4): per-page bins bit-packed
    into uint32 lanes via core.codec.pack_words.  This is what cache
    migration / prefill->decode disaggregation ships between hosts;
    pack_kv/unpack_kv round-trip bit-exactly, and `kv_wire_bytes` is the
    measured footprint of exactly those arrays.
  * PackedKVLC — PackedKV after the device-side lossless stage
    (DESIGN.md §6), coded per page so pages stay independently
    migratable.  Zero chunks dominate padded / unwritten cache regions
    and narrow chunks cut attention-sink-free pages; pack_kv_lc /
    unpack_kv_lc round-trip bit-exactly and `PackedKVLC.wire_nbytes()`
    is the measured (data-dependent) transmitted footprint.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig, codec
from repro.core.bitops import pow2_floor
from repro.core.quantizer import quantize_abs


class QuantizedKV(NamedTuple):
    bins: jnp.ndarray      # int8  [..., S, D]
    eb2: jnp.ndarray       # f32   [..., n_pages]  pow2 bin width per page
    out_idx: jnp.ndarray   # int32 [..., n_pages, cap]  flat idx in page, -1 empty
    out_val: jnp.ndarray   # f32   [..., n_pages, cap]  exact values
    overflow: jnp.ndarray  # bool  [..., n_pages]


def kv_quantizer_config(eb_rel: float = 2.0 ** -6) -> QuantizerConfig:
    # bin_bits=8 -> maxbin 127; eb_rel = 2^-6 keeps |bin| <= 64 by
    # construction so range outliers cannot occur for finite pages.
    return QuantizerConfig(mode="abs", error_bound=eb_rel, bin_bits=8)


def quantize_kv(x: jnp.ndarray, cfg: QuantizerConfig, *, page: int = 128,
                cap: int = 8) -> QuantizedKV:
    """x: [..., S, D] float32/bf16.  S % page == 0."""
    *lead, S, D = x.shape
    assert S % page == 0, (S, page)
    n_pages = S // page
    xf = x.astype(jnp.float32).reshape(*lead, n_pages, page * D)

    amax = jnp.max(jnp.abs(jnp.where(jnp.isfinite(xf), xf, 0.0)), axis=-1)
    eb = jnp.asarray(cfg.error_bound, jnp.float32) * amax    # per-page bound
    q = quantize_abs(xf, cfg, eb=eb[..., None])

    def _compact(outlier, vals):
        flat_out = outlier.reshape(-1, page * D)
        flat_val = vals.reshape(-1, page * D)

        def one(o, v):
            (idx,) = jnp.nonzero(o, size=cap, fill_value=-1)
            val = jnp.where(idx >= 0, v[jnp.maximum(idx, 0)], 0.0)
            return idx.astype(jnp.int32), val

        idx, val = jax.vmap(one)(flat_out, flat_val)
        shape = outlier.shape[:-1]
        return idx.reshape(*shape, cap), val.reshape(*shape, cap)

    out_idx, out_val = _compact(q.outlier, xf)
    n_out = jnp.sum(q.outlier, axis=-1)
    bins = q.bins.astype(jnp.int8).reshape(*lead, S, D)
    _, eb2_all, _ = _eb2(eb, cfg)
    return QuantizedKV(bins, eb2_all, out_idx, out_val, n_out > cap)


def _eb2(eb, cfg: QuantizerConfig):
    floor = jnp.float32(cfg.eb_floor)
    eb_ = jnp.maximum(eb.astype(jnp.float32), floor)
    eb2 = pow2_floor(2.0 * eb_)
    return eb_, eb2, 1.0 / eb2


def dequantize_kv(q: QuantizedKV, *, page: int = 128,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Reference decode (the Pallas attention kernel fuses this instead)."""
    *lead, S, D = q.bins.shape
    n_pages = S // page
    recon = (q.bins.astype(dtype).reshape(*lead, n_pages, page * D)
             * q.eb2[..., None].astype(dtype))
    flat_r = recon.reshape(-1, page * D)
    flat_i = q.out_idx.reshape(-1, q.out_idx.shape[-1])
    flat_v = q.out_val.reshape(-1, q.out_val.shape[-1])

    def one(r, i, v):
        # outlier bins were zeroed by the encoder -> add == exact restore
        return r.at[jnp.where(i >= 0, i, page * D)].add(
            v, mode="drop", indices_are_sorted=False)

    out = jax.vmap(one)(flat_r, flat_i, flat_v.astype(dtype))
    return out.reshape(*lead, S, D)


class PackedKV(NamedTuple):
    """Wire form of QuantizedKV: bins bit-packed 4/word into uint32 lanes.
    Everything here is what a cache transfer actually moves."""
    words: jnp.ndarray     # uint32 [..., n_pages, page*D // 4]
    eb2: jnp.ndarray       # f32   [..., n_pages]
    out_idx: jnp.ndarray   # int32 [..., n_pages, cap]
    out_val: jnp.ndarray   # f32   [..., n_pages, cap]
    overflow: jnp.ndarray  # bool  [..., n_pages]

    def nbytes(self) -> int:
        return (self.words.size * 4 + self.eb2.size * 4
                + self.out_idx.size * 4 + self.out_val.size * 4
                + self.overflow.size)


def pack_kv(q: QuantizedKV, *, page: int = 128) -> PackedKV:
    """Bit-pack a quantized cache for the wire.  Requires page*D % 512 == 0
    (whole uint32 tiles per page; page=128 needs D % 4 == 0)."""
    *lead, s, d = q.bins.shape
    n_pages = s // page
    per = page * d
    assert per % (4 * codec.PACK_LANES) == 0, (page, d)
    flat = q.bins.reshape(-1, per).astype(jnp.int32)
    words = jax.vmap(lambda b: codec.pack_words(b, 8))(flat)
    return PackedKV(words.reshape(*lead, n_pages, per // 4), q.eb2,
                    q.out_idx, q.out_val, q.overflow)


def unpack_kv(p: PackedKV, *, page: int = 128) -> QuantizedKV:
    """Inverse of pack_kv (bit-exact): restore the int8 decode layout."""
    *lead, n_pages, wpp = p.words.shape
    per = wpp * 4
    d = per // page
    flat = p.words.reshape(-1, wpp)
    bins = jax.vmap(lambda w: codec.unpack_words(w, per, 8))(flat)
    bins = bins.astype(jnp.int8).reshape(*lead, n_pages * page, d)
    return QuantizedKV(bins, p.eb2, p.out_idx, p.out_val, p.overflow)


class PackedKVLC(NamedTuple):
    """Wire form of PackedKV after the lossless stage, coded PER PAGE so
    any subset of pages can be shipped independently.  `payload` is padded
    to page capacity for XLA; the transmitted prefix per page is
    `payload_len` words and wire_nbytes() counts exactly those."""
    header_words: jnp.ndarray  # uint32 [..., n_pages, hw_per_page]
    payload: jnp.ndarray       # uint32 [..., n_pages, page*D // 4]
    payload_len: jnp.ndarray   # int32  [..., n_pages]
    eb2: jnp.ndarray           # f32   [..., n_pages]
    out_idx: jnp.ndarray       # int32 [..., n_pages, cap]
    out_val: jnp.ndarray       # f32   [..., n_pages, cap]
    overflow: jnp.ndarray      # bool  [..., n_pages]

    def wire_nbytes(self):
        """Measured transmitted footprint (traced: payload is variable-
        length; +4/page for the transmitted length itself).  Per page the
        header costs its content words only — ceil(n_chunks/16) uint32,
        4 B at page=128/D=64 — not the tile-padded stored plane (zeros the
        receiver re-pads); f32 accumulation, see EncodedLC.wire_bits."""
        n_chunks = self.payload.shape[-1] // codec.LC_CHUNK
        n_pages = self.payload_len.size
        return (n_pages * (codec.lc_header_content_words(n_chunks) * 4 + 4)
                + 4.0 * jnp.sum(self.payload_len.astype(jnp.float32))
                + self.eb2.size * 4 + self.out_idx.size * 4
                + self.out_val.size * 4 + self.overflow.size)


def pack_kv_lc(q: QuantizedKV, *, page: int = 128,
               stage: str = "narrow") -> PackedKVLC:
    """pack_kv + the device-side lossless stage over each page's words.
    Requires whole LC chunks per page — page*D % (4*LC_CHUNK) == 0, i.e.
    D % 16 == 0 at page=128 — so the per-page payload capacity equals the
    page's word count and pages stay self-describing."""
    p = pack_kv(q, page=page)
    *lead, n_pages, wpp = p.words.shape
    assert wpp % codec.LC_CHUNK == 0, (page, wpp)
    flat = p.words.reshape(-1, wpp)
    hw, payload, plen = jax.vmap(
        lambda w: codec.encode_words_lc(w, stage))(flat)
    return PackedKVLC(hw.reshape(*lead, n_pages, -1),
                      payload.reshape(*lead, n_pages, -1),
                      plen.reshape(*lead, n_pages), p.eb2, p.out_idx,
                      p.out_val, p.overflow)


def unpack_kv_lc(p: PackedKVLC, *, page: int = 128) -> QuantizedKV:
    """Inverse of pack_kv_lc (bit-exact)."""
    *lead, n_pages, cap_words = p.payload.shape
    hw = p.header_words.reshape(-1, p.header_words.shape[-1])
    pay = p.payload.reshape(-1, cap_words)
    words = jax.vmap(
        lambda h, w: codec.decode_words_lc(h, w, cap_words))(hw, pay)
    packed = PackedKV(words.reshape(*lead, n_pages, cap_words), p.eb2,
                      p.out_idx, p.out_val, p.overflow)
    return unpack_kv(packed, page=page)


def gather_kv_packed(p: PackedKV, axis: str) -> PackedKV:
    """All-gather a packed cache over a mesh axis (prefill->decode
    disaggregation: every decode host receives every prefill shard's pages
    in wire form).  Call inside shard_map; leading axis of every field
    becomes the axis size."""
    g = lambda a: jax.lax.all_gather(a, axis)
    return PackedKV(g(p.words), g(p.eb2), g(p.out_idx), g(p.out_val),
                    g(p.overflow))


def gather_kv_packed_lc(p: PackedKVLC, axis: str) -> PackedKVLC:
    """gather_kv_packed for the lossless-coded wire form.  The padded
    payload plane is gathered for shape-static XLA; the honest transfer
    size is wire_nbytes() (see the grads.py note on length transmission)."""
    g = lambda a: jax.lax.all_gather(a, axis)
    return PackedKVLC(g(p.header_words), g(p.payload), g(p.payload_len),
                      g(p.eb2), g(p.out_idx), g(p.out_val), g(p.overflow))


def kv_wire_bytes(shape, *, page: int = 128, cap: int = 8) -> int:
    """Analytic wire footprint of pack_kv for a cache of `shape`
    [..., S, D] — matches PackedKV.nbytes() exactly."""
    *lead, s, d = shape
    import math
    n_lead = math.prod(lead) if lead else 1
    n_pages = s // page
    return n_lead * n_pages * ((page * d // 4) * 4 + 4 + cap * 8 + 1)


def kv_error_bound_holds(x, q: QuantizedKV, cfg: QuantizerConfig, *,
                         page: int = 128) -> jnp.ndarray:
    """Debug/test helper: True iff every non-overflow page meets its bound."""
    y = dequantize_kv(q, page=page)
    *lead, S, D = x.shape
    n_pages = S // page
    xf = x.astype(jnp.float32).reshape(*lead, n_pages, page * D)
    yf = y.reshape(*lead, n_pages, page * D)
    amax = jnp.max(jnp.abs(jnp.where(jnp.isfinite(xf), xf, 0.0)), axis=-1)
    eb = cfg.error_bound * amax
    err = jnp.max(jnp.abs(xf - yf), axis=-1)
    ok = (err <= eb) | q.overflow
    return jnp.all(ok)
