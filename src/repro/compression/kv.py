"""Error-bounded KV-cache compression (paper technique applied to serving).

Each (batch, kv_head) cache is split into PAGES of `page` tokens;每 page is
ABS-quantized to int8 bins with a per-page bound eb = eb_rel * max|page|
(the paper's NOA normalization, §2.1.3, with R = page max).  The paper's
guarantee machinery carries over wholesale:

  * double-check + lossless outliers: values the int8 grid cannot represent
    within eb keep their EXACT f32 bits in a per-page (idx, value) side
    table, capped at `cap` slots.  Encoder zeroes outlier bins, so applying
    a correction is a pure ADD of the exact value — bit-exact restore
    without a gather of the reconstruction.
  * pow2-floored steps (FMA immunity) and FTZ screens via core.quantizer.
  * `overflow` flags any page whose outlier count exceeds the cap — the
    guarantee is surfaced, never silently dropped (runtime escalates to an
    uncompressed page).

Why the bound matters here: attention output error from K/V perturbation is
<= eb * (sum of attention weights) = eb per channel, so a guaranteed eb is
a guaranteed output perturbation bound — an UNbounded single outlier (e.g.
an attention-sink token) would be an unbounded output error.

Memory: int8 bins + f32 scale/page + cap*(idx+val) -> ~4x smaller than f32
KV at page=128, cap=8 (25.6% of bf16).

Two representations:

  * QuantizedKV — int8 bins [..., S, D]: the DECODE layout.  The Pallas
    attention kernel (kernels/kv_attention.py) streams these blocks
    directly; int8 lanes are what the VPU dequantizes cheapest.
  * PackedKV — the ONE wire layout (DESIGN.md §4/§7/§9): per-page bins
    bit-packed into uint32 lanes via core.codec.pack_words, optionally
    run through a per-page stage chain in the two-domain grammar —
    leading pred stages (`stages="kvdelta|zero|narrow"`: previous-token
    delta on the page's bin plane, closed-loop per DESIGN.md §9) and any
    chain of pipeline word stages (`stages="narrow"`,
    `stages="shuffle|narrow"`, `stages="narrow|ent"`, ...) coded PER
    PAGE so pages stay independently migratable (each page carries its
    own stage headers, including `ent`'s per-page codebook; `kvdelta`
    never predicts across a page boundary).  This is what cache
    migration / prefill->decode disaggregation ships between hosts — via
    the Transport layer (core.transport, DESIGN.md §8):
    `gather_kv_packed` is `Transport.all_gather` on the wire and
    `models/serve.py::transfer_cache` moves it point-to-point with
    `Transport.send_pages`.  pack_kv/unpack_kv round-trip bit-exactly
    for every stage chain.  Zero chunks dominate padded / unwritten
    cache regions and narrow chunks cut attention-sink-free pages;
    `nbytes()` is the static stage-free footprint and `wire_nbytes()`
    the measured (data-dependent) transmitted one, routed through the
    single accounting accessor `transport.wire_bytes`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig, codec
from repro.core import audit as audit_mod
from repro.core import predict as predict
from repro.core import select as select_mod
from repro.core.bitops import pow2_floor
from repro.core.pipeline import parse_word_stages
from repro.core.quantizer import quantize_abs
from repro.core.transport import TRANSPORT, wire_bytes as _wire_bytes


class QuantizedKV(NamedTuple):
    bins: jnp.ndarray      # int8  [..., S, D]
    eb2: jnp.ndarray       # f32   [..., n_pages]  pow2 bin width per page
    out_idx: jnp.ndarray   # int32 [..., n_pages, cap]  flat idx in page, -1 empty
    out_val: jnp.ndarray   # f32   [..., n_pages, cap]  exact values
    overflow: jnp.ndarray  # bool  [..., n_pages]


def kv_quantizer_config(eb_rel: float = 2.0 ** -6) -> QuantizerConfig:
    # bin_bits=8 -> maxbin 127; eb_rel = 2^-6 keeps |bin| <= 64 by
    # construction so range outliers cannot occur for finite pages.
    return QuantizerConfig(mode="abs", error_bound=eb_rel, bin_bits=8)


def quantize_kv(x: jnp.ndarray, cfg: QuantizerConfig, *, page: int = 128,
                cap: int = 8) -> QuantizedKV:
    """x: [..., S, D] float32/bf16.  S % page == 0."""
    *lead, S, D = x.shape
    assert S % page == 0, (S, page)
    n_pages = S // page
    xf = x.astype(jnp.float32).reshape(*lead, n_pages, page * D)

    amax = jnp.max(jnp.abs(jnp.where(jnp.isfinite(xf), xf, 0.0)), axis=-1)
    eb = jnp.asarray(cfg.error_bound, jnp.float32) * amax    # per-page bound
    q = quantize_abs(xf, cfg, eb=eb[..., None])

    def _compact(outlier, vals):
        flat_out = outlier.reshape(-1, page * D)
        flat_val = vals.reshape(-1, page * D)

        def one(o, v):
            (idx,) = jnp.nonzero(o, size=cap, fill_value=-1)
            val = jnp.where(idx >= 0, v[jnp.maximum(idx, 0)], 0.0)
            return idx.astype(jnp.int32), val

        idx, val = jax.vmap(one)(flat_out, flat_val)
        shape = outlier.shape[:-1]
        return idx.reshape(*shape, cap), val.reshape(*shape, cap)

    out_idx, out_val = _compact(q.outlier, xf)
    n_out = jnp.sum(q.outlier, axis=-1)
    bins = q.bins.astype(jnp.int8).reshape(*lead, S, D)
    _, eb2_all, _ = _eb2(eb, cfg)
    return QuantizedKV(bins, eb2_all, out_idx, out_val, n_out > cap)


def _eb2(eb, cfg: QuantizerConfig):
    floor = jnp.float32(cfg.eb_floor)
    eb_ = jnp.maximum(eb.astype(jnp.float32), floor)
    eb2 = pow2_floor(2.0 * eb_)
    return eb_, eb2, 1.0 / eb2


def dequantize_kv(q: QuantizedKV, *, page: int = 128,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Reference decode (the Pallas attention kernel fuses this instead)."""
    *lead, S, D = q.bins.shape
    n_pages = S // page
    recon = (q.bins.astype(dtype).reshape(*lead, n_pages, page * D)
             * q.eb2[..., None].astype(dtype))
    flat_r = recon.reshape(-1, page * D)
    flat_i = q.out_idx.reshape(-1, q.out_idx.shape[-1])
    flat_v = q.out_val.reshape(-1, q.out_val.shape[-1])

    def one(r, i, v):
        # outlier bins were zeroed by the encoder -> add == exact restore
        return r.at[jnp.where(i >= 0, i, page * D)].add(
            v, mode="drop", indices_are_sorted=False)

    out = jax.vmap(one)(flat_r, flat_i, flat_v.astype(dtype))
    return out.reshape(*lead, S, D)


def _word_stages(stages) -> tuple:
    """Resolve a word-stage chain given as a spec fragment ("narrow",
    "shuffle|narrow", "zero") or a tuple of stage objects — the shared
    pipeline parser.  KV pages pack at 8 bits/value, so bare `shuffle`
    folds at width 8."""
    return parse_word_stages(stages, 8)


def _page_stages(stages):
    """Split a per-page stage chain into (pred, word) tuples — the
    two-domain grammar (DESIGN.md §9) applied to page fragments: leading
    tokens naming registered pred stages ("kvdelta|zero|narrow") form the
    value-domain chain applied to each page's bin plane; the rest are
    word stages.  Tuples split on the stage contract (anything with
    `encode_bins` leads)."""
    if isinstance(stages, tuple):
        pred = []
        while stages and hasattr(stages[0], "encode_bins"):
            pred.append(stages[0])
            stages = stages[1:]
        return tuple(pred), _word_stages(stages)
    parts = [p.strip() for p in str(stages).split("|") if p.strip()]
    npred = 0
    while (npred < len(parts)
           and parts[npred].split(":")[0] in predict.PRED_STAGES):
        npred += 1
    return (predict.parse_pred_stages("|".join(parts[:npred])),
            _word_stages("|".join(parts[npred:])))


@jax.tree_util.register_pytree_node_class
class PackedKV:
    """The ONE wire form of QuantizedKV: per-page packed words, run
    through a (possibly empty, static) word-stage chain.  Everything in
    the arrays is what a cache transfer actually moves; `payload` is
    padded to the static per-page capacity when a stage is
    length-variable and the transmitted prefix per page is
    `payload_len`."""

    def __init__(self, payload, payload_len, headers, eb2, out_idx,
                 out_val, overflow, *, stages=(), pred=(), select=None,
                 chain_id=None, checksum=None):
        self.payload = payload        # uint32 [..., n_pages, cap_words]
        self.payload_len = payload_len  # int32 [..., n_pages]
        self.headers = headers        # tuple of uint32 [..., n_pages, hw]
        self.eb2 = eb2                # f32   [..., n_pages]
        self.out_idx = out_idx        # int32 [..., n_pages, cap]
        self.out_val = out_val        # f32   [..., n_pages, cap]
        self.overflow = overflow      # bool  [..., n_pages]
        self.stages = stages          # word-domain chain (per page)
        self.pred = pred              # value-domain chain (per page, §9)
        self.select = select          # KVSelector for per-page choice (§11)
        self.chain_id = chain_id      # int32 [..., n_pages] when selected
        self.checksum = checksum      # uint32 scalar (§12, integrity=True)

    def tree_flatten(self):
        children = (self.payload, self.payload_len, self.headers, self.eb2,
                    self.out_idx, self.out_val, self.overflow)
        if self.select is not None:
            children = children + (self.chain_id,)
        if self.checksum is not None:
            children = children + (self.checksum,)
        return children, (self.stages, self.pred, self.select,
                          self.checksum is not None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        stages, pred, select, has_checksum = aux
        checksum = None
        if has_checksum:
            *children, checksum = children
        chain_id = None
        if select is not None:
            *children, chain_id = children
        return cls(*children, stages=stages, pred=pred, select=select,
                   chain_id=chain_id, checksum=checksum)

    def with_checksum(self, checksum):
        """Same wire, with the §12 integrity digest carried as aux (the
        covered planes are untouched — see `core.audit`)."""
        return PackedKV(self.payload, self.payload_len, self.headers,
                        self.eb2, self.out_idx, self.out_val, self.overflow,
                        stages=self.stages, pred=self.pred,
                        select=self.select, chain_id=self.chain_id,
                        checksum=checksum)

    # --- legacy field views ------------------------------------------------
    @property
    def words(self):
        return self.payload

    @property
    def header_words(self):
        """The first non-empty stage header plane (the chunk coder's
        width codes)."""
        for h in self.headers:
            if h.shape[-1]:
                return h
        raise AttributeError("this PackedKV has no header planes")

    # --- accounting --------------------------------------------------------
    def nbytes(self) -> int:
        """Static stored footprint: for a stage-free chain this IS the
        wire (legacy PackedKV accounting); with stages it is the padded
        capacity an all-gather buffer holds."""
        b = (self.payload.size + self.eb2.size + self.out_idx.size
             + self.out_val.size) * 4 + self.overflow.size
        b += sum(h.size for h in self.headers) * 4
        if self.stages:
            b += self.payload_len.size * 4
        if self.select is not None:
            b += self.payload_len.size * 4 + self.chain_id.size * 4
        if self.checksum is not None:
            b += 4                    # §12 integrity digest
        return b

    def wire_nbytes(self):
        """Measured transmitted footprint (traced when a stage is
        length-variable; +4/page for the transmitted length itself).
        Routed through the single accounting accessor
        `core.transport.wire_bytes` (DESIGN.md §8) so reported and
        shipped bytes cannot drift."""
        return _wire_bytes(self)


def pack_kv(q: QuantizedKV, *, page: int = 128, stages=(),
            integrity: bool = False) -> PackedKV:
    """Bit-pack a quantized cache for the wire, optionally through a
    per-page stage chain (stages="narrow", "shuffle|narrow",
    "kvdelta|zero|narrow", ...).  Leading pred stages (DESIGN.md §9 —
    `kvdelta` is the shipped one) transform each page's (page, D) bin
    plane closed-loop before packing: token 0 is unpredicted, so a page
    never references another page and migrated pages decode bit-exactly
    on the receiving host.  Requires page*D % 512 == 0 (whole uint32
    tiles per page; page=128 needs D % 4 == 0), and each word stage must
    preserve the page word count (whole LC chunks per page — D % 16 == 0
    at page=128 for zero/narrow) so pages stay self-describing.

    stages='auto' / 'auto:SET' (DESIGN.md §11) selects the fragment PER
    PAGE from a registered `SELECTOR_SETS` candidate set at page close;
    each page transmits a 1-byte chain id next to its length, so every
    page remains independently migratable and self-describing.

    `integrity=True` attaches the §12 wire checksum (one digest over the
    whole wire, carried as aux — the transmitted planes are unchanged);
    `unpack_kv(..., verify=True)` and `Transport.send_pages(...,
    verify=)` re-check it on receive."""
    from repro.core.pipeline import encode_word_stages, word_stage_sizes

    if select_mod.is_auto_spec(stages) or isinstance(stages,
                                                     select_mod.KVSelector):
        sel = (stages if isinstance(stages, select_mod.KVSelector)
               else select_mod.parse_kv_selector(stages))
        p = _pack_kv_select(q, sel, page=page)
        return audit_mod.attach_checksum(p) if integrity else p
    pred, st = _page_stages(stages)
    *lead, s, d = q.bins.shape
    n_pages = s // page
    per = page * d
    assert per % (4 * codec.PACK_LANES) == 0, (page, d)
    flat = q.bins.reshape(-1, per).astype(jnp.int32)
    if pred:
        flat = jax.vmap(lambda b: predict.encode_pred_stages(
            pred, b, (page, d), 8))(flat)
    words = jax.vmap(lambda b: codec.pack_words(b, 8))(flat)
    wpp = per // 4
    if not st:
        plen = jnp.full((*lead, n_pages), wpp, jnp.int32)
        p = PackedKV(words.reshape(*lead, n_pages, wpp), plen, (),
                     q.eb2, q.out_idx, q.out_val, q.overflow, pred=pred)
        return audit_mod.attach_checksum(p) if integrity else p
    sizes = word_stage_sizes(st, wpp)
    assert all(sz == wpp for sz in sizes), (
        "stage chain must preserve the per-page word count so pages stay "
        "self-describing", page, d, sizes)
    headers, payload, plen = jax.vmap(
        lambda w: encode_word_stages(st, w, wpp))(words)
    # explicit last dim: headerless stages carry shape (0,) planes
    headers = tuple(h.reshape(*lead, n_pages, h.shape[-1]) for h in headers)
    p = PackedKV(payload.reshape(*lead, n_pages, -1),
                 plen.reshape(*lead, n_pages), headers, q.eb2,
                 q.out_idx, q.out_val, q.overflow, stages=st, pred=pred)
    return audit_mod.attach_checksum(p) if integrity else p


def _pack_kv_select(q: QuantizedKV, sel, *, page: int = 128) -> PackedKV:
    """Per-page adaptive packing (DESIGN.md §11): score each page's bin
    plane with the §11 statistics, `lax.switch` into the chosen
    fragment's own encoder, and transmit the chain id per page.  Every
    fragment preserves the per-page word count (validated), so the wire
    stays page-migratable like any static chain."""
    *lead, s, d = q.bins.shape
    n_pages = s // page
    per = page * d
    assert per % (4 * codec.PACK_LANES) == 0, (page, d)
    wpp = per // 4
    sel.validate_page(wpp)
    hw = sel.header_capacity_words(wpp)
    flat = q.bins.reshape(-1, per).astype(jnp.int32)
    branches = [
        (lambda b, i=i: sel.encode_page(i, b, (page, d), 8, wpp))
        for i in range(len(sel.chains))]

    def one(bins):
        cid = sel.page_select(bins, (page, d), 8, wpp)
        hdr, pay, plen = jax.lax.switch(cid, branches, bins)
        return cid, hdr, pay, plen

    cid, hdr, pay, plen = jax.vmap(one)(flat)
    return PackedKV(pay.reshape(*lead, n_pages, wpp),
                    plen.reshape(*lead, n_pages),
                    (hdr.reshape(*lead, n_pages, hw),),
                    q.eb2, q.out_idx, q.out_val, q.overflow,
                    select=sel, chain_id=cid.reshape(*lead, n_pages))


def unpack_kv(p: PackedKV, *, page: int = 128,
              verify: bool = False) -> QuantizedKV:
    """Inverse of pack_kv (bit-exact for every stage chain): restore the
    int8 decode layout.  Selected wires (§11) dispatch per page on the
    transmitted chain id.

    §12 guards: per-page transmitted lengths outside [0, words-per-page]
    raise `audit.WireIntegrityError` host-side (traced lengths are
    clamped inside the codec's gathers); `verify=True` re-checks the
    carried checksum (host-side — requires pack_kv(integrity=True))."""
    from repro.core.pipeline import decode_word_stages

    *lead, n_pages, wpp = p.payload.shape
    audit_mod.check_payload_len(p.payload_len, wpp, what="PackedKV")
    if verify:
        ok = audit_mod.verify_wire(p)
        if not isinstance(ok, jax.core.Tracer) and not bool(ok):
            raise audit_mod.WireIntegrityError(
                "PackedKV: checksum mismatch on unpack")
    if p.select is not None:
        per = wpp * 4
        d = per // page
        sel = p.select
        hdr = p.headers[0].reshape(-1, p.headers[0].shape[-1])
        pay = p.payload.reshape(-1, wpp)
        cid = p.chain_id.reshape(-1)
        branches = [
            (lambda h, w, i=i: sel.decode_page(i, h, w, (page, d), 8, wpp))
            for i in range(len(sel.chains))]
        bins = jax.vmap(
            lambda c, h, w: jax.lax.switch(c, branches, h, w))(cid, hdr, pay)
        bins = bins.astype(jnp.int8).reshape(*lead, n_pages * page, d)
        return QuantizedKV(bins, p.eb2, p.out_idx, p.out_val, p.overflow)
    if p.stages:
        batch = p.payload.size // wpp
        hdrs = tuple(h.reshape(batch, h.shape[-1]) for h in p.headers)
        pay = p.payload.reshape(-1, wpp)
        words = jax.vmap(
            lambda hs, w: decode_word_stages(p.stages, hs, w, wpp))(
                hdrs, pay)
    else:
        words = p.payload.reshape(-1, wpp)
    per = wpp * 4
    d = per // page
    bins = jax.vmap(lambda w: codec.unpack_words(w, per, 8))(
        words.reshape(-1, wpp))
    if p.pred:
        # decode-side prediction (§9): integrate each page's residual
        # codes back into bins — page-local, so this is exact wherever
        # the page landed (migration never splits a page)
        bins = jax.vmap(lambda b: predict.decode_pred_stages(
            p.pred, b, (page, d), 8))(bins)
    bins = bins.astype(jnp.int8).reshape(*lead, n_pages * page, d)
    return QuantizedKV(bins, p.eb2, p.out_idx, p.out_val, p.overflow)


def slice_pages(q: QuantizedKV, start: int, count: int = 1, *,
                page: int = 128) -> QuantizedKV:
    """Whole-page slice [start, start+count) of a quantized cache — the
    unit of streaming migration (DESIGN.md §10).  Every page is
    self-describing (its own eb2 / outlier / overflow row), so a slice
    packs to a standalone `PackedKV` wire with `pack_kv` and decodes
    bit-exactly wherever `paste_pages` lands it."""
    s0 = start * page
    return QuantizedKV(
        q.bins[..., s0:s0 + count * page, :],
        q.eb2[..., start:start + count],
        q.out_idx[..., start:start + count, :],
        q.out_val[..., start:start + count, :],
        q.overflow[..., start:start + count])


def paste_pages(dst: QuantizedKV, src: QuantizedKV, start: int, *,
                page: int = 128) -> QuantizedKV:
    """Inverse of `slice_pages`: write a page slice into `dst` at page
    index `start` (bit-exact — pages never split, DESIGN.md §10)."""
    s0 = start * page
    n = src.eb2.shape[-1]
    assert src.bins.shape[-2] == n * page, (src.bins.shape, n, page)
    return QuantizedKV(
        dst.bins.at[..., s0:s0 + n * page, :].set(src.bins),
        dst.eb2.at[..., start:start + n].set(src.eb2),
        dst.out_idx.at[..., start:start + n, :].set(src.out_idx),
        dst.out_val.at[..., start:start + n, :].set(src.out_val),
        dst.overflow.at[..., start:start + n].set(src.overflow))


def gather_kv_packed(p: PackedKV, axis: str) -> PackedKV:
    """All-gather a packed cache over a mesh axis (prefill->decode
    disaggregation: every decode host receives every prefill shard's pages
    in wire form) — `Transport.all_gather` on the one wire form.  Call
    inside shard_map; leading axis of every array becomes the axis size.
    With word stages the padded payload plane is gathered for
    shape-static XLA; the honest transfer size is wire_nbytes() (see the
    grads.py note on length transmission)."""
    return TRANSPORT.all_gather(p, axis)


def kv_wire_bytes(shape, *, page: int = 128, cap: int = 8) -> int:
    """Analytic wire footprint of pack_kv for a cache of `shape`
    [..., S, D] — matches PackedKV.nbytes() exactly."""
    *lead, s, d = shape
    import math
    n_lead = math.prod(lead) if lead else 1
    n_pages = s // page
    return n_lead * n_pages * ((page * d // 4) * 4 + 4 + cap * 8 + 1)


def kv_error_bound_holds(x, q: QuantizedKV, cfg: QuantizerConfig, *,
                         page: int = 128) -> jnp.ndarray:
    """Debug/test helper: True iff every non-overflow page meets its bound."""
    y = dequantize_kv(q, page=page)
    *lead, S, D = x.shape
    n_pages = S // page
    xf = x.astype(jnp.float32).reshape(*lead, n_pages, page * D)
    yf = y.reshape(*lead, n_pages, page * D)
    amax = jnp.max(jnp.abs(jnp.where(jnp.isfinite(xf), xf, 0.0)), axis=-1)
    eb = cfg.error_bound * amax
    err = jnp.max(jnp.abs(xf - yf), axis=-1)
    ok = (err <= eb) | q.overflow
    return jnp.all(ok)
