"""Guaranteed-error-bounded gradient compression for the cross-pod
all-reduce — the paper's quantizer on the slowest wire in the system.

Design (DESIGN.md §2/§4/§5):
  * Within a pod, gradients reduce over the fast 'data'/'model' axes in
    full precision (GSPMD handles those — the links are wide).
  * Across pods, each pod quantizes its pod-local gradient with the ABS
    quantizer (per-tensor NOA-style bound eb = eb_rel * rms(g)) and ships
    the PACKED wire format: bin_bits-wide bins bit-packed into uint32
    lanes (core.codec.pack_words — same layout the fused Pallas pipeline
    in kernels/pack.py emits) plus the capped exact-outlier (idx, payload)
    table.  Peers unpack, dequantize, and average.  Nothing wider than the
    packed words crosses the collective — `wire_bytes` below is the real
    measured footprint, ~3.6x less traffic than an f32 psum at bin_bits=8
    with the 1/64 outlier cap (benchmarks/run.py gradwire).
  * LOSSLESS STAGE (DESIGN.md §6): with `lossless_stage` set to 'zero' or
    'narrow', the packed words are further coded by the chunked lossless
    scheme before the gather — all-zero chunks (the common case for
    gradients whose values sit inside the zero bin) are dropped and the
    rest stored at the minimal word width, exactly reversible, so the
    bound is untouched.  XLA's static shapes force the gathered payload
    to be padded to capacity; the honest footprint is the transmitted
    prefix (`payload_len`), which is what `lc_wire_bytes` measures and
    what a real transport (or a size-psum'd ragged gather) would move.
  * ERROR FEEDBACK: the residual g - shipped is carried to the next step,
    so the long-run update is unbiased.  The paper's guarantee bounds the
    per-step residual ELEMENTWISE: |e_i| <= eb (outliers ship exactly, so
    their residual is 0) — heuristic compressors cannot promise that, and
    it is what keeps the error-feedback buffer from drifting.
  * OVERFLOW: if the outlier cap is exceeded the compact encoding cannot
    honor the bound; a pmax-agreed flag flips that tensor to the lossless
    psum for the step (lax.cond) — the guarantee is never silently
    dropped (the paper's core discipline).

These functions use explicit collectives over the 'pod' axis and are
called INSIDE a shard_map set up by launch/train.py; 'data'/'model'
sharding stays with GSPMD.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig, codec
from repro.core.bitops import bits_to_float, float_to_bits
from repro.core.quantizer import dequantize_abs, quantize_abs


class GradCompressionConfig(NamedTuple):
    eb_rel: float = 2.0 ** -8       # bound relative to grad RMS
    bin_bits: int = 8
    outlier_cap_frac: float = 1 / 64
    enabled: bool = True
    lossless_stage: str = "none"    # 'none' | 'zero' | 'narrow' (§6)

    def qcfg(self) -> QuantizerConfig:
        return QuantizerConfig(mode="abs", error_bound=1.0,  # eb is traced
                               bin_bits=self.bin_bits,
                               outlier_cap_frac=self.outlier_cap_frac)


class CompressedShard(NamedTuple):
    """One pod's wire payload — exactly the arrays the all-gather moves."""
    words: jnp.ndarray       # uint32[n_words] packed bins
    out_idx: jnp.ndarray     # int32[K], n = empty
    out_payload: jnp.ndarray  # uint32[K] exact IEEE bits
    eb: jnp.ndarray          # f32 scalar per-tensor bound
    n_outliers: jnp.ndarray  # int32 scalar (header; not gathered)

    def nbytes(self) -> int:
        """Measured per-pod wire footprint of one all-gather."""
        return (self.words.size * 4 + self.out_idx.size * 4
                + self.out_payload.size * 4 + 4 + 4)


class CompressedShardLC(NamedTuple):
    """CompressedShard after the device-side lossless stage (DESIGN.md §6).
    `payload` is padded to static capacity; the transmitted prefix is
    `payload_len` words and `nbytes()` counts exactly that."""
    header_words: jnp.ndarray  # uint32 — 2-bit per-chunk width codes
    payload: jnp.ndarray       # uint32[capacity], tail zero
    payload_len: jnp.ndarray   # int32 scalar — words actually used
    out_idx: jnp.ndarray       # int32[K], n = empty
    out_payload: jnp.ndarray   # uint32[K] exact IEEE bits
    eb: jnp.ndarray            # f32 scalar per-tensor bound
    n_outliers: jnp.ndarray    # int32 scalar (header; not gathered)

    def nbytes(self):
        """Measured per-pod transmitted footprint (traced: the payload is
        variable-length; +4 for the transmitted length itself).  Header
        content words only, f32 accumulation — see EncodedLC.wire_bits."""
        n_chunks = self.payload.size // codec.LC_CHUNK
        return (4.0 * self.payload_len.astype(jnp.float32)
                + codec.lc_header_content_words(n_chunks) * 4 + 4
                + self.out_idx.size * 4 + self.out_payload.size * 4 + 4 + 4)

    def capacity_nbytes(self) -> int:
        """Static upper bound — what the padded all-gather buffer holds."""
        return (self.header_words.size * 4 + self.payload.size * 4 + 4
                + self.out_idx.size * 4 + self.out_payload.size * 4 + 4 + 4)


def compress_shard(g: jnp.ndarray, cfg: GradCompressionConfig):
    """Quantize + pack one pod-local gradient.  Returns (CompressedShard,
    Quantized) — the second carries outlier/recon planes that stay LOCAL
    (residual bookkeeping); only the shard's arrays go on the wire."""
    qc = cfg.qcfg()
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    k = max(1, int(n * cfg.outlier_cap_frac))
    rms = jnp.sqrt(jnp.mean(flat * flat))
    eb = jnp.asarray(cfg.eb_rel, jnp.float32) * rms

    q = quantize_abs(flat, qc, eb=eb)
    n_out = jnp.sum(q.outlier).astype(jnp.int32)
    (idx,) = jnp.nonzero(q.outlier, size=k, fill_value=n)
    payload = jnp.where(idx < n,
                        float_to_bits(flat)[jnp.minimum(idx, n - 1)], 0)
    words = codec.pack_words(q.bins, cfg.bin_bits)
    shard = CompressedShard(words, idx.astype(jnp.int32),
                            payload.astype(jnp.uint32), eb, n_out)
    return shard, q


def compress_shard_lc(g: jnp.ndarray, cfg: GradCompressionConfig):
    """compress_shard + the device-side lossless stage over the packed
    words.  Returns (CompressedShardLC, Quantized); decoding the shard's
    arrays reproduces the packed words bit-for-bit, so every guarantee of
    compress_shard carries over."""
    if cfg.lossless_stage not in codec.LC_STAGES:
        raise ValueError(
            f"compress_shard_lc needs lossless_stage in {codec.LC_STAGES}, "
            f"got {cfg.lossless_stage!r} (use compress_shard for 'none')")
    shard, q = compress_shard(g, cfg)
    hw, payload, plen = codec.encode_words_lc(shard.words, cfg.lossless_stage)
    return CompressedShardLC(hw, payload, plen, shard.out_idx,
                             shard.out_payload, shard.eb,
                             shard.n_outliers), q


def compressed_mean(g: jnp.ndarray, cfg: GradCompressionConfig, axis: str):
    """Compressed mean of g over the `axis` collective (call inside
    shard_map).  Returns (mean, residual) — residual is THIS shard's
    error-feedback term, elementwise bounded by eb."""
    qc = cfg.qcfg()
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    k = max(1, int(n * cfg.outlier_cap_frac))
    n_words = codec.packed_word_count(n, cfg.bin_bits)
    lossless = cfg.lossless_stage != "none"      # static (python) branch
    if lossless:
        shard, q = compress_shard_lc(g, cfg)
    else:
        shard, q = compress_shard(g, cfg)
    # all pods must take the same branch: agree by pmax
    any_overflow = jax.lax.pmax((shard.n_outliers > k).astype(jnp.int32),
                                axis) > 0
    p = jax.lax.psum(1, axis)        # axis size (jax.lax.axis_size compat)

    def dequant_one(w, e, ii, pp):
        bins = codec.unpack_words(w, n, cfg.bin_bits)
        vals = dequantize_abs(bins, qc, eb=e, dtype=jnp.float32)
        exact = bits_to_float(pp.astype(jnp.int32), jnp.float32)
        # mode='drop' discards empty slots (ii == n).  NEVER clamp them
        # to n-1: an outlier at the last index would be clobbered by
        # the empties' duplicate writes and decode as 0 — a silent
        # guarantee violation (the residual for outliers is 0, so
        # error feedback would not recover it either).
        return vals.at[ii].set(exact, mode="drop")

    def compressed_path(_):
        eb_all = jax.lax.all_gather(shard.eb, axis)
        idx_all = jax.lax.all_gather(shard.out_idx, axis)
        pay_all = jax.lax.all_gather(shard.out_payload, axis)
        if lossless:
            # the padded payload is gathered for shape-static XLA; the
            # transmitted size is shard.nbytes() (payload_len words)
            hw_all = jax.lax.all_gather(shard.header_words, axis)
            lcp_all = jax.lax.all_gather(shard.payload, axis)
            words_all = jax.vmap(
                lambda hw, pw: codec.decode_words_lc(hw, pw, n_words))(
                    hw_all, lcp_all)
        else:
            words_all = jax.lax.all_gather(shard.words, axis)  # uint32 wire

        return jnp.sum(jax.vmap(dequant_one)(words_all, eb_all, idx_all,
                                             pay_all), axis=0)

    def lossless_path(_):
        return jax.lax.psum(flat, axis)

    summed = jax.lax.cond(any_overflow, lossless_path, compressed_path, None)
    # residual: what we failed to ship (0 for outliers — they went exact;
    # 0 if the lossless path ran)
    shipped = jnp.where(q.outlier, flat, q.recon)
    resid = jnp.where(any_overflow, 0.0, flat - shipped)
    return (summed / p).reshape(g.shape), resid.reshape(g.shape)


def compressed_mean_tree(grads, residuals, cfg: GradCompressionConfig,
                         axis: str = "pod"):
    """Tree version with error feedback: grads_in + residuals are
    compressed-averaged; returns (mean_tree, new_residual_tree)."""
    leaves_g, tree = jax.tree.flatten(grads)
    leaves_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(leaves_g, leaves_r):
        m, nr = compressed_mean(g + r.astype(g.dtype), cfg, axis)
        out_g.append(m.astype(g.dtype))
        out_r.append(nr)
    return jax.tree.unflatten(tree, out_g), jax.tree.unflatten(tree, out_r)


def wire_bytes(n_elems: int, cfg: GradCompressionConfig) -> int:
    """PACKED wire footprint per pod per tensor — matches
    CompressedShard.nbytes() exactly (packed uint32 words + capped
    (idx, payload) table + header).  With a lossless stage the footprint
    becomes data-dependent and this is its upper bound (modulo the small
    header plane); use lc_wire_bytes for the measured size."""
    n_words = codec.packed_word_count(n_elems, cfg.bin_bits)
    k = max(1, int(n_elems * cfg.outlier_cap_frac))
    return n_words * 4 + k * 8 + 8


def lc_wire_bytes(shard: CompressedShardLC):
    """Measured transmitted footprint of one lossless-coded shard (traced
    scalar — the payload length is data-dependent).  The gathered buffer
    is padded to shard.capacity_nbytes(); a real transport moves this."""
    return shard.nbytes()
