"""Guaranteed-error-bounded gradient compression for the cross-pod
all-reduce — the paper's quantizer on the slowest wire in the system.

Design (DESIGN.md §2/§4/§5/§7/§8):
  * Within a pod, gradients reduce over the fast 'data'/'model' axes in
    full precision (GSPMD handles those — the links are wide).
  * Across pods, each pod quantizes its pod-local gradient through a
    compression PIPELINE (core.pipeline, DESIGN.md §7) — an ABS quantizer
    with a per-tensor NOA-style bound eb = eb_rel * rms(g), the §4
    bit-pack, and any chain of lossless word stages — into ONE `Encoded`
    wire container.  The TRANSPORT layer (core.transport, DESIGN.md §8)
    moves it: `Transport.reduce_sum` ring-reduces in the packed domain
    when every pod sits on the same pow2 grid with no outliers, and
    otherwise gathers the wires and sums the per-pod decodes —
    bit-identical either way.  Nothing wider than the final payload
    plane crosses the collective — `CompressedShard.nbytes()` is the
    real measured footprint (`benchmarks/run.py gradwire`/`lossless`),
    routed through the one `transport.wire_bytes` accessor.
  * LOSSLESS STAGES (DESIGN.md §6/§7): with word stages in the pipeline
    (e.g. "abs:1|pack:8|narrow", or "abs:1|pack:16|narrow|ent" to
    entropy-code the surviving chunk bytes — a spec silent about cap=
    inherits this config's outlier_cap_frac; an explicit cap= wins),
    the packed words are further coded before the gather — all-zero
    chunks dropped, the rest narrowed/entropy-coded, exactly
    reversible, so the bound is untouched.  XLA's
    static shapes force the gathered payload to be padded to capacity;
    the honest footprint is the transmitted prefix (`payload_len`),
    which is what `nbytes()` measures and what a real transport (or a
    size-psum'd ragged gather) would move.
  * ERROR FEEDBACK: the residual g - shipped is carried to the next step,
    so the long-run update is unbiased.  The paper's guarantee bounds the
    per-step residual ELEMENTWISE: |e_i| <= eb (outliers ship exactly, so
    their residual is 0) — heuristic compressors cannot promise that, and
    it is what keeps the error-feedback buffer from drifting.
  * OVERFLOW: if the outlier cap is exceeded the compact encoding cannot
    honor the bound; a pmax-agreed flag flips that tensor to the lossless
    psum for the step (lax.cond) — the guarantee is never silently
    dropped (the paper's core discipline).

These functions use explicit collectives over the 'pod' axis and are
called INSIDE a shard_map set up by launch/train.py; 'data'/'model'
sharding stays with GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import select as SEL
from repro.core.pipeline import (Encoded, Pipeline, PackStage, QuantStage,
                                 parse_pipeline)
from repro.core.transport import TRANSPORT, Transport, wire_bytes as _wire_bytes


class GradCompressionConfig(NamedTuple):
    eb_rel: float = 2.0 ** -8       # bound relative to grad RMS
    bin_bits: int = 8               # used when `pipeline` is empty
    outlier_cap_frac: float = 1 / 64
    enabled: bool = True
    pipeline: str = ""              # spec, e.g. "abs:1.0|pack:8|narrow" or
    #                                 "delta|abs:1.0|pack:16|narrow|ent";
    #                                 the quantizer eb is a placeholder
    #                                 (the traced per-tensor eb overrides)
    #                                 and a spec without cap= inherits
    #                                 outlier_cap_frac.  Pred-bearing
    #                                 specs (DESIGN.md §9) see the shard
    #                                 as one flat stream; their residual
    #                                 wires never ring-reduce, so
    #                                 reduce_sum takes the
    #                                 gather+dequantize branch (§8).
    #                                 'auto' / 'auto:SET' (DESIGN.md §11)
    #                                 resolves to a Selector: the chain
    #                                 is chosen PER SHARD at encode time
    #                                 from the set's candidates; selector
    #                                 wires also always gather.

    def pipe(self):
        """The compression pipeline this config describes (`Pipeline`,
        or a §11 `Selector` for 'auto' specs).  `pipeline` wins;
        otherwise a stage-free chain is built from eb_rel/bin_bits.
        The quantizer must be ABS: the wire's per-tensor bound
        eb_rel * rms(g) is an ABS bound, and the transport's
        gather/dequant moves exactly the ABS planes (no sign plane)."""
        if SEL.is_auto_spec(self.pipeline):
            sel = SEL.parse_selector(self.pipeline)
            if sel.quant.mode != "abs":
                raise ValueError(
                    f"the gradient wire needs an 'abs' quantizer stage; "
                    f"selector set {sel.name!r} has {sel.quant.mode!r}")
            from repro.configs.registry import SELECTOR_SETS
            if "cap=" not in SELECTOR_SETS[sel.name]["base"]:
                # like plain specs: a base silent about the outlier cap
                # inherits this config's; an explicit cap= wins
                sel = dataclasses.replace(sel, chains=tuple(
                    dataclasses.replace(p, quant=dataclasses.replace(
                        p.quant, cap=self.outlier_cap_frac))
                    for p in sel.chains))
            return sel
        if self.pipeline:
            pipe = parse_pipeline(self.pipeline)
            if pipe.quant.mode != "abs":
                raise ValueError(
                    f"the gradient wire needs an 'abs' quantizer stage "
                    f"(per-tensor eb = eb_rel * rms overrides the spec's "
                    f"bound); got {pipe.quant.mode!r} in {self.pipeline!r}")
            if "cap=" not in self.pipeline:
                # a spec that is silent about the outlier cap inherits
                # this config's; an explicit cap= in the spec wins
                pipe = dataclasses.replace(
                    pipe, quant=dataclasses.replace(
                        pipe.quant, cap=self.outlier_cap_frac))
            return pipe
        return Pipeline(QuantStage("abs", 1.0, self.outlier_cap_frac),
                        PackStage(self.bin_bits))

    def qcfg(self):
        return self.pipe().qcfg()


@jax.tree_util.register_pytree_node_class
class CompressedShard:
    """One pod's wire payload — an `Encoded` container plus its (static)
    pipeline and element count.  The arrays inside `enc` are exactly what
    the transport moves; the legacy field names (`words`, `header_words`,
    `payload`, ...) remain as read-only views."""

    def __init__(self, enc: Encoded, pipe: Pipeline, n: int):
        self.enc = enc
        self.pipe = pipe
        self.n = n

    def tree_flatten(self):
        return (self.enc,), (self.pipe, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # --- legacy field views ------------------------------------------------
    @property
    def words(self):
        """The §4 packed bin plane.  For a staged pipeline this decodes
        the word stages (exact inverses), so it is the same bit-identical
        plane a stage-free pipeline would ship — except under a pred
        chain (§9), where the plane holds the folded residual codes (the
        pred inverse lives bin-side, in `Pipeline.decode`)."""
        if self.pipe.stages:
            return self.pipe.decode_words(self.enc.headers,
                                          self.enc.payload,
                                          self.pipe.n_words(self.n))
        return self.enc.payload

    @property
    def header_words(self):
        """The first non-empty stage header plane (the chunk coder's
        width codes)."""
        for h in self.enc.headers:
            if h.size:
                return h
        raise AttributeError(
            f"pipeline {self.pipe.spec()!r} has no header planes")

    @property
    def payload(self):
        return self.enc.payload

    @property
    def payload_len(self):
        return self.enc.payload_len

    @property
    def out_idx(self):
        return self.enc.out_idx

    @property
    def out_payload(self):
        return self.enc.out_payload

    @property
    def eb(self):
        return self.enc.eb

    @property
    def n_outliers(self):
        return self.enc.n_outliers

    # --- accounting --------------------------------------------------------
    def nbytes(self):
        """Measured per-pod transmitted footprint of one all-gather: a
        static int for static chains, traced (data-dependent) with a
        length-variable lossless stage.  Routed through the single
        accounting accessor `core.transport.wire_bytes` (DESIGN.md §8)."""
        return _wire_bytes(self)

    def capacity_nbytes(self) -> int:
        """Static upper bound — what the padded all-gather buffer holds."""
        return self.pipe.capacity_bytes(self.enc)


def compress_shard(g: jnp.ndarray, cfg: GradCompressionConfig,
                   *, integrity: bool = False):
    """Run one pod-local gradient through the compression pipeline.
    Returns (CompressedShard, Quantized) — the second carries the local
    outlier/recon planes (residual bookkeeping); only the shard's arrays
    go on the wire.  `integrity=True` attaches the §12 wire checksum
    (an extra aux plane — the transmitted planes are unchanged)."""
    pipe = cfg.pipe()
    flat = g.reshape(-1).astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(flat * flat))
    eb = jnp.asarray(cfg.eb_rel, jnp.float32) * rms
    enc, q = pipe.encode(flat, eb=eb, return_quantized=True,
                         integrity=integrity)
    return CompressedShard(enc, pipe, flat.size), q


def compressed_mean(g: jnp.ndarray, cfg: GradCompressionConfig, axis: str,
                    *, transport: Transport | None = None,
                    integrity: str | None = None):
    """Compressed mean of g over the `axis` collective (call inside
    shard_map).  Returns (mean, residual) — residual is THIS shard's
    error-feedback term, elementwise bounded by eb.  All wire movement
    goes through the Transport layer (DESIGN.md §8); `transport=`
    overrides the default (e.g. Transport(reduce='gather') to pin the
    reference path).

    `integrity='drop'` (§12): every shard ships with its checksum, the
    reduce takes the gather path, and a shard whose received wire fails
    the check is DROPPED from the mean — the sum renormalizes by the
    count of shards that verified, so one corrupt wire degrades the
    mean's sample count instead of poisoning every parameter.  The
    residual contract is unchanged (it describes what THIS shard
    shipped; corruption is a transient fault, not a steady state).
    `integrity='raise'` is not expressible in-graph — decode-side raise
    policies live at the eager call sites (`Pipeline.decode(verify=)`,
    `Transport.all_gather(verify='raise')`)."""
    if integrity not in (None, "drop"):
        raise ValueError(f"integrity must be None or 'drop' in-graph, "
                         f"got {integrity!r} (DESIGN.md §12)")
    tp = TRANSPORT if transport is None else transport
    flat = g.reshape(-1).astype(jnp.float32)
    shard, q = compress_shard(g, cfg, integrity=integrity is not None)
    # all pods must take the same branch: agree by pmax
    any_overflow = jax.lax.pmax(shard.enc.overflow.astype(jnp.int32),
                                axis) > 0
    p = jax.lax.psum(1, axis)        # axis size (jax.lax.axis_size compat)

    if integrity == "drop":
        def _verified_mean(_):
            enc_all, ok = tp.all_gather(shard.enc, axis, verify="mask")
            dec = jax.vmap(lambda e: shard.pipe.decode(
                e, n=flat.size, kernels=False))(enc_all)
            w = ok.astype(jnp.float32)
            s = jnp.sum(dec * w[:, None], axis=0)
            return s / jnp.maximum(jnp.sum(w), 1.0)

        mean = jax.lax.cond(
            any_overflow,
            lambda _: jax.lax.psum(flat, axis) / p,
            _verified_mean, None)
    else:
        summed = jax.lax.cond(
            any_overflow,
            lambda _: jax.lax.psum(flat, axis),
            lambda _: tp.reduce_sum(shard.enc, shard.pipe, flat.size, axis),
            None)
        mean = summed / p
    # residual: what we failed to ship (0 for outliers — they went exact;
    # 0 if the lossless path ran)
    shipped = jnp.where(q.outlier, flat, q.recon)
    resid = jnp.where(any_overflow, 0.0, flat - shipped)
    return mean.reshape(g.shape), resid.reshape(g.shape)


def compressed_mean_tree(grads, residuals, cfg: GradCompressionConfig,
                         axis: str = "pod",
                         transport: Transport | None = None):
    """Tree version with error feedback: grads_in + residuals are
    compressed-averaged; returns (mean_tree, new_residual_tree)."""
    leaves_g, tree = jax.tree.flatten(grads)
    leaves_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(leaves_g, leaves_r):
        m, nr = compressed_mean(g + r.astype(g.dtype), cfg, axis,
                                transport=transport)
        out_g.append(m.astype(g.dtype))
        out_r.append(nr)
    return jax.tree.unflatten(tree, out_g), jax.tree.unflatten(tree, out_r)


def wire_bytes(n_elems: int, cfg: GradCompressionConfig) -> int:
    """Analytic PACKED wire footprint per pod per tensor — matches
    CompressedShard.nbytes() for a stage-free pipeline (packed uint32
    words + capped (idx, payload) table + header).  With lossless stages
    the footprint becomes data-dependent and this is its upper bound
    (modulo the small header planes); use shard.nbytes() for the
    measured size."""
    pipe = cfg.pipe()
    qc = pipe.qcfg()
    n_words = pipe.n_words(n_elems)
    k = qc.outlier_cap(n_elems)
    return n_words * 4 + k * 8 + 8
