"""Guaranteed-error-bounded gradient compression for the cross-pod
all-reduce — the paper's quantizer on the slowest wire in the system.

Design (DESIGN.md §2/§5):
  * Within a pod, gradients reduce over the fast 'data'/'model' axes in
    full precision (GSPMD handles those — the links are wide).
  * Across pods, each pod quantizes its pod-local gradient with the ABS
    quantizer (per-tensor NOA-style bound eb = eb_rel * rms(g)), ships
    int8 bins + the capped exact-outlier table, dequantizes the peers'
    payloads, and averages.  Wire traffic drops ~3.9x (int8 + sides) vs
    f32.
  * ERROR FEEDBACK: the residual g - shipped is carried to the next step,
    so the long-run update is unbiased.  The paper's guarantee bounds the
    per-step residual ELEMENTWISE: |e_i| <= eb (outliers ship exactly, so
    their residual is 0) — heuristic compressors cannot promise that, and
    it is what keeps the error-feedback buffer from drifting.
  * OVERFLOW: if the outlier cap is exceeded the compact encoding cannot
    honor the bound; a pmax-agreed flag flips that tensor to the lossless
    psum for the step (lax.cond) — the guarantee is never silently
    dropped (the paper's core discipline).

These functions use explicit collectives over the 'pod' axis and are
called INSIDE a partial-manual shard_map (axis_names={'pod'}) set up by
launch/train.py; 'data'/'model' sharding stays with GSPMD.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig
from repro.core.bitops import bits_to_float, float_to_bits
from repro.core.quantizer import dequantize_abs, quantize_abs


class GradCompressionConfig(NamedTuple):
    eb_rel: float = 2.0 ** -8       # bound relative to grad RMS
    bin_bits: int = 8
    outlier_cap_frac: float = 1 / 64
    enabled: bool = True

    def qcfg(self) -> QuantizerConfig:
        return QuantizerConfig(mode="abs", error_bound=1.0,  # eb is traced
                               bin_bits=self.bin_bits,
                               outlier_cap_frac=self.outlier_cap_frac)


_BIN_DT = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


def compressed_mean(g: jnp.ndarray, cfg: GradCompressionConfig, axis: str):
    """Compressed mean of g over the `axis` collective (call inside
    shard_map).  Returns (mean, residual) — residual is THIS shard's
    error-feedback term, elementwise bounded by eb."""
    qc = cfg.qcfg()
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    k = max(1, int(n * cfg.outlier_cap_frac))
    rms = jnp.sqrt(jnp.mean(flat * flat))
    eb = jnp.asarray(cfg.eb_rel, jnp.float32) * rms

    q = quantize_abs(flat, qc, eb=eb)
    n_out = jnp.sum(q.outlier).astype(jnp.int32)
    (idx,) = jnp.nonzero(q.outlier, size=k, fill_value=n)
    payload = jnp.where(idx < n,
                        float_to_bits(flat)[jnp.minimum(idx, n - 1)], 0)
    # all pods must take the same branch: agree by pmax
    any_overflow = jax.lax.pmax((n_out > k).astype(jnp.int32), axis) > 0
    p = jax.lax.axis_size(axis)

    def compressed_path(_):
        bins = q.bins.astype(_BIN_DT[cfg.bin_bits])
        bins_all = jax.lax.all_gather(bins, axis)            # int8 wire
        eb_all = jax.lax.all_gather(eb, axis)
        idx_all = jax.lax.all_gather(idx, axis)
        pay_all = jax.lax.all_gather(payload, axis)

        def dequant_one(b8, e, ii, pp):
            vals = dequantize_abs(b8.astype(jnp.int32), qc, eb=e,
                                  dtype=jnp.float32)
            exact = bits_to_float(pp, jnp.float32)
            safe = jnp.minimum(ii, n - 1)
            return vals.at[safe].set(jnp.where(ii < n, exact, vals[safe]))

        return jnp.sum(jax.vmap(dequant_one)(bins_all, eb_all, idx_all,
                                             pay_all), axis=0)

    def lossless_path(_):
        return jax.lax.psum(flat, axis)

    summed = jax.lax.cond(any_overflow, lossless_path, compressed_path, None)
    # residual: what we failed to ship (0 for outliers — they went exact;
    # 0 if the lossless path ran)
    shipped = jnp.where(q.outlier, flat, q.recon)
    resid = jnp.where(any_overflow, 0.0, flat - shipped)
    return (summed / p).reshape(g.shape), resid.reshape(g.shape)


def compressed_mean_tree(grads, residuals, cfg: GradCompressionConfig,
                         axis: str = "pod"):
    """Tree version with error feedback: grads_in + residuals are
    compressed-averaged; returns (mean_tree, new_residual_tree)."""
    leaves_g, tree = jax.tree.flatten(grads)
    leaves_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(leaves_g, leaves_r):
        m, nr = compressed_mean(g + r.astype(g.dtype), cfg, axis)
        out_g.append(m.astype(g.dtype))
        out_r.append(nr)
    return jax.tree.unflatten(tree, out_g), jax.tree.unflatten(tree, out_r)


def wire_bytes(n_elems: int, cfg: GradCompressionConfig) -> int:
    """Analytic wire footprint per pod per tensor (for EXPERIMENTS.md)."""
    k = max(1, int(n_elems * cfg.outlier_cap_frac))
    return n_elems * cfg.bin_bits // 8 + k * 8 + 4
