"""Guaranteed-error-bounded gradient compression for the cross-pod
all-reduce — the paper's quantizer on the slowest wire in the system.

Design (DESIGN.md §2/§4/§5):
  * Within a pod, gradients reduce over the fast 'data'/'model' axes in
    full precision (GSPMD handles those — the links are wide).
  * Across pods, each pod quantizes its pod-local gradient with the ABS
    quantizer (per-tensor NOA-style bound eb = eb_rel * rms(g)) and ships
    the PACKED wire format: bin_bits-wide bins bit-packed into uint32
    lanes (core.codec.pack_words — same layout the fused Pallas pipeline
    in kernels/pack.py emits) plus the capped exact-outlier (idx, payload)
    table.  Peers unpack, dequantize, and average.  Nothing wider than the
    packed words crosses the collective — `wire_bytes` below is the real
    measured footprint, ~3.6x less traffic than an f32 psum at bin_bits=8
    with the 1/64 outlier cap (benchmarks/run.py gradwire).
  * ERROR FEEDBACK: the residual g - shipped is carried to the next step,
    so the long-run update is unbiased.  The paper's guarantee bounds the
    per-step residual ELEMENTWISE: |e_i| <= eb (outliers ship exactly, so
    their residual is 0) — heuristic compressors cannot promise that, and
    it is what keeps the error-feedback buffer from drifting.
  * OVERFLOW: if the outlier cap is exceeded the compact encoding cannot
    honor the bound; a pmax-agreed flag flips that tensor to the lossless
    psum for the step (lax.cond) — the guarantee is never silently
    dropped (the paper's core discipline).

These functions use explicit collectives over the 'pod' axis and are
called INSIDE a shard_map set up by launch/train.py; 'data'/'model'
sharding stays with GSPMD.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig, codec
from repro.core.bitops import bits_to_float, float_to_bits
from repro.core.quantizer import dequantize_abs, quantize_abs


class GradCompressionConfig(NamedTuple):
    eb_rel: float = 2.0 ** -8       # bound relative to grad RMS
    bin_bits: int = 8
    outlier_cap_frac: float = 1 / 64
    enabled: bool = True

    def qcfg(self) -> QuantizerConfig:
        return QuantizerConfig(mode="abs", error_bound=1.0,  # eb is traced
                               bin_bits=self.bin_bits,
                               outlier_cap_frac=self.outlier_cap_frac)


class CompressedShard(NamedTuple):
    """One pod's wire payload — exactly the arrays the all-gather moves."""
    words: jnp.ndarray       # uint32[n_words] packed bins
    out_idx: jnp.ndarray     # int32[K], n = empty
    out_payload: jnp.ndarray  # uint32[K] exact IEEE bits
    eb: jnp.ndarray          # f32 scalar per-tensor bound
    n_outliers: jnp.ndarray  # int32 scalar (header; not gathered)

    def nbytes(self) -> int:
        """Measured per-pod wire footprint of one all-gather."""
        return (self.words.size * 4 + self.out_idx.size * 4
                + self.out_payload.size * 4 + 4 + 4)


def compress_shard(g: jnp.ndarray, cfg: GradCompressionConfig):
    """Quantize + pack one pod-local gradient.  Returns (CompressedShard,
    Quantized) — the second carries outlier/recon planes that stay LOCAL
    (residual bookkeeping); only the shard's arrays go on the wire."""
    qc = cfg.qcfg()
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    k = max(1, int(n * cfg.outlier_cap_frac))
    rms = jnp.sqrt(jnp.mean(flat * flat))
    eb = jnp.asarray(cfg.eb_rel, jnp.float32) * rms

    q = quantize_abs(flat, qc, eb=eb)
    n_out = jnp.sum(q.outlier).astype(jnp.int32)
    (idx,) = jnp.nonzero(q.outlier, size=k, fill_value=n)
    payload = jnp.where(idx < n,
                        float_to_bits(flat)[jnp.minimum(idx, n - 1)], 0)
    words = codec.pack_words(q.bins, cfg.bin_bits)
    shard = CompressedShard(words, idx.astype(jnp.int32),
                            payload.astype(jnp.uint32), eb, n_out)
    return shard, q


def compressed_mean(g: jnp.ndarray, cfg: GradCompressionConfig, axis: str):
    """Compressed mean of g over the `axis` collective (call inside
    shard_map).  Returns (mean, residual) — residual is THIS shard's
    error-feedback term, elementwise bounded by eb."""
    qc = cfg.qcfg()
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    k = max(1, int(n * cfg.outlier_cap_frac))
    shard, q = compress_shard(g, cfg)
    # all pods must take the same branch: agree by pmax
    any_overflow = jax.lax.pmax((shard.n_outliers > k).astype(jnp.int32),
                                axis) > 0
    p = jax.lax.psum(1, axis)        # axis size (jax.lax.axis_size compat)

    def compressed_path(_):
        words_all = jax.lax.all_gather(shard.words, axis)    # uint32 wire
        eb_all = jax.lax.all_gather(shard.eb, axis)
        idx_all = jax.lax.all_gather(shard.out_idx, axis)
        pay_all = jax.lax.all_gather(shard.out_payload, axis)

        def dequant_one(w, e, ii, pp):
            bins = codec.unpack_words(w, n, cfg.bin_bits)
            vals = dequantize_abs(bins, qc, eb=e, dtype=jnp.float32)
            exact = bits_to_float(pp.astype(jnp.int32), jnp.float32)
            # mode='drop' discards empty slots (ii == n).  NEVER clamp them
            # to n-1: an outlier at the last index would be clobbered by
            # the empties' duplicate writes and decode as 0 — a silent
            # guarantee violation (the residual for outliers is 0, so
            # error feedback would not recover it either).
            return vals.at[ii].set(exact, mode="drop")

        return jnp.sum(jax.vmap(dequant_one)(words_all, eb_all, idx_all,
                                             pay_all), axis=0)

    def lossless_path(_):
        return jax.lax.psum(flat, axis)

    summed = jax.lax.cond(any_overflow, lossless_path, compressed_path, None)
    # residual: what we failed to ship (0 for outliers — they went exact;
    # 0 if the lossless path ran)
    shipped = jnp.where(q.outlier, flat, q.recon)
    resid = jnp.where(any_overflow, 0.0, flat - shipped)
    return (summed / p).reshape(g.shape), resid.reshape(g.shape)


def compressed_mean_tree(grads, residuals, cfg: GradCompressionConfig,
                         axis: str = "pod"):
    """Tree version with error feedback: grads_in + residuals are
    compressed-averaged; returns (mean_tree, new_residual_tree)."""
    leaves_g, tree = jax.tree.flatten(grads)
    leaves_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(leaves_g, leaves_r):
        m, nr = compressed_mean(g + r.astype(g.dtype), cfg, axis)
        out_g.append(m.astype(g.dtype))
        out_r.append(nr)
    return jax.tree.unflatten(tree, out_g), jax.tree.unflatten(tree, out_r)


def wire_bytes(n_elems: int, cfg: GradCompressionConfig) -> int:
    """Wire footprint per pod per tensor — matches CompressedShard.nbytes()
    exactly (packed uint32 words + capped (idx, payload) table + header)."""
    n_words = codec.packed_word_count(n_elems, cfg.bin_bits)
    k = max(1, int(n_elems * cfg.outlier_cap_frac))
    return n_words * 4 + k * 8 + 8
