"""Distributed compression services built on repro.core (gradients, KV
cache, checkpoints, activations) — where the paper's guaranteed error bound
becomes a systems property."""
