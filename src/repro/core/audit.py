"""Guarantee-audit plane (DESIGN.md §12): device-side bound verification
and wire-integrity checksums.

The paper's claim is that LC *guarantees* the error bound; this module
makes the guarantee observable at runtime instead of only in tests:

  * `audit_report` fuses decode-and-check into the encode pass — one
    `|x - x̂| <= eb` reduction over planes the encoder already computed
    (no host round-trip, no second decode).  Opt in via
    `Pipeline.encode(..., verify=True)` / `Selector.encode(..., verify=True)`.
  * `wire_checksum` / `attach_checksum` / `verify_wire` cover the
    transmitted planes of every wire container (`Encoded`,
    `SelectedWire`, `PackedKV`) with a position-mixed 32-bit xor fold.
    The checksum rides as an EXTRA aux field — opt in via
    `integrity=True` at encode — so clean-path wires stay bit-identical
    to checksum-free encodes.
  * `DEGRADATION_POLICIES` names what a failed check routes to:
    `raise` (structured `WireIntegrityError`), `drop` (drop the shard
    from a mean and renormalize — `compression.grads.compressed_mean`),
    `rerequest` (skip the page insert, caller re-sends —
    `models.engine.DecodeEngine`).

Checksum scope: every plane a receiver uses to decode — payload (full
padded plane; padding is deterministically zero on clean wires, so
truncation faults hit it), headers, transmitted lengths, chain ids,
outlier planes, eb/sign planes — EXCLUDING the checksum field itself.
The fold mixes each word with its position ((i+1) * 0x9E3779B9) and
avalanches the pair (murmur3 fmix32) before the xor reduction, so word
swaps, moved content, and repeated same-value corruption all change the
digest — a plain xor would cancel even-multiplicity changes.

Dispatch over wire types is duck-typed (like `transport.wire_bytes`) so
this module imports none of the container modules — they import us.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_MIX = 0x9E3779B9  # golden-ratio odd constant: position-dependent mixing


class WireIntegrityError(ValueError):
    """A transmitted wire failed a structural or checksum audit."""


# ------------------------------------------------------------ checksum ----

def _as_u32_words(a) -> jnp.ndarray:
    """Reinterpret any wire plane as a flat uint32 word stream (bit-exact
    for 32-bit dtypes; widened for bool / narrow ints)."""
    a = jnp.asarray(a)
    if a.dtype == jnp.bool_:
        a = a.astype(jnp.uint32)
    elif jnp.issubdtype(a.dtype, jnp.floating):
        a = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.uint32)
    elif a.dtype.itemsize != 4:
        a = a.astype(jnp.int32)
    if a.dtype != jnp.uint32:
        a = jax.lax.bitcast_convert_type(a, jnp.uint32)
    return a.reshape(-1)


def _fold(a) -> jnp.ndarray:
    u = _as_u32_words(a)
    if u.size == 0:
        return jnp.uint32(0)
    pos = (jnp.arange(u.size, dtype=jnp.uint32) + jnp.uint32(1)) \
        * jnp.uint32(_MIX)
    # Avalanche each (word, position) pair BEFORE the xor reduction
    # (murmur3 fmix32).  A linear u ^ pos fold is not enough: the same
    # value change at an even number of positions would cancel under
    # xor (e.g. every page's chain id bumping 0 -> 1).  After the
    # nonlinear mix, each position's delta is distinct, so
    # even-multiplicity corruption no longer annihilates.
    m = u ^ pos
    m = m * jnp.uint32(0x85EBCA6B)
    m = m ^ (m >> 13)
    m = m * jnp.uint32(0xC2B2AE35)
    m = m ^ (m >> 16)
    return jax.lax.reduce(m, jnp.uint32(0), jax.lax.bitwise_xor, (0,))


def _rotl(x, r: int):
    return (x << r) | (x >> (32 - r))


def plane_checksum(plane) -> jnp.ndarray:
    """The §12 fold over ONE plane (a uint32 scalar digest) — the
    building block `wire_checksum` combines per container, exposed for
    per-hop coverage: `Transport`'s packed-domain ring checksums each
    `ppermute` hop payload with this (DESIGN.md §8), where the whole-
    wire checksum cannot see intermediate hops."""
    return _fold(plane)


def _planes(wire) -> list:
    """The covered planes of a wire container, in a fixed order.  Duck-typed:
    `eb2` -> PackedKV (it also has chain_id), `chain_id` -> SelectedWire,
    `headers` -> Encoded."""
    if hasattr(wire, "eb2"):                              # compression.kv.PackedKV
        planes = [wire.payload, wire.payload_len, *wire.headers, wire.eb2,
                  wire.out_idx, wire.out_val, wire.overflow]
        if wire.chain_id is not None:
            planes.append(wire.chain_id)
        return planes
    if hasattr(wire, "chain_id"):                         # core.select.SelectedWire
        planes = [wire.chain_id, wire.payload, wire.payload_len,
                  wire.header, wire.out_idx, wire.out_payload,
                  wire.n_outliers, wire.overflow]
    elif hasattr(wire, "headers"):                        # core.pipeline.Encoded
        planes = [wire.payload, wire.payload_len, *wire.headers,
                  wire.out_idx, wire.out_payload, wire.n_outliers,
                  wire.overflow]
    else:
        raise TypeError(f"not an audited wire container: {type(wire)!r}")
    if wire.sign_words is not None:
        planes.append(wire.sign_words)
    if wire.eb is not None:
        planes.append(wire.eb)
    return planes


def wire_checksum(wire) -> jnp.ndarray:
    """Position-mixed 32-bit xor fold over a wire's transmitted planes
    (excluding any carried checksum).  jit-safe; one pass per plane."""
    cs = jnp.uint32(0)
    for p in _planes(wire):
        cs = _rotl(cs, 5) ^ _fold(p)
    return cs


def has_checksum(wire) -> bool:
    return getattr(wire, "checksum", None) is not None


def attach_checksum(wire):
    """Return the same wire with its checksum computed and carried as aux.
    The covered planes are untouched — a checksum-free decode of the
    result is bit-identical."""
    cs = wire_checksum(wire)
    if hasattr(wire, "with_checksum"):                    # PackedKV
        return wire.with_checksum(cs)
    return wire._replace(checksum=cs)                     # NamedTuple wires


def verify_wire(wire) -> jnp.ndarray:
    """Recompute the checksum and compare to the carried one.  Returns a
    traced bool (vmap-able); raises host-side if the wire carries none."""
    if not has_checksum(wire):
        raise ValueError("wire carries no checksum — encode it with "
                         "integrity=True (DESIGN.md §12)")
    return wire_checksum(wire) == wire.checksum


def verify_gathered(wire) -> jnp.ndarray:
    """Per-shard verdicts for a wire with a gathered leading axis (the
    result of `Transport.all_gather`): bool[axis_size]."""
    return jax.vmap(verify_wire)(wire)


# ----------------------------------------------------- length validation --

def check_payload_len(payload_len, capacity: int, *, what: str = "wire"):
    """Satellite guard for transmitted length fields: a corrupt
    `payload_len` past the padded plane's capacity must raise a structured
    error, not index garbage.  Host-side only — traced lengths are clamped
    defensively inside `codec.gather_chunks` instead."""
    if isinstance(payload_len, jax.core.Tracer):
        return
    lens = np.asarray(payload_len)
    if lens.size and ((lens < 0).any() or (lens > capacity).any()):
        bad = lens.reshape(-1)
        raise WireIntegrityError(
            f"{what}: transmitted payload_len {bad[:8].tolist()}"
            f"{'...' if bad.size > 8 else ''} outside [0, {capacity}] — "
            f"corrupt or truncated wire (DESIGN.md §12)")


# ------------------------------------------------------- bound auditing ---

class AuditReport(NamedTuple):
    """Device-side §1-guarantee audit of one encode (all fields are 0-d
    arrays; the pytree flows through jit/shard_map without host sync).

    n:           elements audited
    violations:  non-outlier finite values with |x - x̂| > eb — MUST be 0;
                 anything else is a codec regression or corrupt memory
    max_err:     max |x - x̂| over audited values (f32; REL: relative err)
    n_nonfinite: NaN/INF inputs (§1 failure taxonomy — routed to lossless
                 outlier storage, never binned)
    n_outliers:  values stored losslessly (includes the non-finite ones)
    overflow:    outlier plane overflowed its cap (wire already flags it)
    """

    n: jnp.ndarray
    violations: jnp.ndarray
    max_err: jnp.ndarray
    n_nonfinite: jnp.ndarray
    n_outliers: jnp.ndarray
    overflow: jnp.ndarray

    def ok(self):
        """True iff the bound held everywhere and nothing was dropped."""
        return (self.violations == 0) & ~self.overflow


def audit_report(x, q, cfg, eb=None, overflow=None,
                 n_outliers=None) -> AuditReport:
    """Build an `AuditReport` from planes the encoder already computed
    (`Quantized` from the shared quantize pass) — one extra reduction,
    no re-decode.  The three elementwise counters reduce in a SINGLE
    variadic `lax.reduce` pass, and `n_outliers` should be the wire's
    already-summed count (it equals `sum(q.outlier)` by construction) —
    together that keeps the audit inside the <=5% overhead bound the
    committed BENCH_audit.json pins, even on the cheapest chains.

    The violation test uses the PLAIN requested bound (not eb*TIGHTEN):
    the encoder accepted only `diff <= eb*TIGHTEN < eb`, so a clean
    encode audits to zero violations with margin, and anything the audit
    flags is a true guarantee break.
    """
    dt = x.dtype
    finite = jnp.isfinite(x)
    checked = finite & ~q.outlier
    zero = jnp.zeros((), dt)
    if cfg.mode == "rel":
        # relative metric: |x - x̂| <= eb * |x|; report err / |x|
        bound = jnp.asarray(cfg.error_bound, dt)
        ax = jnp.where(checked, jnp.abs(x), jnp.ones((), dt))
        err = jnp.where(checked, jnp.abs(x - q.recon) / ax, zero)
    else:
        # abs / noa: mirror the encoder's traced-eb floor transform
        e = jnp.asarray(cfg.error_bound if eb is None else eb, dt)
        bound = jnp.maximum(e, jnp.asarray(cfg.eb_floor, dt))
        err = jnp.where(checked, jnp.abs(x - q.recon), zero)
    bad = checked & ~(err <= bound)
    if overflow is None:
        overflow = jnp.zeros((), jnp.bool_)

    def _acc(a, b):
        return (jnp.maximum(a[0], b[0]), a[1] + b[1], a[2] + b[2])

    max_err, violations, n_nonfinite = jax.lax.reduce(
        (err.astype(jnp.float32).reshape(-1),
         bad.reshape(-1).astype(jnp.int32),
         (~finite).reshape(-1).astype(jnp.int32)),
        (jnp.float32(0), jnp.int32(0), jnp.int32(0)), _acc, (0,))
    if n_outliers is None:
        n_outliers = jnp.sum(q.outlier, dtype=jnp.int32)
    return AuditReport(
        n=jnp.int32(x.size),
        violations=violations,
        max_err=max_err,
        n_nonfinite=n_nonfinite,
        n_outliers=jnp.asarray(n_outliers).astype(jnp.int32).reshape(()),
        overflow=jnp.asarray(overflow).astype(jnp.bool_).reshape(()),
    )


# -------------------------------------------------- degradation policies --

def _raise_policy(ctx: dict):
    raise WireIntegrityError(
        f"wire integrity check failed at {ctx.get('site', '?')}: {ctx}")


def _drop_policy(ctx: dict):
    return "drop"


def _rerequest_policy(ctx: dict):
    return "rerequest"


# name -> handler(ctx) -> action token ("drop" | "rerequest") or raises.
# Sites with in-graph handling (compressed_mean's drop-and-renormalize)
# implement the action in the traced graph; host-driven sites (engine
# insert) call the handler directly.
DEGRADATION_POLICIES = {
    "raise": _raise_policy,
    "drop": _drop_policy,
    "rerequest": _rerequest_policy,
}


def register_policy(name: str, handler):
    """Register a degradation policy: handler(ctx_dict) -> action token,
    or raise.  See DESIGN.md §12 for the contract."""
    DEGRADATION_POLICIES[name] = handler


def get_policy(name: str):
    if name not in DEGRADATION_POLICIES:
        raise KeyError(f"unknown degradation policy {name!r}; have "
                       f"{sorted(DEGRADATION_POLICIES)}")
    return DEGRADATION_POLICIES[name]
