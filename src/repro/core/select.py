"""Adaptive chain selection (DESIGN.md §11) — pick the encoding chain per
shard/page at runtime from a small static candidate set.

One fixed chain cannot win on every data shape (the paper's central
lesson: LC is a framework of interchangeable stages precisely because
smooth fields, sparse gradients, iid noise and KV pages want different
coders).  This module makes the encoder's chain choice DATA-DEPENDENT
while keeping every downstream contract intact:

  * STATISTICS (`plane_stats`): one cheap pass over the packed word
    plane the stages already touch — per-chunk maxima give the
    zero-chunk fraction and the exact §6 zero/narrow payload sizes, and
    the byte histogram of the narrowed survivors feeds the `ent`
    Shannon estimate through the same `codec.ent_code_lengths` budget
    scan the real coder uses.  Pred-vs-plain is decided from the same
    statistics computed on the predictor's residual plane (first
    differences for `delta` — the §9 fold is a bijection, so residual
    energy shows up directly as narrower chunks).
  * SCORING (`chain_cost`): estimated transmitted bits per candidate =
    estimated payload bits (exact for plain/zero/narrow, Shannon
    estimate for `ent`) + the chain's static header content
    + `bias` * n_words/1024, argmin wins.  `bias` is the per-chain
    calibration the offline autotuner (benchmarks/autotune.py) fits
    from measured-vs-estimated bits and writes into
    `configs.registry.SELECTOR_SETS`.
  * DISPATCH: `Selector.encode` runs `lax.switch` over the pre-parsed
    candidate `Pipeline`s — fully jit-compatible static dispatch; only
    the selected branch executes, and that branch IS the candidate's
    own `Pipeline.encode`, so the selected wire is bit-identical to
    encoding with that chain directly.
  * WIRE (`SelectedWire`): the chain id rides as a tiny transmitted
    header (1 byte — §11 layout) so decode is self-describing; the
    payload plane is padded to the max candidate capacity and every
    per-stage header plane is flattened into one padded header plane so
    the container is structurally uniform across branches (gathers and
    vmaps stay shape-static).  `Selector.wire_bits` routes each
    branch's accounting through `Pipeline.wire_bits` (+8 bits for the
    chain id), and `transport.wire_bytes` dispatches on the wire form,
    so reported and shipped bytes cannot drift.

`Selector` duck-types the `Pipeline` surface the consumers use
(`encode`/`decode`/`wire_bits`/`wire_bytes`/`qcfg`/`spec`), so
`compression/grads.py` ships selector wires through the same
`CompressedShard`/`Transport` path — always the §8 gather branch, like
pred chains: the wire's meaning depends on a per-shard runtime choice,
so decode-then-sum is the only exact reduction.  `KVSelector` is the
per-page variant `compression/kv.py` dispatches at page close
(`pack_kv(..., stages="auto")`, DESIGN.md §10 lifecycle step 3).

Scoreability restriction: candidate word chains may contain only the
chunk coder (`zero`/`narrow`) and `ent` — `shuffle` transforms the
plane before chunking and is not predictable from the shared
statistics, so it is rejected at set construction rather than silently
mis-scored.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import audit as A
from . import codec as C
from . import predict as P
from .pipeline import (ChunkStage, Encoded, EntStage, PackStage, Pipeline,
                       QuantStage, encode_word_stages, decode_word_stages,
                       parse_pipeline, parse_word_stages, word_stage_sizes)

CHAIN_ID_BITS = 8          # the transmitted chain-id header (§11 layout)
MAX_CHAINS = 1 << CHAIN_ID_BITS


class SelectedWire(NamedTuple):
    """The one wire container every selector produces — an `Encoded`
    made structurally uniform across the candidate set so `lax.switch`
    branches, gathers and vmaps stay shape-static:

      * `chain_id` — int32 scalar, transmitted as a 1-byte header
        (§11 layout): decode and accounting dispatch on it, so the wire
        is self-describing;
      * `payload` — the selected chain's final word plane, zero-padded
        to the max capacity across the set;
      * `header` — every per-stage header plane of the selected chain,
        raveled in chain order and zero-padded to the max total header
        words across the set (the receiver re-splits by the selected
        chain's static layout);
      * the rest is exactly the §4 outlier table / sign plane / bound —
        identical across candidates because every chain in a set shares
        the quantizer and pack stages;
      * `checksum` — the OPT-IN §12 integrity digest (encode with
        integrity=True), carried as aux so checksum-free wires stay
        bit-identical to pre-§12 encodes.
    """
    chain_id: jnp.ndarray         # int32 scalar — transmitted (1 byte)
    payload: jnp.ndarray          # uint32[max capacity]
    payload_len: jnp.ndarray      # int32 scalar — transmitted word count
    header: jnp.ndarray           # uint32[max header words], flattened
    out_idx: jnp.ndarray
    out_payload: jnp.ndarray
    n_outliers: jnp.ndarray
    overflow: jnp.ndarray
    sign_words: jnp.ndarray | None
    eb: jnp.ndarray | None
    checksum: jnp.ndarray | None = None  # uint32 scalar (§12)


# ------------------------------------------------------------ statistics --

class PlaneStats(NamedTuple):
    """Cheap per-plane statistics (all f32 scalars), one pass over the
    packed word plane: exact §6 payload bits under zero-only and narrow
    coding (from the per-chunk maxima), and the `ent` Shannon estimate
    from the byte histogram of the narrowed surviving chunks through
    the real coder's `codec.ent_code_lengths`."""
    zero_frac: jnp.ndarray        # fraction of all-zero chunks
    zero_bits: jnp.ndarray        # exact payload bits under the zero stage
    narrow_bits: jnp.ndarray      # exact payload bits under narrow
    ent_bits: jnp.ndarray         # Shannon-estimated bits under narrow|ent


def plane_stats(words: jnp.ndarray, n_words: int) -> PlaneStats:
    """Statistics for one packed uint32 word plane (jit-safe)."""
    nc = C.lc_chunk_count(n_words)
    pad = jnp.pad(words, (0, nc * C.LC_CHUNK - n_words))
    chunks = pad.reshape(nc, C.LC_CHUNK)
    codes = C.lc_chunk_codes(chunks, "narrow")
    lens_w = C.lc_chunk_lens(codes)                     # words per chunk
    alive = codes > 0
    zero_bits = 32.0 * C.LC_CHUNK * jnp.sum(alive).astype(jnp.float32)
    narrow_bits = 32.0 * jnp.sum(lens_w).astype(jnp.float32)
    # ent estimate: histogram the VALID bytes of the narrowed chunks —
    # exactly the byte multiset of the compacted stream `ent` would code
    # in a narrow|ent chain — and price them with the coder's own
    # length-limited code lengths; the verbatim escape means the stage
    # never pays more than its input, hence the clamp
    sel = C.lc_narrow_chunks(chunks, codes)
    byts = C._ent_chunk_bytes(sel)                      # [nc, 4*LC_CHUNK]
    word_slot = jnp.arange(byts.shape[1], dtype=jnp.int32) // 4
    valid = word_slot[None, :] < lens_w[:, None]
    hist = jnp.zeros(C.ENT_SYMS, jnp.int32).at[byts.reshape(-1)].add(
        valid.reshape(-1).astype(jnp.int32))
    elens = C.ent_code_lengths(hist)
    ent_bits = jnp.sum(hist.astype(jnp.float32) * elens.astype(jnp.float32))
    return PlaneStats(
        1.0 - jnp.mean(alive.astype(jnp.float32)),
        zero_bits, narrow_bits, jnp.minimum(ent_bits, narrow_bits))


def _static_hdr_bits(stages: tuple, n_words: int) -> int:
    """Transmitted header-content bits of a word chain (a python int —
    mirrors the static part of `Pipeline.wire_bits`: per-stage header
    CONTENT plus the 32-bit transmitted-length field)."""
    sizes = word_stage_sizes(stages, n_words)[:-1]
    bits = sum(st.header_content_bits(sz) for st, sz in zip(stages, sizes))
    if stages and stages[-1].transmits_len:
        bits += 32
    return bits


def _est_payload_bits(stages: tuple, st: PlaneStats, n_words: int):
    """Estimated transmitted payload bits of a word chain over a plane
    with statistics `st` — exact for plain/zero/narrow, the Shannon
    estimate for chains ending in `ent`."""
    if not stages:
        return jnp.float32(32 * n_words)
    last = stages[-1]
    if isinstance(last, EntStage):
        return st.ent_bits
    if isinstance(last, ChunkStage) and last.mode == "narrow":
        return st.narrow_bits
    if isinstance(last, ChunkStage):
        return st.zero_bits
    raise ValueError(f"stage {last.spec()!r} is not scoreable from the "
                     f"shared statistics (DESIGN.md §11)")


def chain_cost(stages: tuple, st: PlaneStats, n_words: int,
               bias: float = 0.0):
    """§11 scoring rule: estimated payload bits + static header content
    + the autotuner's calibration bias (bits per 1024 words)."""
    return (_est_payload_bits(stages, st, n_words)
            + jnp.float32(_static_hdr_bits(stages, n_words))
            + jnp.float32(bias) * (n_words / 1024.0))


def _check_scoreable(stages: tuple):
    for st in stages:
        if not isinstance(st, (ChunkStage, EntStage)):
            raise ValueError(
                f"selector candidates may only contain zero/narrow/ent "
                f"word stages (DESIGN.md §11 scoreability); got "
                f"{st.spec()!r}")


def _pred_key(pred: tuple) -> tuple:
    return tuple(p.spec() for p in pred)


# -------------------------------------------------------------- Selector --

@dataclasses.dataclass(frozen=True)
class Selector:
    """A static candidate set of full pipelines sharing one quantizer
    and pack stage, with runtime per-shard selection.  Hashable (usable
    as a jit static / pytree-aux value) and duck-types the `Pipeline`
    surface its consumers use — `compression/grads.py` ships the result
    through the same `CompressedShard`/`Transport` path (§8 gather
    branch, like pred chains)."""
    name: str
    chains: tuple                 # tuple[Pipeline, ...] sharing quant+pack
    bias: tuple = ()              # per-chain bits/1024 words (autotuned)

    def __post_init__(self):
        if not self.chains:
            raise ValueError("a selector needs at least one candidate")
        if len(self.chains) > MAX_CHAINS:
            raise ValueError(f"at most {MAX_CHAINS} candidates fit the "
                             f"{CHAIN_ID_BITS}-bit chain-id header")
        q0, p0 = self.chains[0].quant, self.chains[0].pack
        for pipe in self.chains:
            if pipe.quant != q0 or pipe.pack != p0:
                raise ValueError(
                    f"every candidate in a selector set must share the "
                    f"quantizer and pack stages; {pipe.spec()!r} differs "
                    f"from {self.chains[0].spec()!r}")
            _check_scoreable(pipe.stages)
        if self.bias and len(self.bias) != len(self.chains):
            raise ValueError("bias must have one entry per candidate")

    # --- Pipeline-surface statics -----------------------------------------

    @property
    def quant(self) -> QuantStage:
        return self.chains[0].quant

    @property
    def pack(self) -> PackStage:
        return self.chains[0].pack

    def spec(self) -> str:
        return f"auto:{self.name}"

    def qcfg(self):
        return self.chains[0].qcfg()

    def n_words(self, n: int) -> int:
        return self.chains[0].n_words(n)

    def capacity_words(self, n: int) -> int:
        """Static payload capacity of the uniform wire: the max final
        capacity across candidates."""
        return max(pipe.stage_sizes(n)[-1] for pipe in self.chains)

    def header_capacity_words(self, n: int) -> int:
        """Static size of the flattened header plane: the max total
        stored header words across candidates."""
        return max(self._chain_header_words(i, n)
                   for i in range(len(self.chains)))

    def _chain_header_words(self, i: int, n: int) -> int:
        pipe = self.chains[i]
        sizes = pipe.stage_sizes(n)[:-1]
        return sum(st.header_words(sz)
                   for st, sz in zip(pipe.stages, sizes))

    def _pred_shape(self, pred_shape, n: int) -> tuple:
        shape = (n,) if pred_shape is None else tuple(pred_shape)
        if int(np.prod(shape)) != n:
            raise ValueError(f"pred_shape {shape} has "
                             f"{int(np.prod(shape))} elements, tensor "
                             f"has {n}")
        return shape

    # --- scoring ----------------------------------------------------------

    def _costs(self, bins, base_words, n: int, pred_shape):
        """f32[n_chains] estimated transmitted bits per candidate — the
        §11 scoring rule over per-plane statistics (one stats pass per
        DISTINCT pred prefix in the set)."""
        n_words = self.n_words(n)
        shape = self._pred_shape(pred_shape, n)
        stats = {}
        costs = []
        for i, pipe in enumerate(self.chains):
            key = _pred_key(pipe.pred)
            if key not in stats:
                if pipe.pred:
                    codes = P.encode_pred_stages(pipe.pred, bins, shape,
                                                 self.pack.bits)
                    words = C.pack_words(codes, self.pack.bits)
                else:
                    words = base_words
                stats[key] = plane_stats(words, n_words)
            b = self.bias[i] if self.bias else 0.0
            costs.append(chain_cost(pipe.stages, stats[key], n_words, b))
        return jnp.stack(costs)

    def score(self, x, eb=None, *, pred_shape=None):
        """Estimated wire bits per candidate (the autotuner's view of
        the runtime scoring rule)."""
        flat = x.reshape(-1)
        n = flat.shape[0]
        if pred_shape is None:
            pred_shape = tuple(x.shape)
        ep, qt = C.encode_packed(flat, self.qcfg(), eb,
                                 return_quantized=True)
        return self._costs(qt.bins, ep.words, n, pred_shape)

    # --- encode -----------------------------------------------------------

    def _embed(self, enc: Encoded, i: int, n: int) -> SelectedWire:
        """Uniformize one candidate's `Encoded` into the shared wire."""
        cap = self.capacity_words(n)
        payload = jnp.pad(enc.payload, (0, cap - enc.payload.shape[0]))
        hw = self.header_capacity_words(n)
        flat_h = ([h.reshape(-1) for h in enc.headers]
                  + [jnp.zeros((hw,), jnp.uint32)])
        header = jnp.concatenate(flat_h)[:hw]
        return SelectedWire(jnp.int32(i), payload, enc.payload_len, header,
                            enc.out_idx, enc.out_payload, enc.n_outliers,
                            enc.overflow, enc.sign_words, enc.eb)

    def _view(self, wire: SelectedWire, i: int, n: int) -> Encoded:
        """Exact inverse of `_embed` for candidate `i` (static slicing —
        the chain id names the layout, so the wire is self-describing)."""
        pipe = self.chains[i]
        sizes = pipe.stage_sizes(n)
        headers, off = [], 0
        for st, sz in zip(pipe.stages, sizes[:-1]):
            hw = st.header_words(sz)
            headers.append(wire.header[off:off + hw])
            off += hw
        return Encoded(wire.payload[:sizes[-1]], wire.payload_len,
                       tuple(headers), wire.out_idx, wire.out_payload,
                       wire.n_outliers, wire.overflow, wire.sign_words,
                       wire.eb)

    def encode(self, x, eb=None, *, kernels: bool | None = None,
               interpret: bool | None = None,
               return_quantized: bool = False, pred_shape=None,
               verify: bool = False, integrity: bool = False):
        """Statistics pass -> score -> `lax.switch` into the selected
        candidate's own `Pipeline.encode` (reference path — the branch
        is bit-identical to encoding with that chain directly).  With
        `return_quantized` also returns the quantizer's local planes
        (identical across candidates: they share the quantizer, and
        pred stages are bijections applied after it).

        `kernels=` is accepted for Pipeline-surface compatibility but
        the selector ALWAYS runs the jit reference: the fused Pallas
        kernels have no statistics/switch slot yet — that is the open
        fused-selector row in the DESIGN.md §7 dispatch table.  A
        truthy request warns once rather than silently downgrading.

        §12 audit plane (mirrors `Pipeline.encode`): `verify=True`
        appends an `audit.AuditReport` built from the shared quantizer
        pass (one report, valid for whichever candidate wins — they
        share the quantizer); `integrity=True` attaches the 32-bit
        checksum over the uniform wire as aux."""
        if kernels:
            warnings.warn(
                "Selector.encode always runs the jit reference — the "
                "fused selector kernel is the open row in the DESIGN.md "
                "§7 dispatch table; kernels= is ignored", UserWarning,
                stacklevel=2)
        del kernels, interpret      # reference path; §7 open dispatch row
        flat = x.reshape(-1)
        n = flat.shape[0]
        if pred_shape is None:
            pred_shape = tuple(x.shape)
        ep, qt = C.encode_packed(flat, self.qcfg(), eb,
                                 return_quantized=True)
        costs = self._costs(qt.bins, ep.words, n, pred_shape)
        chain_id = jnp.argmin(costs).astype(jnp.int32)

        def branch(i):
            def run(v):
                enc = self.chains[i].encode(v, eb, kernels=False,
                                            pred_shape=pred_shape)
                return self._embed(enc, i, n)
            return run

        wire = jax.lax.switch(chain_id,
                              [branch(i) for i in range(len(self.chains))],
                              flat)
        if integrity:
            wire = A.attach_checksum(wire)
        if verify:
            report = A.audit_report(
                x, qt, self.qcfg(),
                eb=wire.eb if wire.eb is not None else eb,
                overflow=wire.overflow, n_outliers=wire.n_outliers)
            return (wire, qt, report) if return_quantized else (wire, report)
        return (wire, qt) if return_quantized else wire

    # --- decode -----------------------------------------------------------

    def decode(self, wire: SelectedWire, n: int | None = None, shape=None,
               dtype=None, *, kernels: bool | None = None,
               interpret: bool | None = None, pred_shape=None,
               verify: bool = False):
        """Invert the selected chain: `lax.switch` on the transmitted
        chain id into that candidate's own `Pipeline.decode` — bit-
        identical to decoding the chain's plain `Encoded` directly.
        `kernels=` is ignored like on encode (same open §7 fused slot —
        a truthy request warns once).  §12 guards mirror
        `Pipeline.decode`: host-side `payload_len` range validation,
        and `verify=True` re-checks the carried checksum."""
        if kernels:
            warnings.warn(
                "Selector.decode always runs the jit reference — the "
                "fused selector kernel is the open row in the DESIGN.md "
                "§7 dispatch table; kernels= is ignored", UserWarning,
                stacklevel=2)
        del kernels, interpret
        if n is None:
            if shape is None:
                raise ValueError("decode needs n or shape")
            n = int(np.prod(shape))
        if pred_shape is None and shape is not None:
            pred_shape = tuple(shape)
        A.check_payload_len(wire.payload_len, wire.payload.shape[0],
                            what=f"SelectedWire[{self.spec()}]")
        if verify:
            ok = A.verify_wire(wire)
            if not isinstance(ok, jax.core.Tracer) and not bool(ok):
                raise A.WireIntegrityError(
                    f"SelectedWire[{self.spec()}]: checksum mismatch on "
                    f"decode")

        def branch(i):
            def run(w):
                return self.chains[i].decode(
                    self._view(w, i, n), n=n, shape=shape, dtype=dtype,
                    kernels=False, pred_shape=pred_shape)
            return run

        return jax.lax.switch(wire.chain_id,
                              [branch(i) for i in range(len(self.chains))],
                              wire)

    def roundtrip(self, x, eb=None, **kw):
        return self.decode(self.encode(x, eb, **kw), shape=x.shape, **kw)

    # --- honest wire accounting -------------------------------------------

    def wire_bits(self, wire: SelectedWire, n: int):
        """Transmitted bits: the selected chain's own
        `Pipeline.wire_bits` (dispatched on the transmitted chain id)
        plus the `CHAIN_ID_BITS` chain-id header — the §11 layout.
        Always traced (the chain choice is data-dependent)."""

        def branch(i):
            def run(w):
                return jnp.float32(
                    self.chains[i].wire_bits(self._view(w, i, n), n))
            return run

        bits = jax.lax.switch(wire.chain_id,
                              [branch(i) for i in range(len(self.chains))],
                              wire)
        if wire.checksum is not None:
            bits = bits + jnp.float32(32)          # §12 integrity digest
        return bits + jnp.float32(CHAIN_ID_BITS)

    def wire_bytes(self, wire: SelectedWire, n: int):
        return self.wire_bits(wire, n) / 8.0

    def capacity_bytes(self, wire: SelectedWire) -> int:
        """Static upper bound: what a padded all-gather buffer holds."""
        b = (wire.payload.size + wire.header.size + wire.out_idx.size
             + wire.out_payload.size) * 4 + 8 + 4 + 1
        if wire.sign_words is not None:
            b += wire.sign_words.size * 4
        if wire.checksum is not None:
            b += 4                                 # §12 integrity digest
        return b


# ----------------------------------------------------------- KV selector --

@dataclasses.dataclass(frozen=True)
class KVSelector:
    """Per-page chain selection over page FRAGMENTS of the two-domain
    grammar (optional §9 pred stages + word stages; the quantizer lives
    in the per-page KV bound — DESIGN.md §10).  Every fragment must
    preserve the per-page word count so pages stay independently
    migratable; the chosen fragment's id is transmitted per page
    (1 byte) next to the page's transmitted length."""
    name: str
    chains: tuple                 # tuple[(pred tuple, word tuple), ...]
    bias: tuple = ()

    def __post_init__(self):
        if not self.chains:
            raise ValueError("a KV selector needs at least one fragment")
        if len(self.chains) > MAX_CHAINS:
            raise ValueError(f"at most {MAX_CHAINS} fragments fit the "
                             f"{CHAIN_ID_BITS}-bit chain-id header")
        for _, word in self.chains:
            _check_scoreable(word)
        if self.bias and len(self.bias) != len(self.chains):
            raise ValueError("bias must have one entry per fragment")

    def spec(self) -> str:
        return f"auto:{self.name}"

    def validate_page(self, wpp: int):
        for _, word in self.chains:
            sizes = word_stage_sizes(word, wpp)
            assert all(sz == wpp for sz in sizes), (
                "selector fragments must preserve the per-page word "
                "count so pages stay self-describing", wpp, sizes)

    def header_capacity_words(self, wpp: int) -> int:
        return max((sum(st.header_words(sz) for st, sz in
                        zip(word, word_stage_sizes(word, wpp)[:-1]))
                    for _, word in self.chains))

    def header_content_bits(self, i: int, wpp: int) -> int:
        """Transmitted header-content bits of fragment `i` for one page
        (the per-page accounting `transport.wire_bytes` sums)."""
        pred, word = self.chains[i]
        return (_static_hdr_bits(word, wpp) - (32 if word else 0)
                + sum(p.header_content_bits() for p in pred))

    # --- per-page select / encode / decode --------------------------------

    def page_costs(self, bins, shape, bits: int, wpp: int):
        """f32[n_chains] estimated transmitted bits for ONE page's int32
        bin plane — the §11 scoring rule over the page's word-plane
        statistics (vmap over pages; the autotuner reads these to
        calibrate bias)."""
        stats, costs = {}, []
        base = C.pack_words(bins, bits)
        for i, (pred, word) in enumerate(self.chains):
            key = _pred_key(pred)
            if key not in stats:
                if pred:
                    codes = P.encode_pred_stages(pred, bins, shape, bits)
                    words = C.pack_words(codes, bits)
                else:
                    words = base
                stats[key] = plane_stats(words, wpp)
            b = self.bias[i] if self.bias else 0.0
            costs.append(chain_cost(word, stats[key], wpp, b))
        return jnp.stack(costs)

    def page_select(self, bins, shape, bits: int, wpp: int):
        """Chain id (int32 scalar) for ONE page: argmin of
        `page_costs`."""
        return jnp.argmin(
            self.page_costs(bins, shape, bits, wpp)).astype(jnp.int32)

    def encode_page(self, i: int, bins, shape, bits: int, wpp: int):
        """Encode ONE page's bin plane with fragment `i` into the
        uniform (header, payload, payload_len) triple."""
        pred, word = self.chains[i]
        codes = (P.encode_pred_stages(pred, bins, shape, bits)
                 if pred else bins)
        words = C.pack_words(codes, bits)
        headers, payload, plen = encode_word_stages(word, words, wpp)
        hw = self.header_capacity_words(wpp)
        flat_h = ([h.reshape(-1) for h in headers]
                  + [jnp.zeros((hw,), jnp.uint32)])
        return jnp.concatenate(flat_h)[:hw], payload, plen

    def decode_page(self, i: int, header, payload, shape, bits: int,
                    wpp: int):
        """Exact inverse of `encode_page`: ONE page back to its int32
        bin plane."""
        pred, word = self.chains[i]
        headers, off = [], 0
        for st, sz in zip(word, word_stage_sizes(word, wpp)[:-1]):
            hw = st.header_words(sz)
            headers.append(header[off:off + hw])
            off += hw
        words = decode_word_stages(word, tuple(headers), payload, wpp)
        bins = C.unpack_words(words, wpp * 32 // bits, bits)
        if pred:
            bins = P.decode_pred_stages(pred, bins, shape, bits)
        return bins


# ---------------------------------------------------------- set registry --

def _split_fragment(frag: str, pack_bits: int):
    """'kvdelta|zero|narrow' -> (pred tuple, word tuple) — the page-
    fragment split `compression/kv.py` uses (leading registered pred
    names form the value chain)."""
    parts = [p.strip() for p in str(frag).split("|") if p.strip()]
    npred = 0
    while (npred < len(parts)
           and parts[npred].split(":")[0] in P.PRED_STAGES):
        npred += 1
    return (P.parse_pred_stages("|".join(parts[:npred])),
            parse_word_stages("|".join(parts[npred:]), pack_bits))


@functools.lru_cache(maxsize=None)
def get_selector(name: str) -> Selector:
    """Build the full-pipeline `Selector` for a `SELECTOR_SETS` entry
    (cached so jit sees one static instance per name)."""
    from repro.configs.registry import SELECTOR_SETS

    if name not in SELECTOR_SETS:
        raise KeyError(f"unknown selector set {name!r}; have "
                       f"{sorted(SELECTOR_SETS)}")
    entry = SELECTOR_SETS[name]
    if entry["base"] is None:
        raise KeyError(f"selector set {name!r} is a KV page-fragment set "
                       f"(base=None); use get_kv_selector")
    base = parse_pipeline(entry["base"])
    chains = []
    for frag in entry["chains"]:
        pred, word = _split_fragment(frag, base.pack.bits)
        chains.append(Pipeline(base.quant, base.pack, word, pred))
    return Selector(name, tuple(chains), tuple(entry.get("bias", ())))


@functools.lru_cache(maxsize=None)
def get_kv_selector(name: str) -> KVSelector:
    """Build the per-page `KVSelector` for a base-less `SELECTOR_SETS`
    entry (KV pages pack at 8 bits/value)."""
    from repro.configs.registry import SELECTOR_SETS

    if name not in SELECTOR_SETS:
        raise KeyError(f"unknown selector set {name!r}; have "
                       f"{sorted(SELECTOR_SETS)}")
    entry = SELECTOR_SETS[name]
    if entry["base"] is not None:
        raise KeyError(f"selector set {name!r} is a full-pipeline set; "
                       f"use get_selector")
    chains = tuple(_split_fragment(f, 8) for f in entry["chains"])
    return KVSelector(name, chains, tuple(entry.get("bias", ())))


def is_auto_spec(spec) -> bool:
    """True for the 'auto' / 'auto:SET' grammar extension (§11)."""
    return isinstance(spec, str) and (spec == "auto"
                                      or spec.startswith("auto:"))


def _set_name(spec: str, default: str) -> str:
    return spec.split(":", 1)[1] if ":" in spec else default


def parse_selector(spec: str, *, default: str = "grad-wire") -> Selector:
    """Resolve an 'auto' / 'auto:SET' spec to its `Selector`."""
    if not is_auto_spec(spec):
        raise ValueError(f"not an auto spec: {spec!r}")
    return get_selector(_set_name(spec, default))


def parse_kv_selector(spec: str, *,
                      default: str = "kv-page") -> KVSelector:
    """Resolve an 'auto' / 'auto:SET' spec to its `KVSelector`."""
    if not is_auto_spec(spec):
        raise ValueError(f"not an auto spec: {spec!r}")
    return get_kv_selector(_set_name(spec, default))


def parse_chain(spec):
    """The §11-extended pipeline grammar: 'auto' / 'auto:SET' resolves
    to a `Selector`, anything else parses as a plain `Pipeline`."""
    if isinstance(spec, (Selector, Pipeline)):
        return spec
    if is_auto_spec(spec):
        return parse_selector(spec)
    return parse_pipeline(spec)
