"""Parity-safe transcendental replacements (paper §3.2, ported 1:1 to JAX).

The paper's REL quantizer needs log2()/pow2(), but library transcendentals
differ between backends (the paper observed log() returning 88.5 on GPU vs
88.4999... on CPU; XLA has the same hazard: Eigen polynomials on CPU vs
hardware lookup tables on TPU).  These replacements use ONLY bitcasts,
integer ops, and IEEE-754 add/sub — every XLA backend produces identical
bits, which is what guarantees CPU/TPU compression parity.

They are *approximations* (log2(1+m) ~= m); inaccuracy is harmless because
the quantizer double-checks every value and falls back to lossless storage
(paper §3.1).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# dtype -> (int dtype, mantissa bits, exponent mask, exponent bias)
_FP_SPEC = {
    jnp.dtype(jnp.float32): (jnp.int32, 23, 0xFF, 127),
    jnp.dtype(jnp.float64): (jnp.int64, 52, 0x7FF, 1023),
}


def fp_spec(dtype):
    try:
        return _FP_SPEC[jnp.dtype(dtype)]
    except KeyError:
        raise TypeError(f"unsupported float dtype for bit-level quantizer: {dtype}")


# --- FMA / contraction hazard and why steps are powers of two -------------
#
# The paper disables FMA with compiler flags (-mno-fma / -fmad=false).  XLA
# has no such knob: we measured (tests/test_parity.py::test_fma_contraction
# _documented) that LLVM contracts mul+add at INSTRUCTION level underneath
# XLA, and even `lax.optimization_barrier` does not stop it — the
# double-check accepted values whose decoder-side reconstruction violated
# the bound, and jit vs eager produced different pow2approx bits.
#
# Our fix is stronger than a flag: make contraction mathematically
# irrelevant.  Every quantization step (ABS eb2, REL log_step) is a POWER
# OF TWO, so `bin * step` is an exact exponent shift (error-free for
# |bin| < 2^mantissa_bits).  fma(a,b,c) == fadd(fmul(a,b),c) whenever a*b
# is exact, so any contraction decision by any compiler yields identical
# bits.  The remaining single adds/subs (lone fadd/fsub/fcmp) are
# individually IEEE-deterministic and cannot be contracted further.
# Cost: the step can be up to 2x finer than requested -> <= 1 extra
# bit/value before the lossless stage (measured in benchmarks/).


def pow2_floor(x: jnp.ndarray) -> jnp.ndarray:
    """Largest power of two <= x (x positive, finite, normal) — computed by
    clearing the mantissa bits, so it is deterministic integer work.  Used
    to derive the effective quantization step from a traced per-tensor eb
    on-device."""
    int_t, mb, _, _ = fp_spec(x.dtype)
    bits = lax.bitcast_convert_type(x, int_t)
    return lax.bitcast_convert_type(bits & ~((1 << mb) - 1), x.dtype)


def log2approx(x: jnp.ndarray) -> jnp.ndarray:
    """Paper's log2approxf: exponent + (1.mantissa), exact on powers of two.

    Monotonic piecewise-linear approximation of log2|x|; max error ~0.086.
    Callers pass |x|; sign/zero/denormal cases are the quantizer's job.
    """
    int_t, mb, emask, bias = fp_spec(x.dtype)
    orig_i = lax.bitcast_convert_type(x, int_t)            # extract bit pattern
    expo = (orig_i >> mb) & emask                          # isolate exponent
    frac_i = (bias << mb) | (orig_i & ((1 << mb) - 1))     # isolate fraction
    frac_f = lax.bitcast_convert_type(frac_i.astype(int_t), x.dtype)
    return frac_f + (expo - (bias + 1)).astype(x.dtype)    # add de-biased exponent


def pow2approx(log_f: jnp.ndarray) -> jnp.ndarray:
    """Paper's pow2approxf: exact inverse of log2approx on its own range.

    Bit-determinism contract: log_f must be an EXACT product (bin * pow2
    step — see the module note).  Then `log_f + bias` is immune to FMA
    contraction, and `biased - (expo-1)` is exact by Sterbenz, so every
    backend produces identical bits.
    """
    int_t, mb, _, bias = fp_spec(log_f.dtype)
    biased = log_f + bias                                  # re-bias exponent
    expo = biased.astype(int_t)                            # C-cast: trunc toward zero
    frac_f = biased - (expo - 1).astype(log_f.dtype)       # recreate fraction in [1,2)
    frac_i = lax.bitcast_convert_type(frac_f, int_t)       # extract fraction
    exp_i = (expo << mb) | (frac_i & ((1 << mb) - 1))      # combine exp & frac
    return lax.bitcast_convert_type(exp_i, log_f.dtype)


def float_to_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-exact payload for the lossless outlier channel (preserves NaN
    payloads, -0.0, infinities)."""
    int_t, _, _, _ = fp_spec(x.dtype)
    return lax.bitcast_convert_type(x, int_t)


def bits_to_float(bits: jnp.ndarray, dtype) -> jnp.ndarray:
    return lax.bitcast_convert_type(bits, dtype)
