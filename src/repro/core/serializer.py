"""Host-side byte-stream serializer — the LC-style on-disk/archival format.

Unlike the jit codec (static shapes), this is true variable-length
encoding: outliers are stored INLINE with the bin numbers via an escape
code (+maxbin, which the quantizer's range check keeps out of the valid
bin range), exactly the paper's §3.1 design point vs SZ3's separate
outlier list.  A final lossless stage (zlib, standing in for LC's
lossless components) compresses the packed stream.

Two lossless coders, one pipeline (DESIGN.md §6): zlib here is the
HOST/ARCHIVAL coder — highest ratio, byte-stream output, not jit-able —
used for checkpoints and offline storage.  The DEVICE/WIRE coder is the
chunked zero/narrow scheme of core.codec.encode_lossless (EncodedLC):
weaker ratio but exact, shape-static, and fused into the quantize+pack
kernels, so it is what collectives and cache migrations move.
`compression_ratio` below can report either side (wire=) so benchmark
numbers stay comparable.

Layout (little-endian):
  u32 magic | u8 mode | u8 dtype | u8 bin_bits | u8 flags
  u64 n | u64 eb_bits (exact target-dtype bits of eb, zero-extended)
  zlib( bins[n] as i{bin_bits} with +maxbin escapes
        | payload bits for each escape, in index order
        | sign plane (REL only, packbits) )

Decode recomputes recon with the SAME expressions as the device decoder
(numpy, IEEE ops only) — bit parity between host and device decode is a
test invariant (tests/test_parity.py).
"""
from __future__ import annotations

import struct
import zlib

import numpy as np

from .config import QuantizerConfig
from . import oracle_np as onp

_MAGIC = 0x4C43_4542  # 'LCEB'
_MODES = {"abs": 0, "rel": 1, "noa": 2}
_MODES_INV = {v: k for k, v in _MODES.items()}
_DTYPES = {"float32": 0, "float64": 1}
_DTYPES_INV = {0: "float32", 1: "float64"}
_BIN_NP = {8: np.int8, 16: np.int16, 32: np.int32}


def _eb_bits(eb: float, dtype: np.dtype) -> int:
    if dtype == np.float32:
        return int(np.float32(eb).view(np.uint32))
    return int(np.float64(eb).view(np.uint64))


def _eb_from_bits(bits: int, dtype: np.dtype) -> np.floating:
    if dtype == np.float32:
        return np.uint32(bits).view(np.float32)
    return np.uint64(bits).view(np.float64)


def serialize(x: np.ndarray, cfg: QuantizerConfig, level: int = 6) -> bytes:
    """Full LC-style pipeline on the host: quantize (numpy oracle, bit-
    identical to the device quantizer) -> pack with inline outliers ->
    lossless stage."""
    flat = np.ascontiguousarray(x).reshape(-1)
    dt = flat.dtype
    if cfg.mode == "abs":
        bins, outlier, _ = onp.quantize_abs(flat, cfg)
        sign = None
        eb = cfg.np_dtype.type(cfg.error_bound)
    elif cfg.mode == "rel":
        bins, outlier, _, sign = onp.quantize_rel(flat, cfg)
        eb = cfg.np_dtype.type(cfg.error_bound)
    else:  # noa
        bins, outlier, _, eb = onp.quantize_noa(flat, cfg)
        sign = None

    maxbin = cfg.maxbin
    stored = bins.astype(np.int64)
    stored[outlier] = maxbin                       # inline escape code
    packed = stored.astype(_BIN_NP[cfg.bin_bits]).tobytes()

    bits_t = np.uint32 if dt == np.float32 else np.uint64
    payload = flat[outlier].view(bits_t).tobytes()  # bit-exact, index order
    body = packed + payload
    flags = 0
    if sign is not None:
        body += np.packbits(sign.astype(np.uint8)).tobytes()
        flags |= 1

    header = struct.pack(
        "<IBBBBQQ", _MAGIC, _MODES[cfg.mode], _DTYPES[str(dt)], cfg.bin_bits,
        flags, flat.size, _eb_bits(float(eb), dt))
    return header + zlib.compress(body, level)


def deserialize(stream: bytes) -> tuple[np.ndarray, QuantizerConfig]:
    magic, mode_i, dt_i, bin_bits, flags, n, ebb = struct.unpack(
        "<IBBBBQQ", stream[:24])
    if magic != _MAGIC:
        raise ValueError("bad magic")
    mode = _MODES_INV[mode_i]
    dtype = np.dtype(_DTYPES_INV[dt_i])
    eb = _eb_from_bits(ebb, dtype)
    # NOA's effective eb can be degenerate (all-outlier stream, eb == 0);
    # the config object still needs a valid bound, the decode below uses
    # the header eb directly.
    cfg_eb = float(eb) if float(eb) > 0 else 1.0
    cfg = QuantizerConfig(mode=mode, error_bound=cfg_eb, bin_bits=bin_bits,
                          dtype=str(dtype))
    body = zlib.decompress(stream[24:])

    bin_np = _BIN_NP[bin_bits]
    bins = np.frombuffer(body[: n * bin_np().itemsize], bin_np).astype(np.int64)
    off = n * bin_np().itemsize
    outlier = bins == cfg.maxbin
    n_out = int(outlier.sum())
    bits_t = np.uint32 if dtype == np.float32 else np.uint64
    payload = np.frombuffer(body[off: off + n_out * bits_t().itemsize], bits_t)
    off += n_out * bits_t().itemsize
    sign = None
    if flags & 1:
        nbytes = (n + 7) // 8
        sign = np.unpackbits(
            np.frombuffer(body[off: off + nbytes], np.uint8))[:n].astype(bool)

    bins_clean = np.where(outlier, 0, bins).astype(np.int32)
    if mode == "rel":
        out = onp.dequantize_rel(bins_clean, sign, cfg)
    else:
        # NOA stored its effective eb in the header, so decode is plain ABS.
        out = onp.dequantize_abs(bins_clean, cfg, eb=eb)
    out = out.copy()
    out[outlier] = payload.view(dtype)             # bit-exact restore
    return out, cfg


def _device_pipeline(cfg: QuantizerConfig, pipeline):
    """Resolve the device-wire pipeline: an explicit spec/Pipeline wins;
    otherwise cfg maps onto its historical default chain,
    quantize|pack|narrow (DESIGN.md §6/§7)."""
    from .pipeline import (ChunkStage, PackStage, Pipeline, QuantStage,
                           parse_pipeline)
    if pipeline is not None:
        return parse_pipeline(pipeline)
    return Pipeline(QuantStage(cfg.mode, cfg.error_bound,
                               cfg.outlier_cap_frac, cfg.dtype),
                    PackStage(cfg.bin_bits), (ChunkStage("narrow"),))


def compression_ratio(x: np.ndarray, cfg: QuantizerConfig, level: int = 6,
                      stream: bytes | None = None, wire: str = "host",
                      pipeline=None, per_stage: bool = False,
                      pred_shape=None):
    """Compression ratio of x under cfg.

    wire='host'   — this module's zlib byte stream (archival coder).
    wire='device' — the jit wire format: the compression PIPELINE's
                    `Encoded` container (DESIGN.md §7), counting the
                    transmitted bits only via `Pipeline.wire_bits` — the
                    SAME accessor the gathered wire is measured with, so
                    reported and shipped bytes cannot drift.  `pipeline`
                    (spec string or Pipeline) selects the chain; default
                    is cfg's quantizer + pack + 'narrow' (the §6 stage).
    wire='both'   — (host, device) tuple, for comparable benchmark rows.
    per_stage     — with a device wire, report [(stage_spec, ratio)] per
                    chain prefix instead of one number (Pipeline
                    .stage_report), so any chain's ratio decomposes.
    pred_shape    — value-domain shape for pred-bearing chains (DESIGN.md
                    §9); defaults to x.shape, so a 2-D array reaches
                    `lorenzo` as its plane even though the wire is flat.
    """
    if wire not in ("host", "device", "both"):
        raise ValueError(f"wire must be host|device|both, got {wire!r}")
    host = device = None
    if wire in ("host", "both"):
        if stream is None:
            stream = serialize(x, cfg, level)
        host = x.nbytes / len(stream)
    if wire in ("device", "both"):
        import jax.numpy as jnp                      # lazy: jax import
        pipe = _device_pipeline(cfg, pipeline)
        xj = jnp.asarray(x)
        if pred_shape is None:
            pred_shape = tuple(x.shape)
        if per_stage:
            rows = pipe.stage_report(xj, pred_shape=pred_shape)
            device = [(label, x.nbytes * 8 / float(bits))
                      for label, bits in rows[1:]]
        else:
            enc = pipe.encode(xj, pred_shape=pred_shape)
            device = x.nbytes / (float(pipe.wire_bits(enc, x.size)) / 8)
    if wire == "host":
        return host
    if wire == "device":
        return device
    return host, device
