"""The Transport API (DESIGN.md §8) — ONE choke point for every
compressed wire that crosses a mesh axis.

The paper's guarantee is an end-to-end property: encode -> transmit ->
decode must return every value within eb of its original or bit-for-bit
identical.  Before this module the "transmit" leg was scattered — the
gradient wire hand-rolled five `lax.all_gather` calls over `Encoded`
fields, the KV migration wire did its own pytree-map gather, and serving
moved raw f32 pages.  `Transport` centralizes all of it:

    all_gather(wire, axis)           pytree-aware gather of any wire form
                                     (Encoded, CompressedShard, PackedKV)
    reduce_sum / reduce_mean(...)    the compressed-gradient collective:
                                     a packed-domain ring (lax.ppermute)
                                     when the shards are grid-compatible,
                                     else gather+dequantize+reduce —
                                     bit-identical either way (§8)
    send_pages(wire, src, dst, axis) point-to-point wire movement
                                     (prefill→decode KV disaggregation)
    bytes_moved(wire, op=...)        transmitted-byte accounting for a
                                     whole collective, derived from
                                     `wire_bytes` below

`wire_bytes(wire)` is the single transmitted-bytes accessor all three
former accountings (`CompressedShard.nbytes`, `PackedKV.wire_nbytes`,
the pre-pipeline `lc_wire_bytes`) now route through, so reported and
shipped bytes cannot drift between layers.

PACKED-DOMAIN REDUCE (the §8 compatibility rule).  `reduce_sum` may
reduce in the packed domain — a ring over `lax.ppermute` whose hop
payload is the §4 uint32 word plane, with bins accumulated as integers
and dequantized ONCE at the end — exactly when the result is provably
bit-identical to the gather+dequantize+reduce reference:

  * static:  the chain is ABS with no word stages (linear dequant, no
             data-dependent payload), the axis size p is statically
             known, and p * maxbin < 2^24 (every partial sum of bins is
             an exact f32 multiple of the pow2 step eb2);
  * runtime (pmax/pmin-agreed so all pods branch together): every pod
             quantized on the SAME grid (bit-equal per-tensor eb) and
             no pod has outliers (the exact-payload scatter is empty).

Under those conditions sum_i(bins_i) * eb2 and sum_i(bins_i * eb2) are
the same exactly-representable real number in any summation order, so
the branch cannot change a single bit — pinned by tests/test_transport.
Everything else (REL/NOA, staged chains, mixed grids, outliers) takes
the gather path, which IS the pre-transport reference code path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import audit as A
from . import codec as C
from .pipeline import Encoded, Pipeline
from .quantizer import dequantize_abs
from .select import SelectedWire


def axis_size_static(axis) -> int | None:
    """Static size of a named mesh axis (needed to build a ring perm), or
    None when this JAX cannot resolve it — callers fall back to gather."""
    try:
        size = jax.lax.axis_size(axis)                 # newer JAX
    except (AttributeError, NameError):
        try:
            from jax.core import axis_frame            # 0.4.x: returns int
            size = axis_frame(axis)
        except Exception:
            return None
    return int(size) if isinstance(size, int) else None


# ------------------------------------------------------ byte accounting ---

def _kv_wire_bytes(wire):
    """Per-page accounting for a PackedKV-shaped wire (payload /
    payload_len / stages / eb2 / outlier table / overflow).  Traced when a
    stage is length-variable; +4/page for the transmitted length itself.
    Per page each stage costs its header CONTENT bits only — not the
    tile-padded stored plane (zeros the receiver re-pads).

    BITS accumulate across stages and pages and divide ONCE at the end —
    flooring each stage's content to bytes per page dropped sub-byte
    headers and drifted from `Pipeline.wire_bits` (which sums bits).
    The traced payload word count sums as exact int32 through the
    shared `codec.transmitted_bits` accounting (see its docstring for
    the precision envelope), where the old per-page f32 sum silently
    rounded past 2^24 total words."""
    cap = wire.payload.shape[-1]
    n_pages = wire.payload_len.size
    checksum_bits = 32 if getattr(wire, "checksum", None) is not None else 0
    sel = getattr(wire, "select", None)
    if sel is not None:
        # §11 per-page selection: each page transmits a 1-byte chain id
        # and its own length, and pays the CHOSEN fragment's header
        # content — dispatched per page on the transmitted ids
        hcb = jnp.asarray([sel.header_content_bits(i, cap)
                           for i in range(len(sel.chains))], jnp.int32)
        chain_ids = wire.chain_id.reshape(-1).astype(jnp.int32)
        hdr_bits = jnp.sum(jnp.take(hcb, chain_ids)).astype(jnp.float32)
        static_bits = n_pages * (8 + 32) + checksum_bits
        static_bits += (wire.eb2.size * 32 + wire.out_idx.size * 32
                        + wire.out_val.size * 32 + wire.overflow.size * 8)
        words = jnp.sum(wire.payload_len.astype(jnp.int32))
        return (C.transmitted_bits(words, static_bits) + hdr_bits) / 8.0
    static_bits = checksum_bits + n_pages * sum(st.header_content_bits(cap)
                                                for st in wire.stages)
    # per-page pred stages (§9) transmit their header content too — zero
    # for the shipped static bijections, but the slot keeps this accessor
    # bit-exact against Pipeline.wire_bits for any future predictor
    static_bits += n_pages * sum(st.header_content_bits()
                                 for st in getattr(wire, "pred", ()))
    static_bits += (wire.eb2.size * 32 + wire.out_idx.size * 32
                    + wire.out_val.size * 32 + wire.overflow.size * 8)
    if wire.stages and wire.stages[-1].transmits_len:
        static_bits += n_pages * 32            # the transmitted lengths
        words = jnp.sum(wire.payload_len.astype(jnp.int32))
        return C.transmitted_bits(words, static_bits) / 8.0
    bits = static_bits + 32 * wire.payload.size
    return bits // 8 if bits % 8 == 0 else bits / 8.0


def wire_bytes(wire, *, pipe: Pipeline | None = None, n: int | None = None):
    """Transmitted bytes of ONE wire object — the single accounting
    accessor (DESIGN.md §8).  Dispatches on the wire form:

      * `Encoded` + its `pipe` (and element count `n`): the pipeline's
        transmitted-prefix accounting (`Pipeline.wire_bytes`);
      * a shard carrying its own pipe/n (`CompressedShard`): same, using
        the carried statics;
      * a PackedKV-shaped per-page wire: the per-page chunk accounting;
      * a NamedTuple of wires (e.g. `models.serve.PackedCache`): the sum
        of its fields;
      * a list/tuple of wires (a streamed page sequence — the engine's
        per-page migration ledger, DESIGN.md §10): the sum of its items;
      * a raw array: moves at full width (`size * itemsize`).

    Static int for static chains, traced scalar when a length-variable
    stage makes the payload data-dependent."""
    if isinstance(wire, Encoded):
        if pipe is None:
            raise TypeError("wire_bytes(Encoded) needs pipe= (and n=)")
        return pipe.wire_bytes(wire, n)
    if isinstance(wire, SelectedWire):
        # §11 selector wire: the selected chain's own accounting plus
        # the transmitted chain-id byte, dispatched on the chain id
        if pipe is None or n is None:
            raise TypeError("wire_bytes(SelectedWire) needs pipe= and n=")
        return pipe.wire_bytes(wire, n)
    if isinstance(getattr(wire, "enc", None), (Encoded, SelectedWire)):
        return wire.pipe.wire_bytes(wire.enc, wire.n if n is None else n)
    if hasattr(wire, "eb2") and hasattr(wire, "payload"):
        return _kv_wire_bytes(wire)
    if hasattr(wire, "_fields") or isinstance(wire, (list, tuple)):
        total = 0
        for field in wire:
            total = total + wire_bytes(field)
        return total
    if hasattr(wire, "dtype") and hasattr(wire, "size"):
        return wire.size * wire.dtype.itemsize
    raise TypeError(f"wire_bytes cannot account a {type(wire).__name__}")


# ------------------------------------------------------------ transport ---

@dataclasses.dataclass(frozen=True)
class Transport:
    """Moves compressed wires across mesh axes.  Stateless and hashable;
    `TRANSPORT` below is the default instance consumers share.

    reduce: 'auto' takes the packed-domain ring whenever the §8
    compatibility rule allows (runtime-agreed, bit-identical); 'gather'
    pins the gather+dequantize+reduce reference path unconditionally.

    fault: TEST-ONLY in-graph corruption hook (DESIGN.md §12): applied
    to every received wire pytree right after the collective, BEFORE
    any verify — the fault-injection harness (`runtime.guard`) uses it
    to prove the receive-side checks catch in-flight corruption.  Must
    be a hashable callable (wire) -> wire; None in production.
    """
    reduce: str = "auto"               # 'auto' | 'gather'
    fault: Callable | None = None      # §12 test-only corruption hook

    def __post_init__(self):
        if self.reduce not in ("auto", "gather"):
            raise ValueError(f"reduce must be 'auto' or 'gather', "
                             f"got {self.reduce!r}")

    # --- collectives ------------------------------------------------------

    def _verify_received(self, wire, verify, what: str):
        """Shared §12 receive-side check: verify=None passes the wire
        through untouched (and unchecked); 'mask' appends per-shard
        verdicts from the carried checksums — (wire, bool[axis_size]);
        'raise' checks host-side and raises `WireIntegrityError` (eager
        only — inside jit/shard_map use 'mask' and route the verdicts to
        a degradation policy in-graph)."""
        if verify is None:
            return wire
        ok = A.verify_gathered(wire)
        if verify == "mask":
            return wire, ok
        if verify == "raise":
            if isinstance(ok, jax.core.Tracer):
                raise ValueError(
                    f"{what}: verify='raise' needs eager execution; use "
                    f"verify='mask' inside jit/shard_map (DESIGN.md §12)")
            if not bool(jnp.all(ok)):
                raise A.WireIntegrityError(
                    f"{what}: received wire failed its integrity "
                    f"checksum (shard mask {ok.tolist()})")
            return wire
        raise ValueError(f"verify must be None, 'mask' or 'raise', "
                         f"got {verify!r}")

    def all_gather(self, wire, axis, *, verify=None):
        """All-gather any wire pytree over a mesh axis (call inside
        shard_map); every array leaf grows a leading axis of the axis
        size.  Static metadata (pipelines, stage chains) rides in the
        pytree aux data untouched.  `verify` (§12) checks each received
        shard's carried checksum: 'mask' returns (gathered, bool[p]),
        'raise' raises eagerly on any mismatch — requires wires encoded
        with integrity=True."""
        gathered = jax.tree.map(lambda a: jax.lax.all_gather(a, axis), wire)
        if self.fault is not None:
            gathered = self.fault(gathered)
        return self._verify_received(gathered, verify, "all_gather")

    def _ring_ok(self, pipe: Pipeline, qc, p) -> bool:
        # Pred chains never ring-reduce: the wire carries folded residual
        # codes, and the delta of a sum is not the sum of the deltas once
        # each shard folds independently — decode-then-sum is the only
        # exact path (DESIGN.md §9), so they take the gather branch.
        # Selector wires (§11) likewise: each shard picked its own chain,
        # so the word planes are not grid-aligned across pods.
        return (self.reduce == "auto" and isinstance(pipe, Pipeline)
                and qc.mode == "abs"
                and not pipe.stages and not pipe.pred
                and p is not None and p > 1
                and p * qc.maxbin < (1 << 24))

    def _ring_compat(self, enc, axis):
        # runtime agreement: same pow2 grid everywhere + no outliers
        # anywhere (NaN eb compares unequal -> gather, like any mismatch)
        compat = jax.lax.pmax(enc.n_outliers, axis) == 0
        if enc.eb is not None:
            eb_hi = jax.lax.pmax(enc.eb, axis)
            eb_lo = -jax.lax.pmax(-enc.eb, axis)
            compat = compat & (eb_hi == eb_lo)
        return compat

    def _check_integrity_arg(self, enc, integrity: str):
        """Host-side validation for the checked reduce (§12): the policy
        must exist, be expressible in-graph ('drop' is the only one — a
        traced collective cannot raise or re-request), and the wire must
        carry a checksum for the gather fallback's per-shard verdicts."""
        A.get_policy(integrity)            # fail fast on unknown names
        if integrity != "drop":
            raise ValueError(
                f"reduce integrity={integrity!r}: in-graph reduction "
                f"supports only the 'drop' policy (mask + renormalize); "
                f"route 'raise'/'rerequest' host-side via "
                f"all_gather(verify='mask') (DESIGN.md §12)")
        if not A.has_checksum(enc):
            raise ValueError(
                "reduce with integrity= needs encode(integrity=True) "
                "wires — no checksum carried (DESIGN.md §12)")

    def reduce_sum(self, enc: Encoded, pipe: Pipeline, n: int, axis, *,
                   integrity: str | None = None):
        """Sum of every pod's decoded tensor over `axis` (call inside
        shard_map).  Ring-reduces in the packed domain when the §8
        compatibility rule holds (checked statically + runtime-agreed via
        pmax/pmin so all pods branch together); otherwise — and always
        with reduce='gather' — gathers the wires and sums the per-pod
        decodes, the pre-transport reference path.  Bit-identical either
        way.

        `integrity='drop'` (§12) verifies every received contribution —
        per-hop `plane_checksum`s on the ring (each hop payload rides
        with its owner's digest, so corruption at ANY hop is caught by
        every downstream rank), per-shard wire checksums on the gather
        path — and drops failed contributions from the sum.  Requires
        encode(integrity=True) wires.  NOTE: the dropped-shard sum is a
        partial sum; use `reduce_mean` for the renormalized mean."""
        if integrity is None:
            qc = pipe.qcfg()
            p = axis_size_static(axis)
            if not self._ring_ok(pipe, qc, p):
                return self._gather_sum(enc, pipe, n, axis)
            return jax.lax.cond(
                self._ring_compat(enc, axis),
                lambda _: self._ring_sum(enc, qc, n, axis, p),
                lambda _: self._gather_sum(enc, pipe, n, axis),
                None)
        total, _ = self._reduce_checked(enc, pipe, n, axis, integrity)
        return total

    def reduce_mean(self, enc: Encoded, pipe: Pipeline, n: int, axis, *,
                    integrity: str | None = None, return_valid: bool = False):
        """reduce_sum / axis_size — the compressed-mean collective.

        `integrity='drop'` (§12): failed contributions (hop-corrupt ring
        payloads, checksum-failed gathered shards) are dropped and the
        mean renormalizes over the contributions THIS rank verified —
        the `compressed_mean` drop semantics applied to the collective.
        Each rank divides by its own valid count, so ranks downstream of
        a corrupt link degrade independently instead of silently
        averaging garbage.  `return_valid=True` appends the per-rank
        valid-contribution count (int32; == axis size on a clean run) —
        the observable `benchmarks/audit_bench.py`'s ring detection row
        pins."""
        if integrity is None:
            p = jax.lax.psum(1, axis)      # axis size (old-JAX compatible)
            mean = self.reduce_sum(enc, pipe, n, axis) / p
            return (mean, jax.lax.psum(jnp.int32(1), axis)) \
                if return_valid else mean
        total, n_valid = self._reduce_checked(enc, pipe, n, axis, integrity)
        mean = total / jnp.maximum(n_valid, 1).astype(total.dtype)
        return (mean, n_valid) if return_valid else mean

    def send_pages(self, wire, src: int, dst: int, axis, *, verify=None):
        """Point-to-point: move a wire pytree from mesh rank `src` to
        `dst` along `axis` (call inside shard_map).  Rank `dst` receives
        `src`'s arrays bit-for-bit; every other rank receives zeros
        (ppermute semantics) — callers select the destination shard.
        This is the prefill→decode KV migration primitive: only the wire
        arrays cross the link, never a dequantized plane.

        `verify='mask'` (§12) appends the received wire's checksum
        verdict (a 0-d bool per shard — only rank `dst`'s verdict is
        meaningful; the other ranks verify ppermute's zero fill)."""
        perm = [(src, dst)]
        moved = jax.tree.map(
            lambda a: jax.lax.ppermute(a, axis, perm), wire)
        if self.fault is not None:
            moved = self.fault(moved)
        if verify is None:
            return moved
        ok = A.verify_wire(moved)
        if verify == "mask":
            return moved, ok
        if verify == "raise":
            if isinstance(ok, jax.core.Tracer):
                raise ValueError(
                    "send_pages: verify='raise' needs eager execution; "
                    "use verify='mask' inside jit/shard_map")
            if not bool(ok):
                raise A.WireIntegrityError(
                    "send_pages: received wire failed its integrity "
                    "checksum")
            return moved
        raise ValueError(f"verify must be None, 'mask' or 'raise', "
                         f"got {verify!r}")

    # --- reduce internals -------------------------------------------------

    def _gather_sum(self, enc, pipe, n, axis):
        # the reference path: gather every pod's wire, run the pipeline's
        # exact inverse per pod, sum.  Ops and order match the
        # pre-transport compressed_mean gather/dequant exactly (pinned by
        # tests/test_transport.py), so the refactor cannot move a bit.
        enc_all = self.all_gather(enc, axis)
        dec = jax.vmap(lambda e: pipe.decode(e, n=n, kernels=False))(enc_all)
        return jnp.sum(dec, axis=0)

    def _ring_sum(self, enc, qc, n, axis, p: int):
        # packed-domain ring: each hop moves the §4 word plane (bin_bits
        # per value) to the next rank; bins accumulate as exact int32 and
        # dequantize ONCE.  Valid only under the §8 compatibility rule —
        # reduce_sum guards it; do not call directly without those checks.
        perm = [(i, (i + 1) % p) for i in range(p)]
        total = C.unpack_words(enc.payload, n, qc.bin_bits)
        cur = enc.payload
        for _ in range(p - 1):
            cur = jax.lax.ppermute(cur, axis, perm)
            total = total + C.unpack_words(cur, n, qc.bin_bits)
        return dequantize_abs(total, qc, eb=enc.eb, dtype=jnp.float32)

    def _reduce_checked(self, enc, pipe, n, axis, integrity: str):
        # the §12 verified reduce: -> (masked sum, per-rank valid count).
        # Both branches of the cond return the same (f32[n], int32) pair.
        self._check_integrity_arg(enc, integrity)
        qc = pipe.qcfg()
        p = axis_size_static(axis)
        if not self._ring_ok(pipe, qc, p):
            return self._gather_sum_checked(enc, pipe, n, axis)
        return jax.lax.cond(
            self._ring_compat(enc, axis),
            lambda _: self._ring_sum_checked(enc, qc, n, axis, p),
            lambda _: self._gather_sum_checked(enc, pipe, n, axis),
            None)

    def _gather_sum_checked(self, enc, pipe, n, axis):
        # gather fallback of the verified reduce: per-shard whole-wire
        # checksum verdicts mask the per-pod decodes out of the sum.
        enc_all, ok = self.all_gather(enc, axis, verify="mask")
        dec = jax.vmap(lambda e: pipe.decode(e, n=n, kernels=False))(enc_all)
        mask = ok.reshape((-1,) + (1,) * (dec.ndim - 1))
        total = jnp.sum(jnp.where(mask, dec, jnp.zeros((), dec.dtype)),
                        axis=0)
        return total, jnp.sum(ok.astype(jnp.int32))

    def _ring_sum_checked(self, enc, qc, n, axis, p: int):
        # verified ring (§12): the hop wire is (payload, owner digest) —
        # the digest is `audit.plane_checksum` computed ONCE by the
        # plane's owner and ppermuted alongside through every hop, so a
        # flip introduced at ANY link poisons the recomputed fold at
        # every downstream rank (the whole-wire checksum never sees
        # intermediate hops).  Failed hops are masked out of the int32
        # bin accumulation and the valid count; own bins always count.
        perm = [(i, (i + 1) % p) for i in range(p)]
        total = C.unpack_words(enc.payload, n, qc.bin_bits)
        cur, cs = enc.payload, A.plane_checksum(enc.payload)
        n_valid = jnp.int32(1)
        for _ in range(p - 1):
            cur = jax.lax.ppermute(cur, axis, perm)
            cs = jax.lax.ppermute(cs, axis, perm)
            if self.fault is not None:     # §12 hook: corrupt the hop pair
                cur, cs = self.fault((cur, cs))
            ok = A.plane_checksum(cur) == cs
            bins = C.unpack_words(cur, n, qc.bin_bits)
            total = total + jnp.where(ok, bins, jnp.zeros((), bins.dtype))
            n_valid = n_valid + ok.astype(jnp.int32)
        return (dequantize_abs(total, qc, eb=enc.eb, dtype=jnp.float32),
                n_valid)

    # --- accounting -------------------------------------------------------

    def bytes_moved(self, wire, *, op: str = "all_gather",
                    axis_size: int = 1, pipe: Pipeline | None = None,
                    n: int | None = None):
        """Total bytes a collective moves across the axis, from the
        single `wire_bytes` accessor:

          op='send_pages'   one copy of the wire (src -> dst);
          op='all_gather'   every member ships its wire to the other
                            p - 1 members: p * (p - 1) * wire_bytes;
          op='reduce_sum' / 'reduce_mean'
                            the gather-path bound (== all_gather).  When
                            the §8 ring fires it moves only the word
                            plane per hop — strictly less; this reports
                            the path that is always available.
        """
        w = wire_bytes(wire, pipe=pipe, n=n)
        if op == "send_pages":
            return w
        if op in ("all_gather", "reduce_sum", "reduce_mean"):
            if axis_size < 2:
                # p*(p-1)*w would silently report 0 bytes for a
                # degenerate axis — demand the real size instead
                raise ValueError(
                    f"bytes_moved(op={op!r}) needs axis_size >= 2, "
                    f"got {axis_size}")
            return axis_size * (axis_size - 1) * w
        raise ValueError(f"unknown op {op!r}")


TRANSPORT = Transport()
