"""LC-style composable pipeline API (DESIGN.md §7, value stages §9).

The paper's LC framework is a *chain of interchangeable components* — a
quantizer followed by lossless stages.  This module exposes that chain as
one object instead of forked per-combination surfaces: a `Pipeline`
parsed from a spec string like

    "delta|rel:1e-3|pack:8|zero|narrow"

is any number of value-domain predictor stages (`core.predict`, applied
closed-loop around the quantizer — DESIGN.md §9), a quantizer stage, a
bit-pack stage, and any number of registered lossless *word stages*,
each transforming the packed uint32 word stream
exactly and reversibly.  Encoding produces one `Encoded` wire container
(final payload plane + per-stage header planes + transmitted lengths +
the capped exact-outlier table); `Pipeline.wire_bits` counts exactly the
transmitted prefix — never capacity padding — so the accounting matches
the pre-pipeline `EncodedPacked.wire_bits` / `EncodedLC.wire_bits` bit
for bit on the chains both can express.

Stage contract (`WordStage`): pure jit-safe pytree functions with STATIC
capacities —

    capacity_words(n_in)        static output capacity for an n_in stream
    header_words(n_in)          static stored header-plane size (0 = none)
    header_content_bits(n_in)   transmitted header bits (pad excluded)
    transmits_len               True if the output length is data-dependent
    encode_words(words, n_in)   -> (header, out[capacity], out_len)
    decode_words(header, payload, n_in) -> words[n_in]   (exact inverse)

Registered stages (see STAGES / DESIGN.md §7):

    zero, narrow  — the §6 chunked coder (`core.codec.encode_words_lc`)
    shuffle[:w]   — zigzag sign-fold + byte-plane shuffle
                    (`core.codec.shuffle_words`); w defaults to the pack
                    width
    ent           — static canonical entropy coder over surviving
                    chunks, codebook in the header plane
                    (`core.codec.encode_words_ent`)

Kernel dispatch: known chains map onto the existing fused Pallas kernels
(`kernels/pack.py`, `kernels/lossless.py`), anything else runs the jit
reference — bit-identical either way (the kernels are bit-exact twins by
test), so the §1 guarantee is untouched by dispatch.

    chain                         fused kernel
    quant|pack                    kernels.pack.encode_packed
    quant|pack|zero or |narrow    kernels.lossless.encode_packed_lc
    pred|...                      jit reference (open slot, DESIGN.md §9)
    anything else                 jit reference (core.codec)

`kernels=None` (auto) uses the fused path only on a real TPU backend;
tests force it with `kernels=True, interpret=True`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import audit as A
from . import codec as C
from . import predict as P
from .config import QuantizerConfig

_QUANT_MODES = ("abs", "rel", "noa")
_CAP_DEFAULT = 0.125          # QuantizerConfig.outlier_cap_frac default

# The two-domain spec grammar (DESIGN.md §9): value-domain pred stages
# lead, then the quantizer, the packer, and word-domain stages.
GRAMMAR = ('pipeline = { pred-stage "|" } quant:<eb> "|" pack:<bits> '
           '{ "|" word-stage }')


class Encoded(NamedTuple):
    """The one wire container every pipeline produces.

    `payload` is the FINAL word plane, padded to static capacity when any
    stage is length-variable; `payload_len` is the transmitted word count
    (a constant for static chains).  `headers` holds one stored header
    plane per word stage, in chain order (shape (0,) for headerless
    stages), so gathers and vmaps stay structurally uniform.  The outlier
    table and sign plane are exactly the §4 ones — no stage may touch
    them.  Wire accounting lives on the Pipeline (`Pipeline.wire_bits`),
    which knows each stage's transmitted header content.  `checksum` is
    the OPT-IN §12 integrity digest (encode with integrity=True): an
    extra aux plane over the transmitted fields, never inside them, so
    checksum-free wires stay bit-identical to pre-§12 encodes.
    """
    payload: jnp.ndarray          # uint32[capacity] — final word plane
    payload_len: jnp.ndarray      # int32 scalar — words a transport moves
    headers: tuple                # per-stage uint32 header planes
    out_idx: jnp.ndarray          # int32[K], n = "empty slot"
    out_payload: jnp.ndarray      # uint32[K] — original IEEE bits
    n_outliers: jnp.ndarray       # int32 scalar
    overflow: jnp.ndarray         # bool scalar (bound NOT met when True)
    sign_words: jnp.ndarray | None  # uint32 (REL only)
    eb: jnp.ndarray | None        # traced scalar bound
    checksum: jnp.ndarray | None = None  # uint32 scalar (§12, integrity=True)


def _fmt(v: float) -> str:
    """Canonical float printing for specs (shortest roundtrip repr)."""
    return repr(float(v))


# ---------------------------------------------------------------- stages --

@dataclasses.dataclass(frozen=True)
class QuantStage:
    """Quantizer front end: mode + error bound (+ outlier-cap fraction)."""
    mode: str = "abs"
    eb: float = 1e-3
    cap: float = _CAP_DEFAULT
    dtype: str = "float32"

    def spec(self) -> str:
        s = f"{self.mode}:{_fmt(self.eb)}"
        if self.cap != _CAP_DEFAULT:
            s += f":cap={_fmt(self.cap)}"
        if self.dtype != "float32":
            s += f":dtype={self.dtype}"
        return s


@dataclasses.dataclass(frozen=True)
class PackStage:
    """Bit-pack stage: bins -> uint32 lane words at `bits`/value (§4)."""
    bits: int = 16

    def spec(self) -> str:
        return f"pack:{self.bits}"


@dataclasses.dataclass(frozen=True)
class ChunkStage:
    """The §6 chunked zero/narrow coder as a word stage."""
    mode: str = "narrow"          # 'zero' | 'narrow'
    transmits_len = True

    def capacity_words(self, n_in: int) -> int:
        return C.lc_chunk_count(n_in) * C.LC_CHUNK

    def header_words(self, n_in: int) -> int:
        return C.lc_header_words(n_in)

    def header_content_bits(self, n_in: int) -> int:
        return 32 * C.lc_header_content_words(C.lc_chunk_count(n_in))

    def encode_words(self, words, n_in: int):
        return C.encode_words_lc(words, self.mode)

    def decode_words(self, header, payload, n_in: int):
        return C.decode_words_lc(header, payload, n_in)

    def spec(self) -> str:
        return self.mode


@dataclasses.dataclass(frozen=True)
class EntStage:
    """Static canonical entropy coder over surviving 512-word chunks
    (codec.encode_words_ent, DESIGN.md §7): a cuSZ-style codebook built
    from the byte histogram of the non-zero chunks rides in the header
    plane (4-bit canonical code lengths + 2-bit chunk modes + 16-bit
    chunk bit lengths); each surviving chunk encodes independently as a
    variable-length bitstream with a verbatim escape, so the stage never
    costs more than its header content.  Length-variable: the payload is
    carried padded to capacity with the transmitted word count (§6
    pattern)."""
    transmits_len = True

    def capacity_words(self, n_in: int) -> int:
        return C.lc_chunk_count(n_in) * C.LC_CHUNK

    def header_words(self, n_in: int) -> int:
        return C.ent_header_words(n_in)

    def header_content_bits(self, n_in: int) -> int:
        return 32 * C.ent_header_content_words(C.lc_chunk_count(n_in))

    def encode_words(self, words, n_in: int):
        return C.encode_words_ent(words)

    def decode_words(self, header, payload, n_in: int):
        return C.decode_words_ent(header, payload, n_in)

    def spec(self) -> str:
        return "ent"


@dataclasses.dataclass(frozen=True)
class ShuffleStage:
    """Zigzag sign-fold + byte-plane shuffle (codec.shuffle_words): makes
    the §6 width codes fire on mixed-sign bin streams.  Headerless and
    length-static; `width` must be the lane width of the incoming words
    (the pack width when placed right after `pack`)."""
    width: int = 16
    transmits_len = False

    def capacity_words(self, n_in: int) -> int:
        return C.shuffle_word_count(n_in)

    def header_words(self, n_in: int) -> int:
        return 0

    def header_content_bits(self, n_in: int) -> int:
        return 0

    def encode_words(self, words, n_in: int):
        out = C.shuffle_words(words, self.width)
        return (jnp.zeros((0,), jnp.uint32), out,
                jnp.int32(self.capacity_words(n_in)))

    def decode_words(self, header, payload, n_in: int):
        return C.unshuffle_words(payload, n_in, self.width)

    def spec(self) -> str:
        return f"shuffle:{self.width}"


# ------------------------------------------------------- stage registry ---

def _parse_params(tokens):
    """Split stage arg tokens into (positional list, {key: value})."""
    pos, kw = [], {}
    for t in tokens:
        if "=" in t:
            k, v = t.split("=", 1)
            kw[k] = v
        else:
            pos.append(t)
    return pos, kw


def _parse_chunk(name, tokens):
    if tokens:
        raise ValueError(f"stage {name!r} takes no parameters")
    return ChunkStage(name)


def _parse_shuffle(name, tokens, *, pack_bits):
    pos, kw = _parse_params(tokens)
    if kw or len(pos) > 1:
        raise ValueError("shuffle takes at most one positional width")
    width = int(pos[0]) if pos else pack_bits
    if width not in (8, 16, 32):
        raise ValueError(f"shuffle width must be 8, 16 or 32, got {width}")
    return ShuffleStage(width)


def _parse_ent(name, tokens):
    if tokens:
        raise ValueError(f"stage {name!r} takes no parameters")
    return EntStage()


# name -> parser(name, arg_tokens, pack_bits=...) -> WordStage instance.
# Adding a stage = one class + one entry here (+ a DESIGN.md §7 row).
STAGES = {
    "zero": lambda name, tokens, pack_bits: _parse_chunk(name, tokens),
    "narrow": lambda name, tokens, pack_bits: _parse_chunk(name, tokens),
    "shuffle": lambda name, tokens, pack_bits: _parse_shuffle(
        name, tokens, pack_bits=pack_bits),
    "ent": lambda name, tokens, pack_bits: _parse_ent(name, tokens),
}


def register_stage(name: str, parser) -> None:
    """Register a word stage: parser(name, arg_tokens, pack_bits) -> stage."""
    STAGES[name] = parser


def _unknown_stage_error(tok: str) -> ValueError:
    """Unknown spec token: name every registered stage in BOTH domains
    plus the grammar, so a misplaced stage (a pred token after the
    quantizer, a word token ahead of it) diagnoses itself."""
    return ValueError(
        f"unknown stage {tok!r}; registered value-domain (pred) stages: "
        f"{sorted(P.PRED_STAGES)}; quantizers: {sorted(_QUANT_MODES)}; "
        f"registered word-domain stages: {sorted(STAGES)}; "
        f"grammar: {GRAMMAR}")


def parse_word_stages(stages, pack_bits: int) -> tuple:
    """Resolve a word-stage chain: a tuple of stage objects passes
    through; a spec fragment ("narrow", "shuffle|narrow", "", "none")
    parses via the STAGES registry — the single parser both full
    pipeline specs and per-plane callers (compression/kv.py) share."""
    if isinstance(stages, tuple):
        return stages
    out = []
    for part in str(stages).split("|"):
        part = part.strip()
        if not part or part == "none":
            continue
        tok = part.split(":")
        if tok[0] not in STAGES:
            raise _unknown_stage_error(tok[0])
        out.append(STAGES[tok[0]](tok[0], tok[1:], pack_bits))
    return tuple(out)


# ------------------------------------------------- word-stage chain ops ---

def word_stage_sizes(stages, n_words: int) -> list:
    """[words into stage 0, into stage 1, ..., final capacity] (static)."""
    sizes = [n_words]
    for st in stages:
        sizes.append(st.capacity_words(sizes[-1]))
    return sizes


def encode_word_stages(stages, words, n_words: int):
    """Run a word-stage chain over a packed plane (reusable on any word
    stream — gradient shards, KV pages).  Returns (headers tuple,
    payload, transmitted_len)."""
    headers, cur, cur_n = [], words, n_words
    plen = jnp.int32(n_words)
    for st in stages:
        hdr, cur, plen = st.encode_words(cur, cur_n)
        headers.append(hdr)
        cur_n = st.capacity_words(cur_n)
    return tuple(headers), cur, plen


def decode_word_stages(stages, headers, payload, n_words: int):
    """Exact inverse of encode_word_stages."""
    sizes = word_stage_sizes(stages, n_words)
    cur = payload
    for st, hdr, n_in in reversed(list(zip(stages, headers, sizes[:-1]))):
        cur = st.decode_words(hdr, cur, n_in)
    return cur


# -------------------------------------------------------------- pipeline --

@dataclasses.dataclass(frozen=True)
class Pipeline:
    """One LC chain: pred stages -> quantizer -> pack -> word stages.
    Hashable (usable as a jit static argument); `parse_pipeline` /
    `spec()` roundtrip.  `pred` holds value-domain predictor stages
    (core.predict, DESIGN.md §9): exact bijections on the quantized bin
    plane, applied after the quantizer on encode and inverted before
    dequantize on decode, so the §1 guarantee is inherited unchanged."""
    quant: QuantStage
    pack: PackStage
    stages: tuple = ()
    pred: tuple = ()

    def spec(self) -> str:
        return "|".join([p.spec() for p in self.pred]
                        + [self.quant.spec(), self.pack.spec()]
                        + [s.spec() for s in self.stages])

    def qcfg(self) -> QuantizerConfig:
        return QuantizerConfig(mode=self.quant.mode,
                               error_bound=self.quant.eb,
                               bin_bits=self.pack.bits,
                               dtype=self.quant.dtype,
                               outlier_cap_frac=self.quant.cap)

    # --- stage-size bookkeeping (all static ints) -------------------------

    def n_words(self, n: int) -> int:
        """Packed word count entering the first word stage."""
        return C.packed_word_count(n, self.pack.bits)

    def _word_sizes(self, n_words: int) -> list:
        return word_stage_sizes(self.stages, n_words)

    def stage_sizes(self, n: int) -> list:
        """[words into stage 0, into stage 1, ..., final capacity]."""
        return self._word_sizes(self.n_words(n))

    # --- kernel dispatch --------------------------------------------------

    def kernel_dispatch(self) -> str | None:
        """Dotted name of the fused Pallas entry this chain maps onto, or
        None when encode falls back to the jit reference.  Pred chains
        always take the reference path (encode AND decode) — the fused
        quantize+pack kernels have no bin-transform slot yet; this is the
        open row in the DESIGN.md §7 dispatch table."""
        if self.pred:
            return None
        if not self.stages:
            return "repro.kernels.pack.encode_packed"
        if len(self.stages) == 1 and isinstance(self.stages[0], ChunkStage):
            return "repro.kernels.lossless.encode_packed_lc"
        return None

    @staticmethod
    def _auto_kernels() -> bool:
        return jax.default_backend() == "tpu"

    # --- encode -----------------------------------------------------------

    def encode_words(self, words, n_words: int):
        """Run the word stages only (reusable on any packed plane — KV
        pages, gradient shards).  Returns (headers tuple, payload, len)."""
        return encode_word_stages(self.stages, words, n_words)

    def decode_words(self, headers, payload, n_words: int):
        """Exact inverse of encode_words for the word-stage chain."""
        return decode_word_stages(self.stages, headers, payload, n_words)

    def _wrap_packed(self, ep: C.EncodedPacked, n: int) -> Encoded:
        headers, payload, plen = self.encode_words(ep.words, self.n_words(n))
        return Encoded(payload, plen, headers, ep.out_idx, ep.out_payload,
                       ep.n_outliers, ep.overflow, ep.sign_words, ep.eb)

    # --- pred (value-domain) stage plumbing — DESIGN.md §9 ----------------

    def _pred_shape(self, pred_shape, n: int) -> tuple:
        shape = (n,) if pred_shape is None else tuple(pred_shape)
        if int(np.prod(shape)) != n:
            raise ValueError(f"pred_shape {shape} has {int(np.prod(shape))} "
                             f"elements, tensor has {n}")
        return shape

    def _bin_transform(self, pred_shape, n: int):
        """bins -> codes closure for codec.encode_packed, or None."""
        if not self.pred:
            return None
        shape, bits = self._pred_shape(pred_shape, n), self.pack.bits
        return lambda bins: P.encode_pred_stages(self.pred, bins, shape, bits)

    def _bin_untransform(self, pred_shape, n: int):
        """codes -> bins closure for codec.decode_packed, or None."""
        if not self.pred:
            return None
        shape, bits = self._pred_shape(pred_shape, n), self.pack.bits
        return lambda codes: P.decode_pred_stages(self.pred, codes, shape,
                                                  bits)

    def encode(self, x, eb=None, *, kernels: bool | None = None,
               interpret: bool | None = None, return_quantized: bool = False,
               pred_shape=None, verify: bool = False,
               integrity: bool = False):
        """Encode x through the full chain.  kernels=None dispatches the
        fused Pallas path on TPU and the jit reference elsewhere (bit-
        identical); return_quantized forces the reference quantizer so the
        local outlier/recon planes exist for residual bookkeeping.
        `pred_shape` is the value-domain shape the pred stages see
        (defaults to x.shape) — it lets a flattened stream keep its plane
        structure for `lorenzo`/`kvdelta`.

        §12 audit plane: `verify=True` fuses the decode-and-check audit
        into this pass (it shares the reference quantizer's recon plane,
        so it forces the reference path like return_quantized) and
        appends an `audit.AuditReport` to the return; `integrity=True`
        attaches the 32-bit wire checksum as aux (any dispatch path —
        the covered planes are bit-identical across backends).  Returns
        enc | (enc, qt) | (enc, report) | (enc, qt, report)."""
        n = int(np.prod(x.shape))
        if pred_shape is None:
            pred_shape = tuple(x.shape)
        use_k = (self._auto_kernels() if kernels is None else kernels)
        if use_k and not return_quantized and not verify:
            target = self.kernel_dispatch()
            if target == "repro.kernels.pack.encode_packed":
                from repro.kernels import pack as _kp      # lazy: circular
                ep = _kp.encode_packed(x, self.qcfg(), eb,
                                       interpret=interpret)
                enc = self._wrap_packed(ep, n)
                return A.attach_checksum(enc) if integrity else enc
            if target == "repro.kernels.lossless.encode_packed_lc":
                from repro.kernels import lossless as _kl
                lc = _kl.encode_packed_lc(x, self.qcfg(), eb,
                                          stage=self.stages[0].mode,
                                          interpret=interpret)
                enc = Encoded(lc.payload, lc.payload_len,
                              (lc.header_words,), lc.out_idx,
                              lc.out_payload, lc.n_outliers, lc.overflow,
                              lc.sign_words, lc.eb)
                return A.attach_checksum(enc) if integrity else enc
        ep, qt = C.encode_packed(x, self.qcfg(), eb, return_quantized=True,
                                 bin_transform=self._bin_transform(
                                     pred_shape, n))
        enc = self._wrap_packed(ep, n)
        if integrity:
            enc = A.attach_checksum(enc)
        if verify:
            report = A.audit_report(
                x, qt, self.qcfg(),
                eb=enc.eb if enc.eb is not None else eb,
                overflow=enc.overflow, n_outliers=enc.n_outliers)
            return (enc, qt, report) if return_quantized else (enc, report)
        return (enc, qt) if return_quantized else enc

    # --- decode -----------------------------------------------------------

    def decode(self, enc: Encoded, n: int | None = None, shape=None,
               dtype=None, *, kernels: bool | None = None,
               interpret: bool | None = None, pred_shape=None,
               verify: bool = False):
        """Invert the chain: word stages in reverse, pred stages inverted
        on the bin plane, then unpack + dequantize + exact outlier
        restore.  Bit-identical between the fused-kernel and reference
        back ends.  `pred_shape` must match the encode-side value (it
        defaults to `shape`, falling back to the flat stream).

        §12 guards: a transmitted `payload_len` outside the padded
        plane's [0, capacity] raises `audit.WireIntegrityError` host-side
        (traced lengths are clamped inside the codec's gathers instead);
        `verify=True` re-checks the carried integrity checksum before
        decoding (host-side — raises on mismatch; requires a wire
        encoded with integrity=True)."""
        if n is None:
            if shape is None:
                raise ValueError("decode needs n or shape")
            n = int(np.prod(shape))
        if pred_shape is None and shape is not None:
            pred_shape = tuple(shape)
        A.check_payload_len(enc.payload_len, enc.payload.shape[0],
                            what=f"Encoded[{self.spec()}]")
        if verify:
            ok = A.verify_wire(enc)
            if not isinstance(ok, jax.core.Tracer) and not bool(ok):
                raise A.WireIntegrityError(
                    f"Encoded[{self.spec()}]: checksum mismatch on decode")
        words = self.decode_words(enc.headers, enc.payload, self.n_words(n))
        ep = C.EncodedPacked(words, enc.out_idx, enc.out_payload,
                             enc.n_outliers, enc.overflow, enc.sign_words,
                             enc.eb)
        use_k = (self._auto_kernels() if kernels is None else kernels)
        if use_k and not self.pred:
            from repro.kernels import pack as _kp          # lazy: circular
            return _kp.decode_packed(ep, self.qcfg(), n=n, shape=shape,
                                     dtype=dtype, interpret=interpret)
        return C.decode_packed(ep, self.qcfg(), n=n, shape=shape,
                               dtype=dtype,
                               bin_untransform=self._bin_untransform(
                                   pred_shape, n))

    def roundtrip(self, x, eb=None, **kw):
        return self.decode(self.encode(x, eb, **kw), shape=x.shape, **kw)

    # --- honest wire accounting -------------------------------------------

    def _base_bits(self, enc: Encoded) -> int:
        bits = 64 + enc.out_idx.shape[0] * (32 + 32)
        if enc.sign_words is not None:
            bits += 32 * enc.sign_words.shape[0]
        if enc.checksum is not None:
            bits += 32                             # §12 integrity digest
        # pred stages transmit their header CONTENT here (§9).  Every
        # shipped predictor is a static bijection with zero header bits,
        # but the accounting slot is part of the value-stage contract, so
        # a future parameterized predictor stays bit-exact for free.
        return bits + sum(st.header_content_bits() for st in self.pred)

    def wire_bits(self, enc: Encoded, n: int | None = None):
        """Transmitted wire size in bits: the final payload's transmitted
        prefix, every stage's header CONTENT (tile padding excluded — the
        receiver re-pads), the outlier table, sign plane, and the 64-bit
        packed header (+32 for a transmitted length).  A static int for
        static chains; traced f32 otherwise (exact through 2^24 words —
        see EncodedLC.wire_bits for the rationale).

        Pass `n` (element count) for exact per-stage input sizes; without
        it the final payload capacity is used, which is exact for every
        registered stage (header content depends only on the stage's
        chunk count, recoverable from any tile-aligned capacity — part of
        the stage contract).

        The traced branch routes through `codec.transmitted_bits` —
        exact int32 word accumulation with one f32 conversion (see its
        docstring for the precision envelope); adding f32 bit totals
        instead rounded past 2^24 words."""
        if not self.stages:
            return self._base_bits(enc) + 32 * enc.payload.shape[0]
        if n is not None:
            sizes = self.stage_sizes(n)[:-1]
        else:
            sizes = [enc.payload.shape[0]] * len(self.stages)
        hdr = sum(st.header_content_bits(sz)
                  for st, sz in zip(self.stages, sizes))
        if self.stages[-1].transmits_len:
            return C.transmitted_bits(enc.payload_len,
                                      self._base_bits(enc) + hdr + 32)
        return self._base_bits(enc) + hdr + 32 * enc.payload.shape[0]

    def wire_bytes(self, enc: Encoded, n: int | None = None):
        b = self.wire_bits(enc, n)
        return b // 8 if isinstance(b, int) else b / 8.0

    def capacity_bytes(self, enc: Encoded) -> int:
        """Static upper bound: what a padded all-gather buffer holds."""
        b = (enc.payload.size + enc.out_idx.size + enc.out_payload.size
             + sum(h.size for h in enc.headers)) * 4 + 8
        if enc.sign_words is not None:
            b += enc.sign_words.size * 4
        if enc.checksum is not None:
            b += 4                                 # §12 integrity digest
        if self.stages:
            b += 4                                 # transmitted length field
        return b

    # --- per-stage reporting ----------------------------------------------

    def stage_report(self, x, eb=None, pred_shape=None):
        """[(label, transmitted_bits_after_stage), ...] through the chain,
        starting from the raw tensor.  Reference path (host-callable).
        Pred stages are bijections on the packed plane (zero header bits,
        §9), so they fold into the base row's label — the word-stage rows
        then show what the residual plane actually bought."""
        n = int(np.prod(x.shape))
        if pred_shape is None:
            pred_shape = tuple(x.shape)
        ep, _ = C.encode_packed(x, self.qcfg(), eb, return_quantized=True,
                                bin_transform=self._bin_transform(
                                    pred_shape, n))
        base = self._base_bits(
            Encoded(ep.words, jnp.int32(0), (), ep.out_idx, ep.out_payload,
                    ep.n_outliers, ep.overflow, ep.sign_words, ep.eb))
        base_label = "|".join([p.spec() for p in self.pred]
                              + [self.quant.spec(), self.pack.spec()])
        rows = [("raw", n * np.dtype(self.quant.dtype).itemsize * 8),
                (base_label, base + 32 * ep.words.shape[0])]
        cur, cur_n = ep.words, self.n_words(n)
        hdr_bits = 0
        for st in self.stages:
            _, cur, plen = st.encode_words(cur, cur_n)
            hdr_bits += st.header_content_bits(cur_n)
            cur_n = st.capacity_words(cur_n)
            # mirror wire_bits exactly: +32 (the transmitted length
            # field) only when this prefix's final stage is
            # length-variable, through the same shared accounting
            if st.transmits_len:
                bits = C.transmitted_bits(plen, base + hdr_bits + 32)
            else:
                bits = base + hdr_bits + 32 * cur.shape[0]
            rows.append((st.spec(), float(bits)))
        return rows


# ------------------------------------------------------------ the parser --

def parse_pipeline(spec) -> Pipeline:
    """Parse a pipeline spec string ("delta|abs:1e-3|pack:16|zero|narrow")
    into a Pipeline.  Grammar (GRAMMAR): stages are '|'-separated; each
    stage is name[:arg][:key=value...].  Leading tokens naming registered
    pred stages (predict.PRED_STAGES) form the value-domain chain; the
    next stage must be a quantizer (abs|rel|noa, positional eb, optional
    cap=/dtype=), then pack:<bits>, then registered word stages (STAGES).
    `Pipeline.spec()` is the exact inverse."""
    if isinstance(spec, Pipeline):
        return spec
    parts = [p.strip() for p in str(spec).split("|") if p.strip()]
    pred = []
    while parts and parts[0].split(":")[0] in P.PRED_STAGES:
        tok = parts.pop(0).split(":")
        pred.append(P.PRED_STAGES[tok[0]](tok[0], tok[1:]))
    if len(parts) < 2:
        raise ValueError(
            f"pipeline spec needs at least 'quant:<eb>|pack:<bits>', "
            f"got {spec!r}; grammar: {GRAMMAR}")
    qtok = parts[0].split(":")
    if qtok[0] not in _QUANT_MODES:
        raise _unknown_stage_error(qtok[0])
    pos, kw = _parse_params(qtok[1:])
    if len(pos) != 1:
        raise ValueError(f"quantizer stage needs exactly one error bound, "
                         f"got {parts[0]!r}")
    bad = set(kw) - {"cap", "dtype"}
    if bad:
        raise ValueError(f"unknown quantizer parameters {sorted(bad)}")
    quant = QuantStage(qtok[0], float(pos[0]),
                       float(kw.get("cap", _CAP_DEFAULT)),
                       kw.get("dtype", "float32"))
    ptok = parts[1].split(":")
    if ptok[0] != "pack" or len(ptok) != 2:
        raise ValueError(f"second stage must be 'pack:<bits>', "
                         f"got {parts[1]!r}")
    pack = PackStage(int(ptok[1]))
    if pack.bits not in (8, 16, 32):
        raise ValueError(f"pack bits must be 8, 16 or 32, got {pack.bits}")
    stages = parse_word_stages("|".join(parts[2:]), pack.bits)
    pipe = Pipeline(quant, pack, stages, tuple(pred))
    pipe.qcfg()                       # validate the combination eagerly
    return pipe
