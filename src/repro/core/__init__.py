"""repro.core — guaranteed-error-bound lossy quantizers (the paper's
contribution), as a composable JAX module.

Public API:
    QuantizerConfig             — mode ('abs'|'rel'|'noa'), error bound, widths
    Pipeline / parse_pipeline   — LC-style composable chain + spec strings (§7)
    PRED_STAGES / parse_pred_stages — closed-loop value-domain predictors (§9)
    Encoded                     — the one pipeline wire container (§7)
    Transport / TRANSPORT       — the one compressed-wire mover (§8)
    quantize / Quantized        — bins + outlier flags + recon (jit-safe)
    encode_dense/decode_dense   — fixed-shape codec, outliers stored densely
    encode_compact/decode_compact — capped compact outliers (wire format)
    encode_packed/decode_packed — bins bit-packed into uint32 lanes (§4)
    encode_lossless/decode_lossless — device-side lossless stage (§6)
    shuffle_words/unshuffle_words — zigzag+byte-plane shuffle stage (§7)
    serialize/deserialize       — host byte stream (LC-style inline outliers)
    log2approx/pow2approx       — parity-safe transcendental replacements
    AuditReport / verify_wire / attach_checksum — guarantee-audit plane (§12)
"""
from .audit import (AuditReport, WireIntegrityError, attach_checksum,
                    audit_report, get_policy, register_policy, verify_wire,
                    wire_checksum)
from .bitops import bits_to_float, float_to_bits, log2approx, pow2approx
from .codec import (ENT_MAX_LEN, ENT_SYMS, LC_CHUNK, LC_STAGES,
                    EncodedCompact, EncodedDense, EncodedLC, EncodedPacked,
                    decode_compact, decode_dense, decode_lossless,
                    decode_packed, decode_words_ent, decode_words_lc,
                    encode_compact, encode_dense, encode_lossless,
                    encode_packed, encode_words_ent, encode_words_lc,
                    ent_header_words, lc_chunk_count, lc_header_words,
                    pack_flags, pack_words, packed_word_count,
                    roundtrip_dense, shuffle_word_count, shuffle_words,
                    unpack_flags, unpack_words, unshuffle_words)
from .config import QuantizerConfig
from .pipeline import (GRAMMAR, STAGES, Encoded, Pipeline, parse_pipeline,
                       register_stage)
from .predict import (PRED_STAGES, DeltaStage, KVDeltaStage, LorenzoStage,
                      parse_pred_stages, register_pred_stage)
from .quantizer import (Quantized, dequantize_abs, dequantize_rel, quantize,
                        quantize_abs, quantize_abs_unprotected, quantize_noa,
                        quantize_rel, quantize_rel_library)
from .serializer import compression_ratio, deserialize, serialize
from .transport import TRANSPORT, Transport

__all__ = [
    "QuantizerConfig", "Quantized", "quantize", "quantize_abs", "quantize_rel",
    "quantize_noa", "quantize_abs_unprotected", "quantize_rel_library",
    "dequantize_abs", "dequantize_rel", "encode_dense", "decode_dense",
    "encode_compact", "decode_compact", "encode_packed", "decode_packed",
    "pack_words", "unpack_words", "pack_flags", "unpack_flags",
    "packed_word_count", "roundtrip_dense", "EncodedDense",
    "EncodedCompact", "EncodedPacked", "EncodedLC", "encode_lossless",
    "decode_lossless", "encode_words_lc", "decode_words_lc",
    "lc_chunk_count", "lc_header_words", "LC_CHUNK", "LC_STAGES",
    "encode_words_ent", "decode_words_ent", "ent_header_words",
    "ENT_MAX_LEN", "ENT_SYMS",
    "shuffle_words", "unshuffle_words", "shuffle_word_count",
    "Pipeline", "parse_pipeline", "Encoded", "STAGES", "register_stage",
    "GRAMMAR", "PRED_STAGES", "register_pred_stage", "parse_pred_stages",
    "DeltaStage", "LorenzoStage", "KVDeltaStage",
    "Transport", "TRANSPORT",
    "AuditReport", "WireIntegrityError", "audit_report", "wire_checksum",
    "attach_checksum", "verify_wire", "register_policy", "get_policy",
    "serialize", "deserialize", "compression_ratio",
    "log2approx", "pow2approx", "float_to_bits", "bits_to_float",
]
