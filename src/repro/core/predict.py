"""Closed-loop predictor stages — the value-domain half of the two-domain
pipeline grammar (DESIGN.md §9).

Every high-ratio error-bounded compressor in the survey literature gets
its ratio from a prediction step ahead of quantization (SZ's Lorenzo,
cuSZ's dual-quant delta).  The paper's central lesson applies verbatim:
the predictor must run CLOSED-LOOP — predict from the value the decoder
will reconstruct, never from the raw input — or per-step quantization
error accumulates and silently breaks the §1 bound (the open-loop
regression test in tests/test_predict.py pins the failure).

This module implements closed-loop prediction in the quantized-bin
domain.  The encoder quantizes pointwise first (the §1 guarantee is
decided there and never touched again), then transforms the int32 bin
plane with an exact integer bijection before bit-packing:

    bins --pred.encode_bins--> codes --pack_words--> word plane

Predicting from the previous BIN is predicting from the decoder's view:
``bin[i-1] * eb2`` IS the reconstruction the decoder holds, so the bin
delta equals the closed-loop residual scaled by 1/eb2.  ``scan_reference``
below writes the same computation as the literal per-element
reconstruction-feedback loop; the vectorized stages are pinned
bit-identical to it by test.

Exactness: all arithmetic is two's complement.  A residual is folded to
the pack width ``bits`` (zigzag, so small mixed-sign residuals become
small unsigned codes and the §6/§7 word stages fire); the decoder
integrates in int32 — overflow wraps mod 2^32, which is consistent with
the fold because 2^bits divides 2^32 — and re-wraps to ``bits`` bits.
True bins satisfy |bin| <= maxbin < 2^(bits-1), so the final wrap
recovers them exactly: decode output is BIT-IDENTICAL to the bin plane
of the equivalent pred-free chain, and the §1 guarantee is inherited
unchanged.

Stage contract (`PredStage`, DESIGN.md §9):

    spec()                        spec token ("delta", "lorenzo", ...)
    header_content_bits()         transmitted header bits — 0: the
                                  predictors are static bijections, the
                                  wire carries no pred header plane
    encode_bins(bins, shape, bits)  int32[n] -> int32[n] coded plane
    decode_bins(codes, shape, bits) exact inverse (same shape/bits)

`shape` is the value-domain shape of the ORIGINAL tensor (the
``pred_shape`` threaded through `Pipeline.encode`/`decode`); `bits` is
the pack width.  Registered predictors (PRED_STAGES):

    delta     1-D previous-value predictor (gradient shards; any shape
              is treated as one flat stream)
    lorenzo   2-D Lorenzo predictor over the last two dims (NYX-style
              planes; leading dims batch; 1-D input degrades to a
              single-row plane = delta)
    kvdelta   previous-token delta along the second-to-last axis (KV
              pages shaped (page_tokens, head_dim); token 0 is
              unpredicted so every page decodes independently and
              migrated pages stay bit-exact; 1-D degrades to delta)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- bit helpers --

def _sign_extend(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Canonical int32 representative of a `bits`-bit two's-complement
    value (arithmetic shift pair, same idiom as codec.unpack_words)."""
    v = v.astype(jnp.int32)
    if bits >= 32:
        return v
    sh = jnp.int32(32 - bits)
    return (v << sh) >> sh


def _fold(d: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Residual -> zigzag code, both as sign-extended `bits`-bit int32.
    Zigzag maps small |d| of either sign to small unsigned codes, so the
    §6 width codes and the §7 entropy stage fire on residual planes."""
    d = _sign_extend(d, bits)
    z = (d << jnp.int32(1)) ^ (d >> jnp.int32(31))
    return _sign_extend(z, bits)


def _unfold(z: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Exact inverse of _fold."""
    zu = z.astype(jnp.uint32)
    if bits < 32:
        zu = zu & jnp.uint32((1 << bits) - 1)
    d = (zu >> jnp.uint32(1)) ^ (jnp.uint32(0) - (zu & jnp.uint32(1)))
    return _sign_extend(d.astype(jnp.int32), bits)


def _batched_dims(shape, flat_1d) -> tuple:
    """(batch, rows, cols) view of `shape` for a last-two-dims predictor;
    1-D/0-D input maps to `flat_1d` (how the stage degrades)."""
    shape = tuple(int(s) for s in shape)
    n = 1
    for s in shape:
        n *= s
    if len(shape) < 2:
        return flat_1d(n)
    b = 1
    for s in shape[:-2]:
        b *= s
    return (b, shape[-2], shape[-1])


# ----------------------------------------------------------------- stages --

@dataclasses.dataclass(frozen=True)
class DeltaStage:
    """1-D previous-value predictor: code[i] = fold(bin[i] - bin[i-1]).
    The whole tensor is one flat stream (gradient shards are 1-D on the
    wire anyway); the first element is predicted from 0."""

    def spec(self) -> str:
        return "delta"

    def header_content_bits(self) -> int:
        return 0

    def encode_bins(self, bins, shape, bits: int):
        b = bins.reshape(-1)
        prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), b[:-1]])
        return _fold(b - prev, bits)

    def decode_bins(self, codes, shape, bits: int):
        d = _unfold(codes.reshape(-1), bits)
        return _sign_extend(jnp.cumsum(d, dtype=jnp.int32), bits)


@dataclasses.dataclass(frozen=True)
class LorenzoStage:
    """2-D Lorenzo predictor over the last two dims: the residual is
    bin[i,j] - bin[i-1,j] - bin[i,j-1] + bin[i-1,j-1] (out-of-range
    neighbours read 0), i.e. first differences along both axes — the
    cuSZ predictor shape.  Leading dims batch; 1-D input is a single-row
    plane, where lorenzo degrades exactly to delta."""

    @staticmethod
    def _dims(shape) -> tuple:
        return _batched_dims(shape, lambda n: (1, 1, n))

    def spec(self) -> str:
        return "lorenzo"

    def header_content_bits(self) -> int:
        return 0

    def encode_bins(self, bins, shape, bits: int):
        p = bins.reshape(self._dims(shape)).astype(jnp.int32)
        dr = p - jnp.pad(p, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
        dc = dr - jnp.pad(dr, ((0, 0), (0, 0), (1, 0)))[:, :, :-1]
        return _fold(dc, bits).reshape(-1)

    def decode_bins(self, codes, shape, bits: int):
        d = _unfold(codes.reshape(self._dims(shape)), bits)
        b = jnp.cumsum(jnp.cumsum(d, axis=2, dtype=jnp.int32),
                       axis=1, dtype=jnp.int32)
        return _sign_extend(b, bits).reshape(-1)


@dataclasses.dataclass(frozen=True)
class KVDeltaStage:
    """Previous-token delta along the second-to-last axis — the KV-page
    predictor.  On a (page_tokens, head_dim) page each feature channel is
    predicted from the same channel of the previous token; token 0 is
    unpredicted, so a page never references another page and migrated
    pages decode bit-exactly on the receiving device (transport §8).
    1-D input is a (n, 1) column, where kvdelta degrades to delta."""

    @staticmethod
    def _dims(shape) -> tuple:
        return _batched_dims(shape, lambda n: (1, n, 1))

    def spec(self) -> str:
        return "kvdelta"

    def header_content_bits(self) -> int:
        return 0

    def encode_bins(self, bins, shape, bits: int):
        p = bins.reshape(self._dims(shape)).astype(jnp.int32)
        d = p - jnp.pad(p, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
        return _fold(d, bits).reshape(-1)

    def decode_bins(self, codes, shape, bits: int):
        d = _unfold(codes.reshape(self._dims(shape)), bits)
        return _sign_extend(jnp.cumsum(d, axis=1, dtype=jnp.int32),
                            bits).reshape(-1)


# --------------------------------------------------------------- registry --

def _parse_plain(name, tokens, cls):
    if tokens:
        raise ValueError(f"pred stage {name!r} takes no parameters")
    return cls()


# name -> parser(name, arg_tokens) -> PredStage instance.  Adding a
# predictor = one class + one entry here (+ a DESIGN.md §9 row).
PRED_STAGES = {
    "delta": lambda name, tokens: _parse_plain(name, tokens, DeltaStage),
    "lorenzo": lambda name, tokens: _parse_plain(name, tokens, LorenzoStage),
    "kvdelta": lambda name, tokens: _parse_plain(name, tokens, KVDeltaStage),
}


def register_pred_stage(name: str, parser) -> None:
    """Register a value-domain stage: parser(name, arg_tokens) -> stage."""
    PRED_STAGES[name] = parser


def parse_pred_stages(stages) -> tuple:
    """Resolve a pred-stage chain: a tuple of stage objects passes
    through; a spec fragment ("delta", "kvdelta", "", "none") parses via
    the PRED_STAGES registry — shared by `parse_pipeline` and per-plane
    callers (compression/kv.py)."""
    if isinstance(stages, tuple):
        return stages
    out = []
    for part in str(stages).split("|"):
        part = part.strip()
        if not part or part == "none":
            continue
        tok = part.split(":")
        if tok[0] not in PRED_STAGES:
            raise ValueError(f"unknown pred stage {tok[0]!r}; registered "
                             f"value-domain stages: {sorted(PRED_STAGES)}")
        out.append(PRED_STAGES[tok[0]](tok[0], tok[1:]))
    return tuple(out)


# ------------------------------------------------------------- chain ops --

def encode_pred_stages(pred, bins, shape, bits: int):
    """Apply a pred chain to a flat int32 bin plane, in spec order."""
    for st in pred:
        bins = st.encode_bins(bins, shape, bits)
    return bins


def decode_pred_stages(pred, codes, shape, bits: int):
    """Exact inverse of encode_pred_stages (reverse order)."""
    for st in reversed(pred):
        codes = st.decode_bins(codes, shape, bits)
    return codes


# ------------------------------------------- reconstruction-feedback scan --

def _wrap_py(v: int, bits: int) -> int:
    half = 1 << (bits - 1)
    return ((v + half) & ((1 << bits) - 1)) - half


def _fold_py(d: int, bits: int) -> int:
    return ((d << 1) ^ (d >> 63)) & ((1 << bits) - 1)


def _unfold_py(z: int, bits: int) -> int:
    return (z >> 1) ^ (-(z & 1))


def scan_reference(stage, bins, shape, bits: int):
    """The closed-loop predictor written as the LITERAL per-element
    reconstruction-feedback loop the paper describes: predict from the
    bins reconstructed so far (the decoder's exact view), emit the folded
    residual, then feed the DECODED residual back into the reconstruction
    before moving on.  O(n) python — test-only; the vectorized stages are
    pinned bit-identical to this loop (tests/test_predict.py).

    Returns (codes, recon) as int32 numpy arrays; recon == bins is the
    closed-loop exactness property itself."""
    bins = np.asarray(bins, dtype=np.int64).reshape(-1)
    if isinstance(stage, DeltaStage):
        dims, lorenzo = (1, bins.size, 1), False
    elif isinstance(stage, KVDeltaStage):
        dims, lorenzo = KVDeltaStage._dims(shape), False
    elif isinstance(stage, LorenzoStage):
        dims, lorenzo = LorenzoStage._dims(shape), True
    else:
        raise TypeError(f"no scan reference for {stage!r}")
    p = bins.reshape(dims)
    codes = np.zeros(dims, np.int64)
    recon = np.zeros(dims, np.int64)
    nb, nh, nw = dims
    for b in range(nb):
        for i in range(nh):
            for j in range(nw):
                if lorenzo:
                    pred = ((int(recon[b, i - 1, j]) if i else 0)
                            + (int(recon[b, i, j - 1]) if j else 0)
                            - (int(recon[b, i - 1, j - 1])
                               if i and j else 0))
                else:
                    pred = int(recon[b, i - 1, j]) if i else 0
                d = _wrap_py(int(p[b, i, j]) - pred, bits)
                z = _fold_py(d, bits)
                codes[b, i, j] = _wrap_py(z, bits)
                recon[b, i, j] = _wrap_py(pred + _unfold_py(z, bits), bits)
    return (codes.reshape(-1).astype(np.int32),
            recon.reshape(-1).astype(np.int32))
