"""Guaranteed-error-bound quantizers (the paper's core contribution).

Implements the LC framework's ABS / REL / NOA quantizers with every
correctness mechanism from the paper:

  * double-checking (§3.1): every value is immediately reconstructed and
    verified against the bound; failures are flagged as outliers and kept
    losslessly (bit-exact, inline with the bin stream — NOT a separate
    SZ3-style list).
  * parity-safe REL transcendentals (§3.2): bit-manipulation log2/pow2 from
    `bitops`, IEEE-only ops, identical bits on every XLA backend.
  * special values (§2.2): NaN/INF are explicitly flagged; denormals are
    treated like normal values (ABS) and fall out via the double-check (REL).
  * two's-complement edge case (§2.4/§3.3): the bin-range test is the
    paper's two-comparison form `(bin >= maxbin) | (bin <= -maxbin)`,
    never `abs(bin) >= maxbin`.

Soundness note on the check itself: the comparison `|x - recon| <= eb` is
computed in floating point, so a true error a hair above eb could round to
"equal".  We therefore accept only `diff <= eb * TIGHTEN` with TIGHTEN
covering the few-ulp rounding of the check expression (config.TIGHTEN_*).
The guarantee is then unconditional: every decoded value is within eb of
the original, or (outliers / specials) bit-for-bit identical to it.

All functions are shape-polymorphic, jit-safe, and use only deterministic
IEEE + integer ops — the TPU analogue of the paper's `-mno-fma`+IEEE-only
discipline (see DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from .bitops import float_to_bits, log2approx, pow2_floor, pow2approx
from .config import QuantizerConfig


class Quantized(NamedTuple):
    """Result of quantization, before outlier storage is chosen.

    bins:     int32 bin numbers (0 where outlier)
    outlier:  bool mask — value must be stored losslessly
    recon:    the reconstruction the decoder will produce for non-outliers
              (returned so callers can form residuals without re-decoding)
    sign:     REL only — True where the original value is negative.  REL
              bins encode log2|x| and are signed themselves (|x| < 1 has a
              negative bin), so the value's sign needs its own plane; the
              serializer packs it at 1 bit/value.
    """

    bins: jnp.ndarray
    outlier: jnp.ndarray
    recon: jnp.ndarray
    sign: jnp.ndarray | None = None


def _finite(x):
    # isfinite == explicit INF and NaN check (paper handles both explicitly;
    # for ABS the INF rejection is implicit via the failed double-check, but
    # on XLA float->int conversion of non-finite values is undefined, so we
    # must flag them BEFORE the int cast).
    return jnp.isfinite(x)


def quantize_abs(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> Quantized:
    """ABS quantizer: bin = rint(x / (2*eb)), recon = bin * (2*eb).

    `eb` overrides the config bound (used by NOA and by per-tensor
    gradient/KV compression, where eb is a traced scalar); constants are
    computed in the data dtype either way so encode and decode agree
    bit-for-bit.
    """
    dt = x.dtype
    degenerate = None
    if eb is None:
        eb_, eb2, inv_eb2 = cfg.abs_constants()   # config enforces eb floor
    else:
        # Traced per-tensor eb (NOA, gradient/KV compression): guard the
        # denormal-flush hazard dynamically — an eb below the floor cannot
        # be checked reliably under FTZ, so the whole tensor goes lossless.
        # eb2 is pow2-floored on-device (integer bit op) for FMA immunity,
        # exactly as the host does for static bounds.
        floor = jnp.asarray(cfg.eb_floor, dt)
        eb_ = jnp.asarray(eb, dt)
        degenerate = ~(eb_ >= floor)              # True also for NaN eb
        eb_ = jnp.maximum(eb_, floor)
        eb2 = pow2_floor(jnp.asarray(2.0, dt) * eb_)
        inv_eb2 = jnp.asarray(1.0, dt) / eb2
    maxbin = cfg.maxbin

    finite = _finite(x)
    xs = jnp.where(finite, x, jnp.zeros((), dt))           # keep int cast defined
    bin_f = jnp.rint(xs * inv_eb2)                         # round to nearest bin
    # Range check in FLOAT domain first: |bin_f| can exceed int32 (an
    # implementation-defined cast on XLA), so clamp via the outlier flag
    # before converting.
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    # Paper §3.3: two-comparison form — NEVER abs(bin) (two's-complement min
    # has no positive counterpart; jnp.abs would silently wrap).
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)

    # bin * eb2 is EXACT (pow2 step) -> identical under any FMA contraction;
    # this is our substitute for the paper's -mno-fma (see bitops note).
    recon = bin_i.astype(dt) * eb2                         # decoder's exact expr
    diff = jnp.abs(x - recon)
    bound = eb_ * jnp.asarray(cfg.tighten, dt)
    fails_check = ~(diff <= bound)                         # True for NaN diff too
    # The exactness argument breaks at the overflow boundary: if bin*eb2
    # exceeds the dtype max (huge NOA eb on near-max values), the unfused
    # product is INF but a contracted x - bin*eb2 is computed in extended
    # precision and can come out small — the check would wrongly ACCEPT a
    # value that decodes to INF.  Rejecting on the standalone product is
    # contraction-proof (exact-or-inf, deterministically).
    fails_check |= ~jnp.isfinite(recon)

    outlier = (~finite) | range_bad | range_bad_i | fails_check
    if degenerate is not None:
        outlier = outlier | degenerate
    bins = jnp.where(outlier, 0, bin_i)
    recon = jnp.where(outlier, jnp.zeros((), dt), recon)
    return Quantized(bins, outlier, recon)


def dequantize_abs(bins: jnp.ndarray, cfg: QuantizerConfig, eb=None, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    if eb is None:
        _, eb2, _ = cfg.abs_constants()
    else:
        # mirror the encoder's traced-eb transform exactly
        floor = jnp.asarray(cfg.eb_floor, dt)
        eb_ = jnp.maximum(jnp.asarray(eb, dt), floor)
        eb2 = pow2_floor(jnp.asarray(2.0, dt) * eb_)
    return bins.astype(dt) * eb2


def quantize_rel(x: jnp.ndarray, cfg: QuantizerConfig) -> Quantized:
    """REL quantizer: bins in the (approximate) log2 domain.

    bin = rint(log2approx(|x|) / w), recon = sign(x) * pow2approx(bin * w),
    w = log2(1+eb).  log2approx/pow2approx are the paper's parity-safe
    bit-manipulation replacements; their inaccuracy (and the denormal range,
    where the bit trick reads a wrong exponent) is caught by the
    double-check below and routed to lossless storage.
    """
    dt = x.dtype
    eb_, log_step, inv_log_step = cfg.rel_constants()
    maxbin = cfg.maxbin

    finite = _finite(x)
    ax = jnp.abs(x)
    # Zeros, denormals, and near-denormal normals (where the double-check's
    # own products would flush under FTZ backends) are screened out by a
    # single comparison against a normal-range threshold — identical
    # decision under FTZ and gradual underflow (config.rel_screen_threshold).
    # This is the paper's "even denormals may require special handling for
    # REL" (§2.2) made flush-proof.
    too_small = ~(ax >= jnp.asarray(cfg.rel_screen_threshold(), dt))
    safe = jnp.where(finite & ~too_small, ax, jnp.ones((), dt))
    lg = log2approx(safe)
    bin_f = jnp.rint(lg * inv_log_step)
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)   # paper §3.3 form

    # Sign from the BIT PATTERN, not `x < 0`: DAZ backends read a negative
    # denormal as -0.0 and would flip the comparison vs gradual-underflow
    # backends.  The integer test is flush-proof and parity-exact.
    neg = float_to_bits(x) < 0
    mag = pow2approx(bin_i.astype(dt) * log_step)          # exact pow2-step mul
    recon = jnp.where(neg, -mag, mag)
    # Double-check in the REL metric: |x - r| <= eb * |x| (tightened), and
    # the sign must match (paper §2.1.2).  INF/NaN fail here.  The
    # reconstruction must itself be a normal number, else the decoder-side
    # sub could flush (comparison vs tiny: flush-consistent either way).
    ebT = jnp.asarray(dt.type(eb_) * dt.type(cfg.tighten), dt)
    diff = jnp.abs(x - recon)
    ok = (diff <= ebT * ax) & jnp.isfinite(recon)
    ok &= mag >= jnp.asarray(np.finfo(dt).tiny, dt)
    outlier = (~finite) | too_small | range_bad | range_bad_i | ~ok
    bins = jnp.where(outlier, 0, bin_i)
    recon = jnp.where(outlier, jnp.zeros((), dt), recon)
    return Quantized(bins, outlier, recon, sign=neg)


def dequantize_rel(bins: jnp.ndarray, sign: jnp.ndarray, cfg: QuantizerConfig,
                   dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    _, log_step, _ = cfg.rel_constants()
    mag = pow2approx(bins.astype(dt) * jnp.asarray(log_step, dt))
    return jnp.where(sign, -mag, mag)


def quantize_noa(x: jnp.ndarray, cfg: QuantizerConfig, value_range=None) -> Quantized:
    """NOA = ABS with eb scaled by the value range R = max - min (paper
    §2.1.3).  R is data-dependent, so eb becomes a traced scalar; it must be
    carried in the encoded header for the decoder."""
    if value_range is None:
        finite = jnp.isfinite(x)
        big = jnp.asarray(np.finfo(x.dtype).max, x.dtype)
        hi = jnp.max(jnp.where(finite, x, -big))
        lo = jnp.min(jnp.where(finite, x, big))
        value_range = hi - lo
    eb = jnp.asarray(cfg.error_bound, x.dtype) * value_range
    # Degenerate inputs (R == 0, or eb*R below the denormal-safe floor) are
    # handled inside quantize_abs's traced-eb path: the whole tensor goes
    # lossless rather than risking a flush-corrupted check.
    q = quantize_abs(x, cfg, eb=eb)
    return q, eb


def quantize(x: jnp.ndarray, cfg: QuantizerConfig):
    """Mode dispatch. Returns (Quantized, eb_scalar_or_None)."""
    if cfg.mode == "abs":
        return quantize_abs(x, cfg), None
    if cfg.mode == "rel":
        return quantize_rel(x, cfg), None
    if cfg.mode == "noa":
        return quantize_noa(x, cfg)
    raise ValueError(cfg.mode)


# ---------------------------------------------------------------------------
# Unprotected variants (paper's ablation baseline: Figs 3-4 / Tables 7-8).
# Identical math WITHOUT the double-check — used only by benchmarks to
# reproduce the paper's "protected vs unprotected" comparison.  These can
# and do violate the error bound on adversarial values.
# ---------------------------------------------------------------------------

def quantize_abs_unprotected(x: jnp.ndarray, cfg: QuantizerConfig) -> Quantized:
    dt = x.dtype
    _, eb2, inv_eb2 = cfg.abs_constants()
    maxbin = cfg.maxbin
    finite = _finite(x)
    xs = jnp.where(finite, x, jnp.zeros((), dt))
    bin_f = jnp.rint(xs * inv_eb2)
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    outlier = (~finite) | range_bad          # only range/special screening
    bins = jnp.where(outlier, 0, bin_i)
    return Quantized(bins, outlier, bins.astype(dt) * eb2)


def quantize_rel_library(x: jnp.ndarray, cfg: QuantizerConfig) -> Quantized:
    """REL using the BACKEND's log2/exp2 (the paper's 'original functions'
    baseline): higher accuracy -> better ratio, but NO cross-device parity."""
    dt = x.dtype
    eb_, log_step, inv_log_step = cfg.rel_constants()
    maxbin = cfg.maxbin
    finite = _finite(x)
    ax = jnp.abs(x)
    too_small = ~(ax >= jnp.asarray(cfg.rel_screen_threshold(), dt))
    safe = jnp.where(finite & ~too_small, ax, jnp.ones((), dt))
    lg = jnp.log2(safe)                                    # library call
    bin_f = jnp.rint(lg * inv_log_step)
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    mag = jnp.exp2(bin_i.astype(dt) * log_step)            # library call
    neg = float_to_bits(x) < 0
    recon = jnp.where(neg, -mag, mag)
    ebT = jnp.asarray(dt.type(eb_) * dt.type(cfg.tighten), dt)
    diff = jnp.abs(x - recon)
    ok = (diff <= ebT * ax) & jnp.isfinite(recon)
    ok &= mag >= jnp.asarray(np.finfo(dt).tiny, dt)
    outlier = (~finite) | too_small | range_bad | ~ok
    bins = jnp.where(outlier, 0, bin_i)
    recon = jnp.where(outlier, jnp.zeros((), dt), recon)
    return Quantized(bins, outlier, recon, sign=neg)
