"""jit-safe fixed-shape codec over the quantizers.

XLA needs static shapes, so the in-flight representation differs from the
host byte stream (serializer.py) while preserving the paper's semantics:
outliers live WITH the bins (same index space — LC's inline placement, not
SZ3's side list), stored bit-exactly so NaN payloads / -0.0 / INF survive.

Three layouts:

  * DENSE  — bins + outlier payload at every index (payload 0 where not
    outlier).  Reference layout; wire-size = bins + full payload, used where
    simplicity beats size (activation offload, tests).
  * COMPACT — bins + (idx, payload) arrays capped at K = ceil(frac * n).
    If the outlier count exceeds K the tensor CANNOT be represented within
    the bound — encode reports `overflow` and callers must take the
    lossless path (compression/grads.py does this with a psum-agreed
    lax.cond).  The guarantee is never silently dropped.
  * PACKED — COMPACT with the bins bit-packed into uint32 lanes (and the
    REL sign plane packed at 1 bit/value).  This is the wire format the
    collectives actually move (compression/grads.py); pack/unpack here are
    the jit-safe lax shift/or reference paths, bit-exact oracles for the
    fused Pallas kernels in kernels/pack.py.  Layout documented in
    DESIGN.md §4 and under pack_words below.

Bin storage width is cfg.bin_bits; bins are produced as int32 and narrowed
here (safe: the quantizer's range check already confined them to
(-maxbin, maxbin)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import quantizer as q
from .bitops import bits_to_float, float_to_bits
from .config import QuantizerConfig

_BIN_DTYPE = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


class EncodedDense(NamedTuple):
    bins: jnp.ndarray        # int{8,16,32}[n]
    outlier: jnp.ndarray     # bool[n]
    payload: jnp.ndarray     # uint-bits[n], original bits where outlier
    sign: jnp.ndarray | None  # bool[n] (REL only)
    eb: jnp.ndarray | None   # traced scalar bound (NOA / per-tensor eb)


class EncodedCompact(NamedTuple):
    bins: jnp.ndarray        # int{8,16,32}[n]
    out_idx: jnp.ndarray     # int32[K], n = "empty slot"
    out_payload: jnp.ndarray  # uint-bits[K]
    n_outliers: jnp.ndarray  # int32 scalar
    overflow: jnp.ndarray    # bool scalar: n_outliers > K (bound NOT met)
    sign: jnp.ndarray | None
    eb: jnp.ndarray | None

    def wire_bits(self, cfg: QuantizerConfig) -> int:
        """Static wire size in bits (what the collective actually moves)."""
        n = self.bins.shape[0]
        k = self.out_idx.shape[0]
        elem = np.dtype(str(self.out_payload.dtype)).itemsize * 8
        sign_bits = n if self.sign is not None else 0
        return n * cfg.bin_bits + k * (32 + elem) + sign_bits + 64


def _narrow(bins: jnp.ndarray, cfg: QuantizerConfig) -> jnp.ndarray:
    return bins.astype(_BIN_DTYPE[cfg.bin_bits])


def encode_dense(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> EncodedDense:
    flat = x.reshape(-1)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:  # noa
        qt, eb = q.quantize_noa(flat, cfg)
    payload = jnp.where(qt.outlier, float_to_bits(flat), 0)
    return EncodedDense(_narrow(qt.bins, cfg), qt.outlier, payload, qt.sign,
                        None if eb is None else jnp.asarray(eb, flat.dtype))


def decode_dense(enc: EncodedDense, cfg: QuantizerConfig, shape=None):
    bins = enc.bins.astype(jnp.int32)
    if cfg.mode == "rel":
        recon = q.dequantize_rel(bins, enc.sign, cfg)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb)
    vals = jnp.where(enc.outlier, bits_to_float(enc.payload, recon.dtype), recon)
    return vals.reshape(shape) if shape is not None else vals


def encode_compact(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> EncodedCompact:
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = cfg.outlier_cap(n)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:
        qt, eb = q.quantize_noa(flat, cfg)
    n_out = jnp.sum(qt.outlier).astype(jnp.int32)
    # Static-size gather of outlier positions; fill value n marks empties.
    (idx,) = jnp.nonzero(qt.outlier, size=k, fill_value=n)
    safe_idx = jnp.minimum(idx, n - 1)
    payload = jnp.where(idx < n, float_to_bits(flat)[safe_idx], 0)
    return EncodedCompact(_narrow(qt.bins, cfg), idx.astype(jnp.int32), payload,
                          n_out, n_out > k, qt.sign,
                          None if eb is None else jnp.asarray(eb, flat.dtype))


def decode_compact(enc: EncodedCompact, cfg: QuantizerConfig, shape=None,
                   dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    bins = enc.bins.astype(jnp.int32)
    if cfg.mode == "rel":
        recon = q.dequantize_rel(bins, enc.sign, cfg, dtype=dt)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb, dtype=dt)
    n = recon.shape[0]
    vals = bits_to_float(enc.out_payload, dt)
    # Scatter exact outliers back over their reconstructions; empty slots
    # (idx == n) drop out of bounds and are discarded by mode='drop'.
    recon = recon.at[enc.out_idx].set(vals, mode="drop")
    return recon.reshape(shape) if shape is not None else recon


def roundtrip_dense(x: jnp.ndarray, cfg: QuantizerConfig):
    """Encode+decode; the decoded result carries the full guarantee."""
    return decode_dense(encode_dense(x, cfg), cfg, shape=x.shape)


# ---------------------------------------------------------------------------
# PACKED layout — bins bit-packed into uint32 lanes (the device wire format)
# ---------------------------------------------------------------------------
#
# Word layout (little-endian within a word, lane-tiled across words): the
# flat stream is padded with zeros to a whole number of TILES of
# vpw * PACK_LANES elements (vpw = 32 // bin_bits values per word), viewed
# row-major as [R, PACK_LANES], and word row w packs element rows
# w*vpw .. w*vpw+vpw-1: element [w*vpw + i, lane] occupies bits
# [i*bin_bits, (i+1)*bin_bits) of word [w, lane].  Bins are stored as
# bin_bits-wide two's complement (lossless: the quantizer confined them to
# (-maxbin, maxbin)).  Grouping rows instead of adjacent lanes keeps the
# pack a pure sublane shift/or on the TPU VPU, and makes the layout
# identical for any kernel block height that is a multiple of vpw — the
# Pallas kernels and this reference produce bit-identical words.

PACK_LANES = 128          # lane width of the packed tile (VPU native)
_PACK_WIDTHS = (1, 8, 16, 32)


def packed_word_count(n: int, bin_bits: int) -> int:
    """Number of uint32 words `pack_words` emits for n elements."""
    vpw = 32 // bin_bits
    tile = vpw * PACK_LANES
    return -(-n // tile) * PACK_LANES


def pack_words(values: jnp.ndarray, bin_bits: int) -> jnp.ndarray:
    """Pack flat int values into uint32 words (layout in the module note).

    values: int32/uint32[n] with each value representable in bin_bits
    (two's complement).  Returns uint32[packed_word_count(n, bin_bits)].
    jit-safe: pure reshape + shift/or reduction, no gathers.
    """
    if bin_bits not in _PACK_WIDTHS:
        raise ValueError(f"bin_bits must be one of {_PACK_WIDTHS}")
    vpw = 32 // bin_bits
    n = values.shape[0]
    n_words = packed_word_count(n, bin_bits)
    u = values.astype(jnp.uint32)
    if bin_bits != 32:
        u = u & jnp.uint32((1 << bin_bits) - 1)
    u = jnp.pad(u, (0, n_words * vpw - n))
    grp = u.reshape(-1, vpw, PACK_LANES)
    word = grp[:, 0, :]
    for i in range(1, vpw):
        word = word | (grp[:, i, :] << jnp.uint32(i * bin_bits))
    return word.reshape(-1)


def unpack_words(words: jnp.ndarray, n: int, bin_bits: int,
                 signed: bool = True) -> jnp.ndarray:
    """Inverse of pack_words.  Returns int32[n] (sign-extended) or
    uint32[n] when signed=False."""
    vpw = 32 // bin_bits
    w = words.reshape(-1, PACK_LANES)
    if vpw == 1:
        flat = w.reshape(-1)[:n]
    else:
        mask = jnp.uint32((1 << bin_bits) - 1)
        cols = [(w >> jnp.uint32(i * bin_bits)) & mask for i in range(vpw)]
        flat = jnp.stack(cols, axis=1).reshape(-1)[:n]
    if not signed:
        return flat
    if bin_bits == 32:
        return flat.astype(jnp.int32)
    sh = jnp.int32(32 - bin_bits)
    return (flat.astype(jnp.int32) << sh) >> sh     # arithmetic sign-extend


def pack_flags(flags: jnp.ndarray) -> jnp.ndarray:
    """bool[n] -> uint32[ceil-to-tile(n/32)] at 1 bit/value (sign plane)."""
    return pack_words(flags.astype(jnp.uint32), 1)


def unpack_flags(words: jnp.ndarray, n: int) -> jnp.ndarray:
    return unpack_words(words, n, 1, signed=False).astype(bool)


class EncodedPacked(NamedTuple):
    """COMPACT with device-side bit-packed bins — the actual wire format.

    Everything here is what crosses the collective: uint32 words, the
    capped exact-outlier table, and an 8-byte header (n_outliers/overflow +
    eb).  No full-width bins, no bool plane, no recon plane.
    """
    words: jnp.ndarray        # uint32[n_words] — bin_bits-wide packed bins
    out_idx: jnp.ndarray      # int32[K], n = "empty slot"
    out_payload: jnp.ndarray  # uint32[K] — original IEEE bits, bit-exact
    n_outliers: jnp.ndarray   # int32 scalar
    overflow: jnp.ndarray     # bool scalar: n_outliers > K (bound NOT met)
    sign_words: jnp.ndarray | None  # uint32[n_sign_words] (REL only)
    eb: jnp.ndarray | None    # traced scalar bound (NOA / per-tensor eb)

    def wire_bits(self, cfg: QuantizerConfig | None = None) -> int:
        """Static wire size in bits — exactly the bytes the collective
        moves, tile padding included.  vs EncodedCompact (whose bins are
        also bin_bits-wide): the sign plane is 1 bit/value instead of a
        byte-wide bool, and everything rides uint32 lanes."""
        bits = 32 * self.words.shape[0]
        bits += self.out_idx.shape[0] * (32 + 32)
        if self.sign_words is not None:
            bits += 32 * self.sign_words.shape[0]
        return bits + 64                     # n_outliers/overflow + eb header


def encode_packed(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> EncodedPacked:
    """Quantize + bit-pack in one jit-safe call (reference path; the fused
    Pallas pipeline in kernels/pack.py is its bit-exact device twin)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = cfg.outlier_cap(n)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:
        qt, eb = q.quantize_noa(flat, cfg)
    n_out = jnp.sum(qt.outlier).astype(jnp.int32)
    (idx,) = jnp.nonzero(qt.outlier, size=k, fill_value=n)
    safe_idx = jnp.minimum(idx, n - 1)
    payload = jnp.where(idx < n, float_to_bits(flat)[safe_idx], 0)
    words = pack_words(qt.bins, cfg.bin_bits)
    sign_words = None if qt.sign is None else pack_flags(qt.sign)
    return EncodedPacked(words, idx.astype(jnp.int32),
                         payload.astype(jnp.uint32), n_out, n_out > k,
                         sign_words,
                         None if eb is None else jnp.asarray(eb, flat.dtype))


def decode_packed(enc: EncodedPacked, cfg: QuantizerConfig, n: int | None = None,
                  shape=None, dtype=None):
    """Unpack + dequantize + exact outlier restore.  `n` (or `shape`) gives
    the true element count — the packed stream carries pad words."""
    if n is None:
        if shape is None:
            raise ValueError("decode_packed needs n or shape")
        n = int(np.prod(shape))
    dt = jnp.dtype(dtype or cfg.dtype)
    bins = unpack_words(enc.words, n, cfg.bin_bits)
    if cfg.mode == "rel":
        sign = unpack_flags(enc.sign_words, n)
        recon = q.dequantize_rel(bins, sign, cfg, dtype=dt)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb, dtype=dt)
    vals = bits_to_float(enc.out_payload.astype(jnp.int32), dt)
    recon = recon.at[enc.out_idx].set(vals, mode="drop")
    return recon.reshape(shape) if shape is not None else recon
