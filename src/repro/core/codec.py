"""jit-safe fixed-shape codec over the quantizers.

XLA needs static shapes, so the in-flight representation differs from the
host byte stream (serializer.py) while preserving the paper's semantics:
outliers live WITH the bins (same index space — LC's inline placement, not
SZ3's side list), stored bit-exactly so NaN payloads / -0.0 / INF survive.

Two layouts:

  * DENSE  — bins + outlier payload at every index (payload 0 where not
    outlier).  Reference layout; wire-size = bins + full payload, used where
    simplicity beats size (activation offload, tests).
  * COMPACT — bins + (idx, payload) arrays capped at K = ceil(frac * n).
    This is what goes over the pod axis for gradient compression.  If the
    outlier count exceeds K the tensor CANNOT be represented within the
    bound — encode reports `overflow` and callers must take the lossless
    path (compression/grads.py does this with a psum-agreed lax.cond).  The
    guarantee is never silently dropped.

Bin storage width is cfg.bin_bits; bins are produced as int32 and narrowed
here (safe: the quantizer's range check already confined them to
(-maxbin, maxbin)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import quantizer as q
from .bitops import bits_to_float, float_to_bits
from .config import QuantizerConfig

_BIN_DTYPE = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


class EncodedDense(NamedTuple):
    bins: jnp.ndarray        # int{8,16,32}[n]
    outlier: jnp.ndarray     # bool[n]
    payload: jnp.ndarray     # uint-bits[n], original bits where outlier
    sign: jnp.ndarray | None  # bool[n] (REL only)
    eb: jnp.ndarray | None   # traced scalar bound (NOA / per-tensor eb)


class EncodedCompact(NamedTuple):
    bins: jnp.ndarray        # int{8,16,32}[n]
    out_idx: jnp.ndarray     # int32[K], n = "empty slot"
    out_payload: jnp.ndarray  # uint-bits[K]
    n_outliers: jnp.ndarray  # int32 scalar
    overflow: jnp.ndarray    # bool scalar: n_outliers > K (bound NOT met)
    sign: jnp.ndarray | None
    eb: jnp.ndarray | None

    def wire_bits(self, cfg: QuantizerConfig) -> int:
        """Static wire size in bits (what the collective actually moves)."""
        n = self.bins.shape[0]
        k = self.out_idx.shape[0]
        elem = np.dtype(str(self.out_payload.dtype)).itemsize * 8
        sign_bits = n if self.sign is not None else 0
        return n * cfg.bin_bits + k * (32 + elem) + sign_bits + 64


def _narrow(bins: jnp.ndarray, cfg: QuantizerConfig) -> jnp.ndarray:
    return bins.astype(_BIN_DTYPE[cfg.bin_bits])


def encode_dense(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> EncodedDense:
    flat = x.reshape(-1)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:  # noa
        qt, eb = q.quantize_noa(flat, cfg)
    payload = jnp.where(qt.outlier, float_to_bits(flat), 0)
    return EncodedDense(_narrow(qt.bins, cfg), qt.outlier, payload, qt.sign,
                        None if eb is None else jnp.asarray(eb, flat.dtype))


def decode_dense(enc: EncodedDense, cfg: QuantizerConfig, shape=None):
    bins = enc.bins.astype(jnp.int32)
    if cfg.mode == "rel":
        recon = q.dequantize_rel(bins, enc.sign, cfg)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb)
    vals = jnp.where(enc.outlier, bits_to_float(enc.payload, recon.dtype), recon)
    return vals.reshape(shape) if shape is not None else vals


def encode_compact(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> EncodedCompact:
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = cfg.outlier_cap(n)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:
        qt, eb = q.quantize_noa(flat, cfg)
    n_out = jnp.sum(qt.outlier).astype(jnp.int32)
    # Static-size gather of outlier positions; fill value n marks empties.
    (idx,) = jnp.nonzero(qt.outlier, size=k, fill_value=n)
    safe_idx = jnp.minimum(idx, n - 1)
    payload = jnp.where(idx < n, float_to_bits(flat)[safe_idx], 0)
    return EncodedCompact(_narrow(qt.bins, cfg), idx.astype(jnp.int32), payload,
                          n_out, n_out > k, qt.sign,
                          None if eb is None else jnp.asarray(eb, flat.dtype))


def decode_compact(enc: EncodedCompact, cfg: QuantizerConfig, shape=None,
                   dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    bins = enc.bins.astype(jnp.int32)
    if cfg.mode == "rel":
        recon = q.dequantize_rel(bins, enc.sign, cfg, dtype=dt)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb, dtype=dt)
    n = recon.shape[0]
    vals = bits_to_float(enc.out_payload, dt)
    # Scatter exact outliers back over their reconstructions; empty slots
    # (idx == n) drop out of bounds and are discarded by mode='drop'.
    recon = recon.at[enc.out_idx].set(vals, mode="drop")
    return recon.reshape(shape) if shape is not None else recon


def roundtrip_dense(x: jnp.ndarray, cfg: QuantizerConfig):
    """Encode+decode; the decoded result carries the full guarantee."""
    return decode_dense(encode_dense(x, cfg), cfg, shape=x.shape)
