"""jit-safe fixed-shape codec over the quantizers.

XLA needs static shapes, so the in-flight representation differs from the
host byte stream (serializer.py) while preserving the paper's semantics:
outliers live WITH the bins (same index space — LC's inline placement, not
SZ3's side list), stored bit-exactly so NaN payloads / -0.0 / INF survive.

Three layouts:

  * DENSE  — bins + outlier payload at every index (payload 0 where not
    outlier).  Reference layout; wire-size = bins + full payload, used where
    simplicity beats size (activation offload, tests).
  * COMPACT — bins + (idx, payload) arrays capped at K = ceil(frac * n).
    If the outlier count exceeds K the tensor CANNOT be represented within
    the bound — encode reports `overflow` and callers must take the
    lossless path (compression/grads.py does this with a psum-agreed
    lax.cond).  The guarantee is never silently dropped.
  * PACKED — COMPACT with the bins bit-packed into uint32 lanes (and the
    REL sign plane packed at 1 bit/value).  This is the wire format the
    collectives actually move (compression/grads.py); pack/unpack here are
    the jit-safe lax shift/or reference paths, bit-exact oracles for the
    fused Pallas kernels in kernels/pack.py.  Layout documented in
    DESIGN.md §4 and under pack_words below.
  * LC — PACKED followed by the device-side lossless coding stage
    (DESIGN.md §6): the uint32 word stream is chunked, all-zero chunks are
    dropped, and the remaining chunks are stored at the minimal word width
    they need.  encode_lossless/decode_lossless are exact inverses, so the
    end-to-end bound guarantee is untouched; the Pallas twin lives in
    kernels/lossless.py.

Bin storage width is cfg.bin_bits; bins are produced as int32 and narrowed
here (safe: the quantizer's range check already confined them to
(-maxbin, maxbin)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantizer as q
from .bitops import bits_to_float, float_to_bits
from .config import QuantizerConfig

_BIN_DTYPE = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


class EncodedDense(NamedTuple):
    bins: jnp.ndarray        # int{8,16,32}[n]
    outlier: jnp.ndarray     # bool[n]
    payload: jnp.ndarray     # uint-bits[n], original bits where outlier
    sign: jnp.ndarray | None  # bool[n] (REL only)
    eb: jnp.ndarray | None   # traced scalar bound (NOA / per-tensor eb)


class EncodedCompact(NamedTuple):
    bins: jnp.ndarray        # int{8,16,32}[n]
    out_idx: jnp.ndarray     # int32[K], n = "empty slot"
    out_payload: jnp.ndarray  # uint-bits[K]
    n_outliers: jnp.ndarray  # int32 scalar
    overflow: jnp.ndarray    # bool scalar: n_outliers > K (bound NOT met)
    sign: jnp.ndarray | None
    eb: jnp.ndarray | None

    def wire_bits(self, cfg: QuantizerConfig) -> int:
        """Static wire size in bits (what the collective actually moves)."""
        n = self.bins.shape[0]
        k = self.out_idx.shape[0]
        elem = np.dtype(str(self.out_payload.dtype)).itemsize * 8
        sign_bits = n if self.sign is not None else 0
        return n * cfg.bin_bits + k * (32 + elem) + sign_bits + 64


def _narrow(bins: jnp.ndarray, cfg: QuantizerConfig) -> jnp.ndarray:
    return bins.astype(_BIN_DTYPE[cfg.bin_bits])


def encode_dense(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> EncodedDense:
    flat = x.reshape(-1)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:  # noa
        qt, eb = q.quantize_noa(flat, cfg)
    payload = jnp.where(qt.outlier, float_to_bits(flat), 0)
    return EncodedDense(_narrow(qt.bins, cfg), qt.outlier, payload, qt.sign,
                        None if eb is None else jnp.asarray(eb, flat.dtype))


def decode_dense(enc: EncodedDense, cfg: QuantizerConfig, shape=None):
    bins = enc.bins.astype(jnp.int32)
    if cfg.mode == "rel":
        recon = q.dequantize_rel(bins, enc.sign, cfg)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb)
    vals = jnp.where(enc.outlier, bits_to_float(enc.payload, recon.dtype), recon)
    return vals.reshape(shape) if shape is not None else vals


def encode_compact(x: jnp.ndarray, cfg: QuantizerConfig, eb=None) -> EncodedCompact:
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = cfg.outlier_cap(n)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:
        qt, eb = q.quantize_noa(flat, cfg)
    n_out = jnp.sum(qt.outlier).astype(jnp.int32)
    # Static-size gather of outlier positions; fill value n marks empties.
    (idx,) = jnp.nonzero(qt.outlier, size=k, fill_value=n)
    safe_idx = jnp.minimum(idx, n - 1)
    payload = jnp.where(idx < n, float_to_bits(flat)[safe_idx], 0)
    return EncodedCompact(_narrow(qt.bins, cfg), idx.astype(jnp.int32), payload,
                          n_out, n_out > k, qt.sign,
                          None if eb is None else jnp.asarray(eb, flat.dtype))


def decode_compact(enc: EncodedCompact, cfg: QuantizerConfig, shape=None,
                   dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    bins = enc.bins.astype(jnp.int32)
    if cfg.mode == "rel":
        recon = q.dequantize_rel(bins, enc.sign, cfg, dtype=dt)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb, dtype=dt)
    n = recon.shape[0]
    vals = bits_to_float(enc.out_payload, dt)
    # Scatter exact outliers back over their reconstructions; empty slots
    # (idx == n) drop out of bounds and are discarded by mode='drop'.
    recon = recon.at[enc.out_idx].set(vals, mode="drop")
    return recon.reshape(shape) if shape is not None else recon


def roundtrip_dense(x: jnp.ndarray, cfg: QuantizerConfig):
    """Encode+decode; the decoded result carries the full guarantee."""
    return decode_dense(encode_dense(x, cfg), cfg, shape=x.shape)


# ---------------------------------------------------------------------------
# PACKED layout — bins bit-packed into uint32 lanes (the device wire format)
# ---------------------------------------------------------------------------
#
# Word layout (little-endian within a word, lane-tiled across words): the
# flat stream is padded with zeros to a whole number of TILES of
# vpw * PACK_LANES elements (vpw = 32 // bin_bits values per word), viewed
# row-major as [R, PACK_LANES], and word row w packs element rows
# w*vpw .. w*vpw+vpw-1: element [w*vpw + i, lane] occupies bits
# [i*bin_bits, (i+1)*bin_bits) of word [w, lane].  Bins are stored as
# bin_bits-wide two's complement (lossless: the quantizer confined them to
# (-maxbin, maxbin)).  Grouping rows instead of adjacent lanes keeps the
# pack a pure sublane shift/or on the TPU VPU, and makes the layout
# identical for any kernel block height that is a multiple of vpw — the
# Pallas kernels and this reference produce bit-identical words.

PACK_LANES = 128          # lane width of the packed tile (VPU native)
_PACK_WIDTHS = (1, 2, 4, 8, 16, 32)


def packed_word_count(n: int, bin_bits: int) -> int:
    """Number of uint32 words `pack_words` emits for n elements."""
    vpw = 32 // bin_bits
    tile = vpw * PACK_LANES
    return -(-n // tile) * PACK_LANES


def pack_words(values: jnp.ndarray, bin_bits: int) -> jnp.ndarray:
    """Pack flat int values into uint32 words (layout in the module note).

    values: int32/uint32[n] with each value representable in bin_bits
    (two's complement).  Returns uint32[packed_word_count(n, bin_bits)].
    jit-safe: pure reshape + shift/or reduction, no gathers.
    """
    if bin_bits not in _PACK_WIDTHS:
        raise ValueError(f"bin_bits must be one of {_PACK_WIDTHS}")
    vpw = 32 // bin_bits
    n = values.shape[0]
    n_words = packed_word_count(n, bin_bits)
    u = values.astype(jnp.uint32)
    if bin_bits != 32:
        u = u & jnp.uint32((1 << bin_bits) - 1)
    u = jnp.pad(u, (0, n_words * vpw - n))
    grp = u.reshape(-1, vpw, PACK_LANES)
    word = grp[:, 0, :]
    for i in range(1, vpw):
        word = word | (grp[:, i, :] << jnp.uint32(i * bin_bits))
    return word.reshape(-1)


def unpack_words(words: jnp.ndarray, n: int, bin_bits: int,
                 signed: bool = True) -> jnp.ndarray:
    """Inverse of pack_words.  Returns int32[n] (sign-extended) or
    uint32[n] when signed=False."""
    vpw = 32 // bin_bits
    w = words.reshape(-1, PACK_LANES)
    if vpw == 1:
        flat = w.reshape(-1)[:n]
    else:
        mask = jnp.uint32((1 << bin_bits) - 1)
        cols = [(w >> jnp.uint32(i * bin_bits)) & mask for i in range(vpw)]
        flat = jnp.stack(cols, axis=1).reshape(-1)[:n]
    if not signed:
        return flat
    if bin_bits == 32:
        return flat.astype(jnp.int32)
    sh = jnp.int32(32 - bin_bits)
    return (flat.astype(jnp.int32) << sh) >> sh     # arithmetic sign-extend


def pack_flags(flags: jnp.ndarray) -> jnp.ndarray:
    """bool[n] -> uint32[ceil-to-tile(n/32)] at 1 bit/value (sign plane)."""
    return pack_words(flags.astype(jnp.uint32), 1)


# ---------------------------------------------------------------------------
# SHUFFLE — byte-plane shuffle with zigzag sign-fold (a lossless word stage)
# ---------------------------------------------------------------------------
#
# Two's-complement small negatives (0xFF.. sign extension) set the high
# bits of every word they touch, so the §6 width codes never fire on
# mixed-sign bin streams.  The shuffle stage (DESIGN.md §7) fixes that in
# two exactly-reversible moves, the byte-level analogue of FZ-GPU's
# bitshuffle (arXiv 2304.12557):
#
#   1. ZIGZAG fold each `width`-bit lane: z = (v << 1) ^ (v >> width-1),
#      so small |v| of EITHER sign has small z (clear high bytes);
#   2. byte-plane TRANSPOSE (width < 32): byte j of every lane becomes a
#      contiguous plane, so the cleared high bytes form whole all-zero
#      chunks the §6 coder drops.  At width == 32 a lane IS a word and the
#      §6 width codes already select trailing zero byte planes, so the
#      transpose is the identity and only the fold is applied — which is
#      exactly what makes `narrow` chunks fire on mixed-sign bins.
#
# The stream is padded to whole PACK_LANES tiles (zeros fold to zeros, so
# truncation on decode is exact); output length = shuffle_word_count(n).


def _width_mask(width: int) -> jnp.ndarray:
    return jnp.uint32(0xFFFFFFFF if width == 32 else (1 << width) - 1)


def _zigzag(lanes: jnp.ndarray, width: int) -> jnp.ndarray:
    """uint32 lanes holding width-bit two's complement -> zigzag codes."""
    sh = jnp.int32(32 - width)
    v = (lanes.astype(jnp.int32) << sh) >> sh          # sign-extend
    z = (v << jnp.int32(1)) ^ (v >> jnp.int32(31))
    return z.astype(jnp.uint32) & _width_mask(width)


def _unzigzag(z: jnp.ndarray, width: int) -> jnp.ndarray:
    v = (z >> jnp.uint32(1)) ^ (jnp.uint32(0) - (z & jnp.uint32(1)))
    return v & _width_mask(width)


def shuffle_word_count(n_words: int) -> int:
    """Words `shuffle_words` emits for an n_words stream (tile-padded)."""
    return -(-n_words // PACK_LANES) * PACK_LANES


def shuffle_words(words: jnp.ndarray, width: int) -> jnp.ndarray:
    """Fold + byte-plane-shuffle a packed uint32 word stream whose lanes
    are `width`-bit values (width in {8, 16, 32}).  jit-safe, exact
    inverse is unshuffle_words."""
    if width not in (8, 16, 32):
        raise ValueError(f"shuffle width must be 8, 16 or 32, got {width}")
    n_words = words.shape[0]
    npad = shuffle_word_count(n_words)
    w = jnp.pad(words, (0, npad - n_words))
    if width == 32:
        return _zigzag(w, 32)
    lanes = unpack_words(w, npad * 32 // width, width, signed=False)
    z = _zigzag(lanes, width)
    planes = [(z >> jnp.uint32(8 * j)) & jnp.uint32(0xFF)
              for j in range(width // 8)]
    return pack_words(jnp.concatenate(planes), 8)


def unshuffle_words(shuffled: jnp.ndarray, n_words: int,
                    width: int) -> jnp.ndarray:
    """Exact inverse of shuffle_words; n_words is the pre-shuffle count."""
    npad = shuffle_word_count(n_words)
    if width == 32:
        return _unzigzag(shuffled[:npad], 32)[:n_words]
    n_lanes = npad * 32 // width
    stream = unpack_words(shuffled, 4 * npad, 8, signed=False)
    planes = stream.reshape(width // 8, n_lanes)
    z = planes[0]
    for j in range(1, width // 8):
        z = z | (planes[j] << jnp.uint32(8 * j))
    return pack_words(_unzigzag(z, width), width)[:n_words]


def unpack_flags(words: jnp.ndarray, n: int) -> jnp.ndarray:
    return unpack_words(words, n, 1, signed=False).astype(bool)


class EncodedPacked(NamedTuple):
    """COMPACT with device-side bit-packed bins — the actual wire format.

    Everything here is what crosses the collective: uint32 words, the
    capped exact-outlier table, and an 8-byte header (n_outliers/overflow +
    eb).  No full-width bins, no bool plane, no recon plane.
    """
    words: jnp.ndarray        # uint32[n_words] — bin_bits-wide packed bins
    out_idx: jnp.ndarray      # int32[K], n = "empty slot"
    out_payload: jnp.ndarray  # uint32[K] — original IEEE bits, bit-exact
    n_outliers: jnp.ndarray   # int32 scalar
    overflow: jnp.ndarray     # bool scalar: n_outliers > K (bound NOT met)
    sign_words: jnp.ndarray | None  # uint32[n_sign_words] (REL only)
    eb: jnp.ndarray | None    # traced scalar bound (NOA / per-tensor eb)

    def wire_bits(self, cfg: QuantizerConfig | None = None) -> int:
        """Static wire size in bits — exactly the bytes the collective
        moves, tile padding included.  vs EncodedCompact (whose bins are
        also bin_bits-wide): the sign plane is 1 bit/value instead of a
        byte-wide bool, and everything rides uint32 lanes."""
        bits = 32 * self.words.shape[0]
        bits += self.out_idx.shape[0] * (32 + 32)
        if self.sign_words is not None:
            bits += 32 * self.sign_words.shape[0]
        return bits + 64                     # n_outliers/overflow + eb header


def encode_packed(x: jnp.ndarray, cfg: QuantizerConfig, eb=None, *,
                  return_quantized: bool = False,
                  bin_transform=None) -> EncodedPacked:
    """Quantize + bit-pack in one jit-safe call (reference path; the fused
    Pallas pipeline in kernels/pack.py is its bit-exact device twin).
    With return_quantized, also returns the local Quantized (outlier/recon
    planes stay on-device for residual bookkeeping, never on the wire).
    `bin_transform` (optional) is an exact int32 bijection applied to the
    bin plane just before packing — the value-domain predictor hook
    (core.predict / DESIGN.md §9).  It must be inverted by the matching
    `bin_untransform` in decode_packed; the returned Quantized keeps the
    UNtransformed bins so residual bookkeeping stays in the value domain."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = cfg.outlier_cap(n)
    if cfg.mode == "abs":
        qt = q.quantize_abs(flat, cfg, eb=eb)
    elif cfg.mode == "rel":
        qt = q.quantize_rel(flat, cfg)
    else:
        qt, eb = q.quantize_noa(flat, cfg)
    n_out = jnp.sum(qt.outlier).astype(jnp.int32)
    (idx,) = jnp.nonzero(qt.outlier, size=k, fill_value=n)
    safe_idx = jnp.minimum(idx, n - 1)
    payload = jnp.where(idx < n, float_to_bits(flat)[safe_idx], 0)
    bins = qt.bins if bin_transform is None else bin_transform(qt.bins)
    words = pack_words(bins, cfg.bin_bits)
    sign_words = None if qt.sign is None else pack_flags(qt.sign)
    enc = EncodedPacked(words, idx.astype(jnp.int32),
                        payload.astype(jnp.uint32), n_out, n_out > k,
                        sign_words,
                        None if eb is None else jnp.asarray(eb, flat.dtype))
    return (enc, qt) if return_quantized else enc


def decode_packed(enc: EncodedPacked, cfg: QuantizerConfig, n: int | None = None,
                  shape=None, dtype=None, bin_untransform=None):
    """Unpack + dequantize + exact outlier restore.  `n` (or `shape`) gives
    the true element count — the packed stream carries pad words.
    `bin_untransform` inverts the encode-side `bin_transform` on the
    unpacked plane before dequantize (core.predict / DESIGN.md §9)."""
    if n is None:
        if shape is None:
            raise ValueError("decode_packed needs n or shape")
        n = int(np.prod(shape))
    dt = jnp.dtype(dtype or cfg.dtype)
    bins = unpack_words(enc.words, n, cfg.bin_bits)
    if bin_untransform is not None:
        bins = bin_untransform(bins)
    if cfg.mode == "rel":
        sign = unpack_flags(enc.sign_words, n)
        recon = q.dequantize_rel(bins, sign, cfg, dtype=dt)
    else:
        recon = q.dequantize_abs(bins, cfg, eb=enc.eb, dtype=dt)
    vals = bits_to_float(enc.out_payload.astype(jnp.int32), dt)
    recon = recon.at[enc.out_idx].set(vals, mode="drop")
    return recon.reshape(shape) if shape is not None else recon


# ---------------------------------------------------------------------------
# LC layout — device-side lossless stage over the packed word stream
# ---------------------------------------------------------------------------
#
# The paper's LC pipeline follows quantize+pack with a lossless coder — the
# stage GPU compressors win their ratio in (cuSZ's Huffman over quantization
# codes, FZ-GPU's bitshuffle + zero-suppression).  This is the TPU-shaped
# equivalent (DESIGN.md §6): the packed uint32 word stream is split into
# chunks of LC_CHUNK = 512 words (4 sublane rows x 128 lanes), and each
# chunk is stored at the minimal word width it needs:
#
#   code 0 — all words zero: the chunk is dropped entirely (dominant for
#            smooth/sparse gradients where most bins hit the zero bin);
#   code 1 — every word < 2^8:  stored at  8 bits/word (4 words/uint32);
#   code 2 — every word < 2^16: stored at 16 bits/word (2 words/uint32);
#   code 3 — verbatim uint32 words.
#
# A chunk's narrowed image IS pack_words(chunk_words, width): LC_CHUNK was
# chosen so one chunk is a whole pack tile at width 8 (vpw 4 * 128 lanes)
# and two tiles at width 16 — the narrowing reuses the sublane shift/or
# machinery and therefore fuses into the same kernels (kernels/lossless.py).
# The 2-bit codes pack into a header plane via pack_words(codes, 2).
#
# XLA needs static shapes, so the variable-length payload is carried
# padded-to-capacity (n_chunks * LC_CHUNK words) with the used word count
# transmitted in `payload_len` — a real transport moves only payload_len
# words plus the header plane; wire_bits() accounts exactly that.
# encode 'stage' selects the mode: 'zero' restricts codes to {0, 3} (zero
# suppression only), 'narrow' uses the full set.

LC_CHUNK = 512                 # words per chunk (4 x PACK_LANES)
LC_STAGES = ("zero", "narrow")
_LC_WIDTHS = (0, 8, 16, 32)    # stored word width per header code
_LC_LENS = tuple(LC_CHUNK * w // 32 for w in _LC_WIDTHS)   # payload words


def transmitted_bits(payload_len, static_bits: int):
    """THE traced transmitted-size accounting every accessor shares
    (`Pipeline.wire_bits`, `EncodedLC.wire_bits`, `stage_report`,
    `transport._kv_wire_bytes`): `static_bits` (a python int — headers,
    tables, length fields) plus 32 bits per transmitted payload word.
    The static part is folded into the WORD count as exact int32 and
    converted to f32 ONCE: exact through 2^24 total words, one final
    rounding (never accumulated drift) beyond, and well-defined up to
    2^31 words (8 GiB of payload — beyond any single wire this repo can
    hold in device memory, since the padded capacity buffer is at least
    as large; int32 would wrap past that, f32-per-term would drift far
    sooner).  This JAX has no int64, hence the envelope."""
    static_words, rem = divmod(static_bits, 32)
    words = payload_len + jnp.int32(static_words)
    return 32.0 * words.astype(jnp.float32) + rem


def lc_chunk_count(n_words: int) -> int:
    return -(-n_words // LC_CHUNK)


def lc_header_words(n_words: int) -> int:
    """uint32 words in the STORED 2-bit header plane for an n_words stream
    (tile-padded per the §4 layout, pad words zero)."""
    return packed_word_count(lc_chunk_count(n_words), 2)


def lc_header_content_words(n_chunks: int) -> int:
    """uint32 words of real header content — 16 two-bit codes per word.
    This is what a transport moves; the stored plane is tile-padded to
    lc_header_words(...) with zeros the receiver re-pads, exactly like the
    payload's capacity padding."""
    return -(-n_chunks // 16)


def lc_chunk_codes(chunks: jnp.ndarray, stage: str) -> jnp.ndarray:
    """Per-chunk width code.  chunks: uint32[n_chunks, LC_CHUNK]."""
    if stage not in LC_STAGES:
        raise ValueError(f"lossless stage must be one of {LC_STAGES}")
    mx = jnp.max(chunks, axis=1)
    zero = mx == 0
    if stage == "zero":
        return jnp.where(zero, 0, 3).astype(jnp.int32)
    return jnp.where(zero, 0,
                     jnp.where(mx < (1 << 8), 1,
                               jnp.where(mx < (1 << 16), 2, 3))
                     ).astype(jnp.int32)


def lc_chunk_lens(codes: jnp.ndarray) -> jnp.ndarray:
    """Payload words each chunk occupies, from its header code."""
    return jnp.take(jnp.asarray(_LC_LENS, jnp.int32), codes)


def lc_narrow_chunks(chunks: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Narrow each chunk to its code's width, left-aligned and zero-padded
    to LC_CHUNK (the compaction scatter strips the padding)."""
    n_chunks = chunks.shape[0]
    flat = chunks.reshape(-1)
    # full-stream pack groups whole chunks (LC_CHUNK is a tile multiple for
    # both widths), so this equals a per-chunk pack_words — and equals the
    # kernels' sublane _pack_block on the same rows.
    cand1 = pack_words(flat, 8).reshape(n_chunks, LC_CHUNK // 4)
    cand2 = pack_words(flat, 16).reshape(n_chunks, LC_CHUNK // 2)
    pad1 = jnp.pad(cand1, ((0, 0), (0, LC_CHUNK - LC_CHUNK // 4)))
    pad2 = jnp.pad(cand2, ((0, 0), (0, LC_CHUNK - LC_CHUNK // 2)))
    c = codes[:, None]
    return jnp.where(c == 1, pad1,
                     jnp.where(c == 2, pad2,
                               jnp.where(c == 3, chunks, jnp.uint32(0))))


def compact_chunks(sel: jnp.ndarray, lens: jnp.ndarray):
    """Concatenate per-chunk word prefixes at their true lengths.  sel:
    uint32[n_chunks, LC_CHUNK] (each chunk's payload left-aligned), lens:
    int32[n_chunks] words used per chunk (<= LC_CHUNK).  Returns (payload
    uint32[n_chunks * LC_CHUNK] with the tail zero, payload_len int32
    scalar — the words a real transport moves).  Shared by the zero/
    narrow chunk coder and the `ent` entropy stage."""
    n_chunks = sel.shape[0]
    cap = n_chunks * LC_CHUNK
    ends = jnp.cumsum(lens)
    offs = ends - lens
    slot = jnp.arange(LC_CHUNK, dtype=jnp.int32)[None, :]
    dest = jnp.where(slot < lens[:, None], offs[:, None] + slot, cap)
    payload = jnp.zeros((cap,), jnp.uint32).at[dest.reshape(-1)].set(
        sel.reshape(-1), mode="drop")
    return payload, ends[-1].astype(jnp.int32)


def gather_chunks(payload: jnp.ndarray, lens: jnp.ndarray) -> jnp.ndarray:
    """Inverse of compact_chunks: re-pad each chunk's words to LC_CHUNK
    slots.  Returns uint32[n_chunks, LC_CHUNK].

    Corrupt (over-long) transmitted lengths would otherwise index past
    the padded plane; the clamp makes the gather deterministic on every
    backend — host-side length validation with a structured error lives
    at the decode entries (audit.check_payload_len, DESIGN.md §12)."""
    ends = jnp.cumsum(lens)
    offs = ends - lens
    slot = jnp.arange(LC_CHUNK, dtype=jnp.int32)[None, :]
    valid = slot < lens[:, None]
    src = jnp.where(valid, offs[:, None] + slot, 0)
    src = jnp.clip(src, 0, jnp.int32(payload.shape[0] - 1))
    return jnp.where(valid, payload[src], jnp.uint32(0))


def lc_compact_payload(sel: jnp.ndarray, codes: jnp.ndarray):
    """compact_chunks with the §6 per-code chunk lengths."""
    return compact_chunks(sel, lc_chunk_lens(codes))


def lc_gather_chunks(payload: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of lc_compact_payload: re-pad each chunk's narrowed words to
    LC_CHUNK slots.  Returns uint32[n_chunks, LC_CHUNK]."""
    return gather_chunks(payload, lc_chunk_lens(codes))


def lc_expand_chunks(padded: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Widen narrowed chunks back to uint32 words (exact inverse of
    lc_narrow_chunks for the valid prefix)."""
    n_chunks = padded.shape[0]
    flat_n = n_chunks * LC_CHUNK
    exp1 = unpack_words(padded[:, :LC_CHUNK // 4].reshape(-1), flat_n, 8,
                        signed=False).reshape(n_chunks, LC_CHUNK)
    exp2 = unpack_words(padded[:, :LC_CHUNK // 2].reshape(-1), flat_n, 16,
                        signed=False).reshape(n_chunks, LC_CHUNK)
    c = codes[:, None]
    return jnp.where(c == 1, exp1,
                     jnp.where(c == 2, exp2,
                               jnp.where(c == 3, padded, jnp.uint32(0))))


def encode_words_lc(words: jnp.ndarray, stage: str = "narrow"):
    """Lossless-code a packed uint32 word stream (layout in the module
    note).  Returns (header_words, payload, payload_len); jit-safe, exact.
    Reusable on any word plane (gradient shards, KV pages, sign planes)."""
    n_words = words.shape[0]
    n_chunks = lc_chunk_count(n_words)
    wpad = jnp.pad(words, (0, n_chunks * LC_CHUNK - n_words))
    chunks = wpad.reshape(n_chunks, LC_CHUNK)
    codes = lc_chunk_codes(chunks, stage)
    sel = lc_narrow_chunks(chunks, codes)
    payload, plen = lc_compact_payload(sel, codes)
    return pack_words(codes, 2), payload, plen


def decode_words_lc(header_words: jnp.ndarray, payload: jnp.ndarray,
                    n_words: int) -> jnp.ndarray:
    """Exact inverse of encode_words_lc.  n_words is the pre-coding word
    count (packed_word_count of the element count)."""
    n_chunks = lc_chunk_count(n_words)
    codes = unpack_words(header_words, n_chunks, 2,
                         signed=False).astype(jnp.int32)
    padded = lc_gather_chunks(payload, codes)
    return lc_expand_chunks(padded, codes).reshape(-1)[:n_words]


class EncodedLC(NamedTuple):
    """PACKED after the device-side lossless stage — the compressed wire.

    `payload` is padded to static capacity for XLA; only `payload_len`
    words of it (plus the header plane and the outlier table) are
    meaningful, and wire_bits() counts exactly those.  decode_lossless
    reproduces the EncodedPacked bit-for-bit, so every guarantee statement
    about PACKED carries over verbatim.  Layout: DESIGN.md §6.
    """
    header_words: jnp.ndarray   # uint32 — 2-bit per-chunk width codes
    payload: jnp.ndarray        # uint32[capacity] — compacted chunk data
    payload_len: jnp.ndarray    # int32 scalar — words actually used
    out_idx: jnp.ndarray        # int32[K], n = "empty slot"
    out_payload: jnp.ndarray    # uint32[K] — original IEEE bits
    n_outliers: jnp.ndarray     # int32 scalar
    overflow: jnp.ndarray       # bool scalar (bound NOT met when True)
    sign_words: jnp.ndarray | None  # uint32 (REL only, not lossless-coded)
    eb: jnp.ndarray | None      # traced scalar bound

    def wire_bits(self, cfg: QuantizerConfig | None = None):
        """Transmitted wire size in bits.  Traced (data-dependent) because
        the payload is variable-length; +32 for the transmitted length.
        Counts the header plane's content words only (its tile padding is
        zeros the receiver re-pads, like the payload's capacity padding).
        Routed through `transmitted_bits` — exact int32 word
        accumulation with one f32 conversion (see its docstring for the
        precision envelope)."""
        n_chunks = self.payload.shape[0] // LC_CHUNK
        static = 32 * lc_header_content_words(n_chunks)
        static += self.out_idx.shape[0] * (32 + 32)
        if self.sign_words is not None:
            static += 32 * self.sign_words.shape[0]
        static += 64 + 32           # packed header + payload_len field
        return transmitted_bits(self.payload_len, static)


# ---------------------------------------------------------------------------
# ENT — static canonical entropy coder over surviving chunk payloads (§7)
# ---------------------------------------------------------------------------
#
# The ratio the §6 width codes leave on the table is sub-byte: a surviving
# narrowed chunk still spends a full 8 bits on every byte even when the
# byte distribution is heavily skewed (small bins cluster around 0x00/0xFF).
# The `ent` word stage closes that gap cuSZ-style — a STATIC codebook built
# from the symbol histogram, transmitted in the stage's header plane — with
# FZ-GPU's lesson kept intact: the transform is an exact, reversible pass
# over the device word stream, so the §1 guarantee is untouched.
#
# Layout.  The input word stream is chunked exactly like §6 (LC_CHUNK = 512
# words).  Each chunk gets a 2-bit mode code:
#
#   mode 0 — all words zero: dropped entirely (0 payload words);
#   mode 1 — entropy-coded: the chunk's 2048 bytes (little-endian within
#            each word) encode as a variable-length bitstream, padded to a
#            whole word count, bit length transmitted per chunk;
#   mode 2 — verbatim escape: the coded stream would exceed the chunk's
#            raw 512 words (incompressible bytes), so the chunk is stored
#            untouched — `ent` never costs more than the header planes.
#
# The codebook is one canonical prefix code shared by every chunk of the
# stream, built from the byte histogram of the SURVIVING (non-zero) chunks:
# per-symbol Shannon lengths ceil(-log2 p) — read off the f32 exponent
# bits, no transcendentals, so the wire is deterministic integer work —
# clipped to ENT_MAX_LEN, then a Kraft-budget sweep over symbols in
# descending frequency guarantees sum 2^-l <= 1 (a canonical code always
# exists; frequent symbols keep their ideal lengths).  Only the 256 4-bit
# LENGTHS are transmitted — canonical codes and the 2^ENT_MAX_LEN decode
# LUT rebuild from lengths alone, the classic canonical-Huffman trick.
#
# Bit order: codes deposit first-bit-at-lowest-bit (LSB-first within
# uint32 words), so encode is a cumsum + disjoint-bit scatter-add and
# decode reads a 32-bit window per symbol.  Chunks encode independently —
# decode is a per-chunk scan (2048 symbols) vmapped across chunks, the
# same independence cuSZ uses to parallelize Huffman on GPUs.  The jit
# reference lives here; a fused Pallas kernel slot is documented in the
# §7 dispatch table.

ENT_MAX_LEN = 12               # max code length; decode LUT = 2^12 entries
ENT_SYMS = 256                 # byte alphabet
_ENT_CHUNK_SYMS = 4 * LC_CHUNK            # 2048 coded bytes per chunk
_ENT_CHUNK_CAP_BITS = 32 * LC_CHUNK       # verbatim-escape threshold
_ENT_BUF_WORDS = _ENT_CHUNK_SYMS * ENT_MAX_LEN // 32   # worst-case coded

# Static bit-reversal table for ENT_MAX_LEN-bit values (the canonical
# code is MSB-first; the stream is LSB-first — see the bit-order note).
_rev = np.zeros(1 << ENT_MAX_LEN, np.int32)
for _j in range(ENT_MAX_LEN):
    _rev = (_rev << 1) | ((np.arange(1 << ENT_MAX_LEN) >> _j) & 1)
_ENT_REV = _rev
del _rev, _j


def ent_header_words(n_words: int) -> int:
    """uint32 words in the STORED `ent` header plane: the 4-bit codebook
    lengths, the 2-bit per-chunk modes, and the 16-bit per-chunk bit
    lengths, each tile-padded per the §4 pack layout."""
    nc = lc_chunk_count(n_words)
    return (packed_word_count(ENT_SYMS, 4) + packed_word_count(nc, 2)
            + packed_word_count(nc, 16))


def ent_header_content_words(n_chunks: int) -> int:
    """uint32 words of real header content (what a transport moves; the
    stored plane's tile padding is zeros the receiver re-pads): 32 words
    of codebook lengths + 2 bits/chunk of modes + 16 bits/chunk of bit
    lengths."""
    return (ENT_SYMS * 4 // 32 + lc_header_content_words(n_chunks)
            + -(-n_chunks // 2))


def _floor_log2_f32(x: jnp.ndarray) -> jnp.ndarray:
    """floor(log2 x) for positive normal f32 — the unbiased exponent,
    pure integer work (deterministic on every backend)."""
    return ((float_to_bits(x) >> 23) & 0xFF) - 127


def ent_code_lengths(hist: jnp.ndarray) -> jnp.ndarray:
    """Length-limited code lengths (1..ENT_MAX_LEN) from a 256-bin symbol
    histogram (int32[256]).  Shannon ideal ceil(-log2 p) per symbol
    (= -floor_log2(p) exactly, read off the f32 exponent), clipped, then
    repaired to Kraft-feasibility by a budget scan in descending
    frequency order: each symbol takes the longest of its ideal length
    and the shortest length the remaining budget can afford while
    leaving one 2^-ENT_MAX_LEN slot per remaining symbol.  The budget
    invariant guarantees sum 2^-l <= 1, so canonical codes exist."""
    lmax = ENT_MAX_LEN
    total = jnp.maximum(jnp.sum(hist), 1).astype(jnp.float32)
    p = jnp.maximum(hist.astype(jnp.float32) / total, jnp.float32(2.0**-126))
    ideal = jnp.where(hist > 0, -_floor_log2_f32(p), lmax)
    ideal = jnp.clip(ideal, 1, lmax).astype(jnp.int32)
    order = jnp.argsort(-hist)                 # frequency descending
    remaining = jnp.arange(ENT_SYMS - 1, -1, -1, dtype=jnp.int32)

    def step(budget, inp):
        want, rem = inp
        lmin = lmax - _floor_log2_f32((budget - rem).astype(jnp.float32))
        lens = jnp.clip(jnp.maximum(want, lmin), 1, lmax)
        return budget - (jnp.int32(1) << (lmax - lens)), lens

    _, lens_sorted = jax.lax.scan(step, jnp.int32(1 << lmax),
                                  (ideal[order], remaining))
    return jnp.zeros(ENT_SYMS, jnp.int32).at[order].set(lens_sorted)


def _ent_canonical(lens: jnp.ndarray):
    """Canonical code assignment from lengths: symbols sorted by
    (length, symbol) take consecutive codes within their length class.
    Returns (order int32[256] = symbols in canonical order, codes
    MSB-first per canonical position, first-bit-aligned code starts)."""
    lmax = ENT_MAX_LEN
    count = jnp.zeros(lmax + 1, jnp.int32).at[lens].add(1)
    first, code = [jnp.int32(0)] * (lmax + 1), jnp.int32(0)
    for ln in range(1, lmax + 1):
        code = (code + count[ln - 1]) << 1
        first[ln] = code
    first = jnp.stack(first)
    order = jnp.argsort(lens)                  # stable: (length, symbol)
    sl = lens[order]
    rank = jnp.arange(ENT_SYMS, dtype=jnp.int32) - jnp.searchsorted(
        sl, sl, side="left").astype(jnp.int32)
    codes = first[sl] + rank
    return order, sl, codes


def ent_encode_table(lens: jnp.ndarray):
    """(length, LSB-first deposit value) per SYMBOL, from the code
    lengths: the deposit value is the canonical code bit-reversed within
    its length so its first (most-significant) bit lands first in the
    LSB-first stream."""
    order, sl, codes = _ent_canonical(lens)
    rev = jnp.asarray(_ENT_REV)[codes] >> (ENT_MAX_LEN - sl)
    return (jnp.zeros(ENT_SYMS, jnp.int32).at[order].set(sl),
            jnp.zeros(ENT_SYMS, jnp.uint32).at[order].set(
                rev.astype(jnp.uint32)))


def ent_decode_lut(lens: jnp.ndarray):
    """(symbol, length) decode LUT indexed by the next ENT_MAX_LEN raw
    stream bits (LSB-first window): canonical code starts are sorted, so
    the matching symbol is a searchsorted over the MSB-aligned window,
    composed with the static bit-reversal."""
    lmax = ENT_MAX_LEN
    order, sl, codes = _ent_canonical(lens)
    starts = codes << (lmax - sl)              # strictly increasing
    win = jnp.asarray(_ENT_REV)                # raw window -> MSB-aligned
    j = jnp.clip(jnp.searchsorted(starts, win, side="right") - 1,
                 0, ENT_SYMS - 1)
    return order[j].astype(jnp.int32), sl[j]


def _ent_chunk_bytes(chunks: jnp.ndarray) -> jnp.ndarray:
    """uint32[nc, LC_CHUNK] -> int32[nc, 4*LC_CHUNK] byte symbols in
    stream order (little-endian within each word)."""
    b = jnp.stack([(chunks >> jnp.uint32(8 * j)) & jnp.uint32(0xFF)
                   for j in range(4)], axis=-1)
    return b.reshape(chunks.shape[0], _ENT_CHUNK_SYMS).astype(jnp.int32)


def encode_words_ent(words: jnp.ndarray):
    """Entropy-code a packed uint32 word stream (layout in the module
    note).  Returns (header_words, payload, payload_len); jit-safe,
    exact inverse is decode_words_ent.  Reusable on any word plane —
    gradient shards, KV pages — like every §7 word stage."""
    n_words = words.shape[0]
    nc = lc_chunk_count(n_words)
    wpad = jnp.pad(words, (0, nc * LC_CHUNK - n_words))
    chunks = wpad.reshape(nc, LC_CHUNK)
    alive = jnp.max(chunks, axis=1) > 0
    byts = _ent_chunk_bytes(chunks)
    # codebook from the byte histogram of SURVIVING chunks only — zero
    # chunks are dropped whole and must not skew the code lengths
    hist = jnp.zeros(ENT_SYMS, jnp.int32).at[byts.reshape(-1)].add(
        jnp.repeat(alive.astype(jnp.int32), _ENT_CHUNK_SYMS))
    lens = ent_code_lengths(hist)
    sym_len, sym_code = ent_encode_table(lens)

    # per-chunk bitstream: cumsum the code lengths, deposit each code's
    # <= 2 word fragments by scatter-ADD (bits are disjoint, so add == or)
    lns = sym_len[byts]
    ends = jnp.cumsum(lns, axis=1)
    offs = ends - lns
    bitlen = ends[:, -1]
    code = sym_code[byts]
    w_idx = offs >> 5
    boff = (offs & 31).astype(jnp.uint32)
    lo = code << boff
    hi = jnp.where(boff > 0,
                   code >> jnp.where(boff > 0, jnp.uint32(32) - boff,
                                     jnp.uint32(1)),
                   jnp.uint32(0))

    def deposit(wi, lo_, hi_):
        buf = jnp.zeros((_ENT_BUF_WORDS + 1,), jnp.uint32)
        return buf.at[wi].add(lo_).at[wi + 1].add(hi_)

    coded = jax.vmap(deposit)(w_idx, lo, hi)[:, :LC_CHUNK]
    modes = jnp.where(~alive, 0,
                      jnp.where(bitlen <= _ENT_CHUNK_CAP_BITS, 1, 2)
                      ).astype(jnp.int32)
    m = modes[:, None]
    sel = jnp.where(m == 1, coded, jnp.where(m == 2, chunks, jnp.uint32(0)))
    lens_words = jnp.where(modes == 1, (bitlen + 31) >> 5,
                           jnp.where(modes == 2, LC_CHUNK, 0)
                           ).astype(jnp.int32)
    payload, plen = compact_chunks(sel, lens_words)
    header = jnp.concatenate([
        pack_words(lens, 4),
        pack_words(modes, 2),
        pack_words(jnp.where(modes == 1, bitlen, 0), 16)])
    return header, payload, plen


def decode_words_ent(header_words: jnp.ndarray, payload: jnp.ndarray,
                     n_words: int) -> jnp.ndarray:
    """Exact inverse of encode_words_ent.  n_words is the pre-coding word
    count; everything needed to decode (codebook lengths, per-chunk modes
    and bit lengths) rides in the header plane."""
    nc = lc_chunk_count(n_words)
    hw_len = packed_word_count(ENT_SYMS, 4)
    hw_mode = packed_word_count(nc, 2)
    lens = unpack_words(header_words[:hw_len], ENT_SYMS, 4,
                        signed=False).astype(jnp.int32)
    modes = unpack_words(header_words[hw_len:hw_len + hw_mode], nc, 2,
                         signed=False).astype(jnp.int32)
    bitlen = unpack_words(header_words[hw_len + hw_mode:], nc, 16,
                          signed=False).astype(jnp.int32)
    lens_words = jnp.where(modes == 1, (bitlen + 31) >> 5,
                           jnp.where(modes == 2, LC_CHUNK, 0)
                           ).astype(jnp.int32)
    padded = gather_chunks(payload, lens_words)
    lut_sym, lut_len = ent_decode_lut(lens)
    buf = jnp.pad(padded, ((0, 0), (0, 1)))    # window reads cross words

    def dec_chunk(cw):
        def step(pos, _):
            wi = pos >> 5
            bo = (pos & 31).astype(jnp.uint32)
            win = (cw[wi] >> bo) | jnp.where(
                bo > 0,
                cw[wi + 1] << jnp.where(bo > 0, jnp.uint32(32) - bo,
                                        jnp.uint32(1)),
                jnp.uint32(0))
            u = (win & jnp.uint32((1 << ENT_MAX_LEN) - 1)).astype(jnp.int32)
            # clamp: mode-0/2 lanes decode garbage that the mode mask
            # discards, but their positions must stay inside the padded
            # row (a fused-kernel port has no OOB-gather clamping); a
            # real mode-1 stream never exceeds the cap, so this is a
            # no-op for it
            nxt = jnp.minimum(pos + lut_len[u],
                              jnp.int32(_ENT_CHUNK_CAP_BITS))
            return nxt, lut_sym[u].astype(jnp.uint32)

        _, syms = jax.lax.scan(step, jnp.int32(0), None,
                               length=_ENT_CHUNK_SYMS)
        b = syms.reshape(LC_CHUNK, 4)
        return (b[:, 0] | (b[:, 1] << jnp.uint32(8))
                | (b[:, 2] << jnp.uint32(16)) | (b[:, 3] << jnp.uint32(24)))

    decoded = jax.vmap(dec_chunk)(buf)
    m = modes[:, None]
    out = jnp.where(m == 1, decoded,
                    jnp.where(m == 2, padded, jnp.uint32(0)))
    return out.reshape(-1)[:n_words]


def encode_lossless(enc: EncodedPacked, stage: str = "narrow") -> EncodedLC:
    """Run the device-side lossless stage over an EncodedPacked (reference
    path; kernels/lossless.py is its bit-exact Pallas twin)."""
    header_words, payload, plen = encode_words_lc(enc.words, stage)
    return EncodedLC(header_words, payload, plen, enc.out_idx,
                     enc.out_payload, enc.n_outliers, enc.overflow,
                     enc.sign_words, enc.eb)


def decode_lossless(lc: EncodedLC, n_words: int) -> EncodedPacked:
    """Exact inverse of encode_lossless; n_words as in decode_words_lc."""
    words = decode_words_lc(lc.header_words, lc.payload, n_words)
    return EncodedPacked(words, lc.out_idx, lc.out_payload, lc.n_outliers,
                         lc.overflow, lc.sign_words, lc.eb)
