"""Pure-numpy mirror of the quantizers — the 'other device' for parity tests.

The paper's parity requirement is that two independent implementations on
different hardware/compilers produce bit-identical compressed streams.  In
this container we cannot run a real TPU, so the parity test is: the JAX
(XLA:CPU) quantizer and this numpy implementation — two independent
compiler stacks — must agree bit-for-bit on bins, outlier flags, and
reconstructions.  That only holds because every op used is IEEE-754
add/sub/mul/cmp, integer ops, or bitcasts (the paper's discipline); a
version using library log/pow fails this test (demonstrated in
benchmarks/rel_parity_ratio.py).
"""
from __future__ import annotations

import numpy as np

from .config import QuantizerConfig

_SPEC = {
    np.dtype(np.float32): (np.int32, np.uint32, 23, 0xFF, 127),
    np.dtype(np.float64): (np.int64, np.uint64, 52, 0x7FF, 1023),
}


def log2approx(x: np.ndarray) -> np.ndarray:
    int_t, _, mb, emask, bias = _SPEC[x.dtype]
    orig_i = x.view(int_t)
    expo = (orig_i >> mb) & emask
    frac_i = ((int_t(bias) << mb) | (orig_i & ((int_t(1) << mb) - int_t(1))))
    frac_f = frac_i.astype(int_t).view(x.dtype)
    return frac_f + (expo - (bias + 1)).astype(x.dtype)


def pow2approx(log_f: np.ndarray) -> np.ndarray:
    int_t, _, mb, _, bias = _SPEC[log_f.dtype]
    biased = log_f + log_f.dtype.type(bias)
    with np.errstate(invalid="ignore"):
        expo = biased.astype(int_t)            # trunc toward zero (C cast)
    frac_f = biased - (expo - 1).astype(log_f.dtype)
    frac_i = frac_f.view(int_t)
    exp_i = (expo << mb) | (frac_i & ((int_t(1) << mb) - int_t(1)))
    return exp_i.view(log_f.dtype)


def quantize_abs(x: np.ndarray, cfg: QuantizerConfig, eb=None):
    from .config import _pow2_floor_np

    dt = x.dtype
    degenerate = False
    if eb is None:
        eb, eb2, inv_eb2 = cfg.abs_constants()
    else:
        # mirror of the traced-eb guard + pow2 step in quantizer.py
        eb = dt.type(eb)
        floor = dt.type(cfg.eb_floor)
        degenerate = not (eb >= floor)
        eb = max(eb, floor)
        eb2 = _pow2_floor_np(dt.type(2) * eb)
        inv_eb2 = dt.type(1) / eb2
    maxbin = cfg.maxbin

    finite = np.isfinite(x)
    xs = np.where(finite, x, dt.type(0))
    # Mask magnitudes whose xs * inv_eb2 would overflow before multiplying.
    # eb2 is a power of two, so the scaling is EXACT: |xs| <= max * eb2 iff
    # the product fits, and anything above it is a range outlier anyway
    # (|bin| would far exceed maxbin).  The decision is bit-identical to
    # the unmasked JAX path; this only silences the spurious overflow
    # RuntimeWarning, which would otherwise bury real regressions.
    thr = dt.type(min(float(np.finfo(dt).max) * float(eb2),
                      float(np.finfo(dt).max)))
    huge = np.abs(xs) > thr
    xs = np.where(huge, dt.type(0), xs)
    bin_f = np.rint(xs * inv_eb2)
    range_bad = huge | (np.abs(bin_f) >= dt.type(maxbin))
    with np.errstate(invalid="ignore"):
        bin_i = np.where(range_bad, 0, bin_f).astype(np.int32)
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)
    recon = bin_i.astype(dt) * eb2
    with np.errstate(invalid="ignore", over="ignore"):
        fails = ~(np.abs(x - recon) <= eb * dt.type(cfg.tighten))
    fails |= ~np.isfinite(recon)       # recon-overflow guard (see quantizer.py)
    outlier = (~finite) | range_bad | range_bad_i | fails | degenerate
    bins = np.where(outlier, 0, bin_i)
    recon = np.where(outlier, dt.type(0), recon)
    return bins, outlier, recon


def dequantize_abs(bins, cfg: QuantizerConfig, eb=None):
    from .config import _pow2_floor_np

    dt = cfg.np_dtype
    if eb is None:
        _, eb2, _ = cfg.abs_constants()
    else:
        eb_ = max(dt.type(eb), dt.type(cfg.eb_floor))
        eb2 = _pow2_floor_np(dt.type(2) * eb_)
    return bins.astype(dt) * eb2


def quantize_rel(x: np.ndarray, cfg: QuantizerConfig):
    dt = x.dtype
    eb, log_step, inv_log_step = cfg.rel_constants()
    maxbin = cfg.maxbin

    finite = np.isfinite(x)
    ax = np.abs(x)
    too_small = ~(ax >= dt.type(cfg.rel_screen_threshold()))
    safe = np.where(finite & ~too_small, ax, dt.type(1))
    lg = log2approx(safe)
    bin_f = np.rint(lg * inv_log_step)
    range_bad = np.abs(bin_f) >= dt.type(maxbin)
    with np.errstate(invalid="ignore"):
        bin_i = np.where(range_bad, 0, bin_f).astype(np.int32)
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)
    int_t = _SPEC[dt][0]
    neg = x.view(int_t) < 0          # bit-pattern sign (parity with JAX)
    mag = pow2approx(bin_i.astype(dt) * log_step)
    recon = np.where(neg, -mag, mag)
    ebT = dt.type(eb) * dt.type(cfg.tighten)
    with np.errstate(invalid="ignore"):
        ok = (np.abs(x - recon) <= ebT * ax)
    ok &= np.isfinite(recon)
    ok &= mag >= np.finfo(dt).tiny
    outlier = (~finite) | too_small | range_bad | range_bad_i | ~ok
    bins = np.where(outlier, 0, bin_i)
    return bins, outlier, np.where(outlier, dt.type(0), recon), neg


def dequantize_rel(bins, sign, cfg: QuantizerConfig):
    dt = cfg.np_dtype
    _, log_step, _ = cfg.rel_constants()
    mag = pow2approx(bins.astype(dt) * log_step)
    return np.where(sign, -mag, mag)


def quantize_noa(x: np.ndarray, cfg: QuantizerConfig):
    finite = np.isfinite(x)
    if finite.any():
        r = x[finite].max().astype(x.dtype) - x[finite].min().astype(x.dtype)
    else:
        r = x.dtype.type(0)
    eb = x.dtype.type(cfg.error_bound) * r
    bins, outlier, recon = quantize_abs(x, cfg, eb=eb)
    return bins, outlier, recon, eb
