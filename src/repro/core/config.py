"""Quantizer configuration and host-side derived constants.

All data-independent constants (eb2, 1/eb2, the REL log-step) are computed
ONCE on the host in double precision and then frozen to the target dtype.
Devices never evaluate a transcendental to derive them — a second parity
hazard the paper's framework avoids by baking constants into the compressed
header.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

Mode = str  # 'abs' | 'rel' | 'noa'

# Acceptance tightening (see quantizer.py): the double-check comparison is
# itself floating point.  Accepting only diff <= eb * TIGHTEN guarantees the
# TRUE error is <= eb even after the check's own rounding (a few ulps).  The
# margin is ~1e-6 relative for f32 — immeasurable in compression ratio.
TIGHTEN_F32 = 1.0 - 2.0 ** -18
TIGHTEN_F64 = 1.0 - 2.0 ** -40

# Denormal-flush hardening.  XLA backends (CPU and TPU) run with FTZ/DAZ:
# arithmetic that produces or consumes denormals flushes to zero, while
# numpy keeps IEEE gradual underflow.  Measured in this repo (see
# tests/test_parity.py::test_ftz_semantics_documented): under jit,
# 1e-20 * 1e-20 == 0.0.  Unguarded, the double-check can flush BOTH sides
# of `|x-r| <= eb*|x|` to zero and wrongly accept — the paper's §2.2
# denormal lesson reappearing one layer down.  Guards:
#   * ABS: eb must be >= EB_FLOOR so every denormal quantizes to bin 0
#     with true error < tiny <= eb under BOTH semantics (sound + parity).
#   * REL: magnitudes below rel_screen_threshold() are outliers, decided by
#     comparisons only (comparisons give identical answers under FTZ and
#     gradual underflow because the threshold is a normal number).
EB_FLOOR_F32 = 2.0 ** -120
EB_FLOOR_F64 = 2.0 ** -1000


def _pow2_floor_np(x):
    """Largest power of two <= x, by clearing mantissa bits (host mirror of
    bitops.pow2_floor)."""
    dt = x.dtype
    if dt == np.float32:
        bits = np.float32(x).view(np.uint32)
        return (bits & np.uint32(0xFF800000)).view(np.float32)
    bits = np.float64(x).view(np.uint64)
    return (bits & np.uint64(0xFFF0000000000000)).view(np.float64)


@dataclasses.dataclass(frozen=True)
class QuantizerConfig:
    """User-facing knobs for one LC-style guaranteed-error-bound quantizer."""

    mode: Mode = "abs"            # 'abs' | 'rel' | 'noa'
    error_bound: float = 1e-3     # eb (for 'noa': relative to value range R)
    bin_bits: int = 16            # storage width of bin numbers (sign incl.)
    dtype: str = "float32"        # data dtype: 'float32' | 'float64'
    outlier_cap_frac: float = 0.125  # compact codec: max outliers fraction
                                     # (paper Table 9 max observed: 11.16%)

    def __post_init__(self):
        if self.mode not in ("abs", "rel", "noa"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if not (self.error_bound > 0.0) or not math.isfinite(self.error_bound):
            raise ValueError("error_bound must be finite and positive")
        if self.bin_bits not in (8, 16, 32):
            raise ValueError("bin_bits must be 8, 16 or 32")
        if self.mode == "abs" and self.error_bound < self.eb_floor:
            raise ValueError(
                f"abs error_bound {self.error_bound} below the denormal-safe "
                f"floor {self.eb_floor} for {self.dtype} (see EB_FLOOR_* note)")

    @property
    def eb_floor(self) -> float:
        return EB_FLOOR_F64 if self.dtype == "float64" else EB_FLOOR_F32

    def rel_screen_threshold(self):
        """Smallest |x| the REL quantizer will bin; below it -> outlier.

        2 * max(tiny, tiny/eb), rounded UP: keeps every product in the
        double-check (`eb*T*|x|`) and every sub (`x - recon`) in the normal
        range, so FTZ backends and gradual-underflow backends make the SAME
        accept/reject decision and the bound is sound under both.
        """
        dt = self.np_dtype
        tiny = float(np.finfo(dt).tiny)
        thr = 2.0 * max(tiny, tiny / self.error_bound)
        return np.nextafter(dt.type(thr), dt.type(np.inf))

    @property
    def np_dtype(self):
        return np.dtype(self.dtype)

    @property
    def tighten(self) -> float:
        return TIGHTEN_F64 if self.np_dtype == np.float64 else TIGHTEN_F32

    @property
    def maxbin(self) -> int:
        # Valid bins are (-maxbin, maxbin); |bin| >= maxbin is an outlier.
        # This keeps +maxbin free as the serializer's inline escape code and
        # keeps the two's-complement minimum (paper §2.4) out of the stream.
        return (1 << (self.bin_bits - 1)) - 1

    # --- host-side derived constants (exact target-dtype bits) -------------

    def abs_constants(self, eb: float | None = None):
        """(eb, eb2, inv_eb2) as numpy scalars of the data dtype.

        eb2 — the bin width — is floored to a POWER OF TWO so that
        bin * eb2 and x * inv_eb2 are exact exponent shifts; this makes the
        codec immune to FMA contraction on any backend (see bitops module
        note).  The acceptance check still uses the user's original eb, so
        the guarantee is against the REQUESTED bound.
        """
        dt = self.np_dtype
        eb_ = dt.type(self.error_bound if eb is None else eb)
        eb2 = _pow2_floor_np(dt.type(2.0) * eb_)
        inv_eb2 = dt.type(1.0) / eb2
        return eb_, eb2, inv_eb2

    def rel_constants(self):
        """(eb, log_step, inv_log_step) for the REL quantizer.

        log_step w is the bin width in the log2approx domain.  log2approx is
        piecewise linear per octave, so a bin-center reconstruction has
        relative error <= ~w/2; w = log2(1+eb) ~= 1.44*eb keeps that under
        ~0.72*eb with margin for the approximation's octave-boundary slope
        changes.  Anything that still lands outside eb is discarded by the
        double-check and stored losslessly.

        w is floored to a POWER OF TWO (FMA-contraction immunity — bitops
        module note); the ratio cost of the finer step is bounded by one
        bit per value before the lossless stage.
        """
        dt = self.np_dtype
        eb_ = dt.type(self.error_bound)
        step = math.log2(1.0 + self.error_bound)  # exact-ish host double
        log_step = _pow2_floor_np(dt.type(step))
        inv_log_step = dt.type(1.0) / log_step
        return eb_, log_step, inv_log_step

    def outlier_cap(self, n: int) -> int:
        return max(1, int(math.ceil(n * self.outlier_cap_frac)))
