"""Checkpoint manager: atomic, async, retention-limited, with an optional
guaranteed-error-bounded LOSSY codec for the f32 bulk (paper technique on
the storage path).

Fault-tolerance contract:
  * atomic publish: write to <dir>/tmp-<step>/ then os.rename -> a reader
    never sees a torn checkpoint; step directories are self-describing.
  * async save: serialization happens on a worker thread off the train
    loop; `wait()` joins before the next save or process exit.
  * retention: keep the newest `keep` checkpoints (and every multiple of
    `keep_period` if set).
  * restore picks the highest complete step; corrupted/partial dirs are
    skipped — restart after a mid-save failure is safe.

Lossy mode: master weights / optimizer moments are serialized through
core.serializer (ABS quantizer, inline lossless outliers).  The error
bound guarantees restored weights are within eb of the saved ones —
restart curves are indistinguishable for eb << optimizer step noise, at
3-6x smaller checkpoints (measured in benchmarks/checkpoint_codec.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np

import jax

from repro.core import QuantizerConfig, deserialize, serialize

_MANIFEST = "manifest.json"


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 lossy: QuantizerConfig | None = None):
        self.dir = directory
        self.keep = keep
        self.lossy = lossy
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot `tree` (pytree of arrays) at `step`."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host copy

        def _work():
            tmp = os.path.join(self.dir, f"tmp-{step}")
            final = os.path.join(self.dir, f"step-{step:012d}")
            os.makedirs(tmp, exist_ok=True)
            leaves, treedef = jax.tree.flatten(host_tree)
            manifest = {"step": step, "n_leaves": len(leaves),
                        "treedef": str(treedef),
                        "lossy": bool(self.lossy), "leaves": []}
            for i, leaf in enumerate(leaves):
                path = os.path.join(tmp, f"leaf-{i:05d}.npy")
                entry = {"dtype": str(leaf.dtype), "shape": list(leaf.shape)}
                if (self.lossy is not None and leaf.dtype == np.float32
                        and leaf.size > 1024):
                    stream = serialize(leaf.reshape(-1), self.lossy)
                    with open(path + ".lc", "wb") as f:
                        f.write(stream)
                    entry["codec"] = "lc"
                else:
                    np.save(path, leaf)
                    entry["codec"] = "raw"
                manifest["leaves"].append(entry)
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f)
            os.rename(tmp, final)                    # atomic publish
            self._retain()

        if blocking:
            _work()
        else:
            self._thread = threading.Thread(target=_work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:012d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step-"):
                continue
            if os.path.exists(os.path.join(self.dir, name, _MANIFEST)):
                out.append(int(name.split("-")[1]))
        return out

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None):
        """Restore into the structure of `template` (arrays or
        ShapeDtypeStructs).  Returns (tree, step) or (None, None)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"step-{step:012d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        t_leaves, treedef = jax.tree.flatten(template)
        assert len(t_leaves) == manifest["n_leaves"], "tree mismatch"
        leaves = []
        for i, (tmpl, entry) in enumerate(zip(t_leaves, manifest["leaves"])):
            path = os.path.join(d, f"leaf-{i:05d}.npy")
            if entry["codec"] == "lc":
                with open(path + ".lc", "rb") as f:
                    arr, _ = deserialize(f.read())
                arr = arr.reshape(entry["shape"])
            else:
                arr = np.load(path)
            leaves.append(arr.astype(entry["dtype"]))
        return jax.tree.unflatten(treedef, leaves), step
