"""repro.checkpoint"""
