"""Decode-step (serving) paths with KV caches — raw bf16 or
guaranteed-error-bounded quantized (the paper's technique in the serving
hot loop).

Quantized mode cache layout per layer (compression/kv.py):
    bins   int8 [L, B, G, S, hd]     4x smaller than bf16 K+V
    eb2    f32  [L, B, G, nP]        per-page pow2 step
    out_idx/out_val [L, B, G, nP, cap]  exact outliers (bit-exact restore)
    hot    bf16 [L, B, page, G, hd]  write buffer for the open page
When the open page fills ((pos+1) % page == 0) it is quantized in-step via
lax.cond.  The XLA decode path dequantizes history explicitly; on real TPU
the fused Pallas kernel (kernels/kv_attention.py) streams int8 directly.

PREFILL→DECODE DISAGGREGATION (DESIGN.md §8): a prefill host builds the
QuantCache and hands it to a decode host.  KV pages cross that link ONLY
as `PackedKV` wires moved by `Transport.send_pages` — never as raw f32/
bf16 planes: `pack_cache` converts a QuantCache to the `PackedCache`
wire (closed pages bit-packed per page, optionally chunk-coded; the open
hot page rides raw because it is not quantized yet), `transfer_cache`
moves it across a mesh axis, `unpack_cache` restores the decode layout
bit-exactly.  The §1 guarantee survives the transfer verbatim because
pack/unpack are exact inverses (tests/test_transport.py pins both the
bit-exactness and the page error bound after transfer).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import QuantizerConfig
from repro.core.transport import TRANSPORT, Transport
from repro.compression import kv as KVC
from . import layers as L
from . import mamba as M
from .transformer import DTYPE


class RawCache(NamedTuple):
    k: jnp.ndarray            # [L, B, S, G, hd]
    v: jnp.ndarray


class QuantCache(NamedTuple):
    k: KVC.QuantizedKV        # bins [L, B, G, S, hd], ...
    v: KVC.QuantizedKV
    hot_k: jnp.ndarray        # [L, B, page, G, hd]
    hot_v: jnp.ndarray


PAGE = 128
CAP = 8


def make_raw_cache(cfg: ArchConfig, batch, seq, n_layers=None):
    l_ = n_layers if n_layers is not None else cfg.n_layers
    shape = (l_, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    return RawCache(jnp.zeros(shape, DTYPE), jnp.zeros(shape, DTYPE))


def make_quant_cache(cfg: ArchConfig, batch, seq, n_layers=None):
    l_ = n_layers if n_layers is not None else cfg.n_layers
    g, hd = cfg.n_kv_heads, cfg.head_dim
    np_ = seq // PAGE

    def one():
        return KVC.QuantizedKV(
            bins=jnp.zeros((l_, batch, g, seq, hd), jnp.int8),
            eb2=jnp.zeros((l_, batch, g, np_), jnp.float32),
            out_idx=jnp.full((l_, batch, g, np_, CAP), -1, jnp.int32),
            out_val=jnp.zeros((l_, batch, g, np_, CAP), jnp.float32),
            overflow=jnp.zeros((l_, batch, g, np_), bool),
        )

    hot = jnp.zeros((l_, batch, PAGE, g, hd), DTYPE)
    return QuantCache(one(), one(), hot, hot)


class PackedCache(NamedTuple):
    """The prefill→decode transfer wire for a QuantCache: closed pages as
    per-page `PackedKV` wires, the open hot page raw (it is not quantized
    yet — at PAGE=128 it amortizes away at production context lengths).
    `core.transport.wire_bytes` accounts it field by field."""
    k: KVC.PackedKV
    v: KVC.PackedKV
    hot_k: jnp.ndarray
    hot_v: jnp.ndarray


def pack_cache(cache: QuantCache, *, stages=()) -> PackedCache:
    """QuantCache -> transfer wire.  `stages` is a per-page chain spec in
    the two-domain grammar — or "auto"/"auto:SET", which hands the
    per-page choice to the §11 selector (`pack_kv` resolves it; the wire
    carries one chain-id byte per page, so decode needs no side
    channel): optional leading pred stages (DESIGN.md §9 —
    "kvdelta|zero|narrow" runs the previous-token delta on each page's
    bin plane before coding; the prediction is decode-side and page-local
    so migrated pages stay bit-exact) then word stages ("zero", "narrow",
    "shuffle|narrow", ...) — zero chunks drop the unwritten tail of a
    mid-decode cache."""
    return PackedCache(KVC.pack_kv(cache.k, page=PAGE, stages=stages),
                       KVC.pack_kv(cache.v, page=PAGE, stages=stages),
                       cache.hot_k, cache.hot_v)


def unpack_cache(wire: PackedCache) -> QuantCache:
    """Exact inverse of pack_cache: restore the int8 decode layout."""
    return QuantCache(KVC.unpack_kv(wire.k, page=PAGE),
                      KVC.unpack_kv(wire.v, page=PAGE),
                      wire.hot_k, wire.hot_v)


def transfer_cache(cache: QuantCache, src: int, dst: int, axis: str, *,
                   stages=(), transport: Transport | None = None):
    """Move a serving cache from mesh rank `src` (prefill) to `dst`
    (decode) along `axis` — call inside shard_map.  KV pages cross the
    link only as PackedKV wires through `Transport.send_pages`
    (DESIGN.md §8); rank `dst` returns the bit-identical QuantCache,
    other ranks return zeros (ppermute semantics)."""
    tp = TRANSPORT if transport is None else transport
    return unpack_cache(tp.send_pages(pack_cache(cache, stages=stages),
                                      src, dst, axis))


def _project_token(cfg: ArchConfig, p, x, pos):
    """x: [B, 1, D] -> q [B,1,H,hd], k/v [B,1,G,hd] with rope at pos."""
    b = x.shape[0]
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hx = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (hx @ p["wq"]).reshape(b, 1, h, hd)
    kv = (hx @ p["wkv"]).reshape(b, 1, 2, g, hd)
    k, v = kv[:, :, 0], kv[:, :, 1]
    positions = jnp.full((1, 1), pos, jnp.int32)
    cos, sin = L.rope_tables(positions,
                             hd if cfg.rope == "full" else hd // 2)
    q = L.apply_rope(q, cos, sin, cfg.rope)
    k = L.apply_rope(k, cos, sin, cfg.rope)
    return q, k, v


def _attn_decode_raw(cfg: ArchConfig, p, x, kc, vc, pos):
    """kc/vc: [B, S, G, hd] one layer's cache."""
    b = x.shape[0]
    q, k, v = _project_token(cfg, p, x, pos)
    kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
    lengths = jnp.full((b,), pos + 1, jnp.int32)
    o = L.decode_attention(q, kc, vc, lengths)
    h, hd = cfg.n_heads, cfg.head_dim
    return x + o.reshape(b, 1, h * hd) @ p["wo"], kc, vc


def _quantize_page(qkv: KVC.QuantizedKV, hot, page_idx, kv_cfg):
    """Quantize the filled hot page [B, page, G, hd] into history slot."""
    b, page, g, hd = hot.shape
    x = hot.transpose(0, 2, 1, 3).astype(jnp.float32)        # [B, G, P, hd]
    q = KVC.quantize_kv(x.reshape(b, g, page, hd), kv_cfg, page=page,
                        cap=CAP)
    bins = jax.lax.dynamic_update_slice(
        qkv.bins, q.bins, (0, 0, page_idx * page, 0))
    upd = lambda dst, src: jax.lax.dynamic_update_slice(
        dst, src, (0, 0, page_idx) + (0,) * (src.ndim - 3))
    return KVC.QuantizedKV(bins, upd(qkv.eb2, q.eb2),
                           upd(qkv.out_idx, q.out_idx),
                           upd(qkv.out_val, q.out_val),
                           upd(qkv.overflow, q.overflow))


def _attn_decode_quant(cfg: ArchConfig, p, x, qk, qv, hot_k, hot_v, pos,
                       kv_cfg):
    b = x.shape[0]
    g, hd = cfg.n_kv_heads, cfg.head_dim
    s = qk.bins.shape[3]
    q, k, v = _project_token(cfg, p, x, pos)

    in_page = pos % PAGE
    hot_k = jax.lax.dynamic_update_slice(
        hot_k, k.astype(hot_k.dtype), (0, in_page, 0, 0))
    hot_v = jax.lax.dynamic_update_slice(
        hot_v, v.astype(hot_v.dtype), (0, in_page, 0, 0))

    # attention = closed (quantized) pages + open (hot) page
    hist_k = KVC.dequantize_kv(qk, page=PAGE, dtype=DTYPE)   # [B,G,S,hd]
    hist_v = KVC.dequantize_kv(qv, page=PAGE, dtype=DTYPE)
    page_start = (pos // PAGE) * PAGE
    hist_len = jnp.full((b,), page_start, jnp.int32)
    hot_len = jnp.full((b,), in_page + 1, jnp.int32)

    o_hist, l_hist, m_hist = _partial_attn(q, hist_k.transpose(0, 2, 1, 3),
                                           hist_v.transpose(0, 2, 1, 3),
                                           hist_len)
    o_hot, l_hot, m_hot = _partial_attn(q, hot_k, hot_v, hot_len)
    m = jnp.maximum(m_hist, m_hot)
    w1 = l_hist * jnp.exp(m_hist - m)
    w2 = l_hot * jnp.exp(m_hot - m)
    o = (o_hist * w1[..., None] + o_hot * w2[..., None]) / (
        w1 + w2)[..., None]
    o = o.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)

    # close the page when it fills
    kv_c = kv_cfg
    qk, qv, hot_k, hot_v = jax.lax.cond(
        (pos + 1) % PAGE == 0,
        lambda a: (_quantize_page(a[0], a[2], pos // PAGE, kv_c),
                   _quantize_page(a[1], a[3], pos // PAGE, kv_c),
                   jnp.zeros_like(a[2]), jnp.zeros_like(a[3])),
        lambda a: a,
        (qk, qv, hot_k, hot_v))
    return x + o @ p["wo"], qk, qv, hot_k, hot_v


def _partial_attn(q, kc, vc, lengths):
    """Un-normalized attention piece for two-segment combination.
    q [B,1,H,hd]; kc/vc [B,T,G,hd]; returns (acc/l, l, m) per [B,G*gs]."""
    b, _, h, hd = q.shape
    t, g = kc.shape[1], kc.shape[2]
    gs = h // g
    qg = q.reshape(b, g, gs, hd)
    scores = jnp.einsum("bgqd,bsgd->bgqs", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) / (hd ** 0.5)
    valid = jnp.arange(t)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, L.NEG_BIG)
    m = scores.max(-1)                                       # [B,G,gs]
    p = jnp.exp(scores - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bgqs,bsgd->bgqd", p, vc.astype(jnp.float32))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(b, g * gs, hd), l.reshape(b, g * gs), m.reshape(b, g * gs)


def _ffn_decode(cfg: ArchConfig, p, x, mesh):
    from .transformer import _ffn_block
    y, _ = _ffn_block(cfg, p, x, mesh)
    return y


def serve_step(cfg: ArchConfig, params, cache, tokens, pos, mesh=None,
               kv_cfg: QuantizerConfig | None = None):
    """One decode step.  tokens: int32 [B, 1]; pos: scalar int32 (aligned
    batch).  Returns (logits [B, V] f32, new_cache)."""
    x = params["emb"][tokens].astype(DTYPE)

    if cfg.family == "hybrid":
        x, cache = _serve_hybrid(cfg, params, cache, x, pos, mesh)
    elif isinstance(cache, QuantCache):
        assert kv_cfg is not None

        def body(h, xs):
            lp, qk, qv, hk, hv = xs      # scan slices the leading L axis
            h, qk, qv, hk, hv = _attn_decode_quant(
                cfg, lp, h, qk, qv, hk, hv, pos, kv_cfg)
            h = _ffn_decode(cfg, lp, h, mesh)
            return h, (qk, qv, hk, hv)

        x, (qk, qv, hk, hv) = jax.lax.scan(
            body, x, (params["layers"], cache.k, cache.v,
                      cache.hot_k, cache.hot_v))
        cache = QuantCache(qk, qv, hk, hv)
    else:
        def body(h, xs):
            lp, kc, vc = xs
            h, kc, vc = _attn_decode_raw(cfg, lp, h, kc, vc, pos)
            h = _ffn_decode(cfg, lp, h, mesh)
            return h, (kc, vc)

        x, (kc, vc) = jax.lax.scan(body, x,
                                   (params["layers"], cache.k, cache.v))
        cache = RawCache(kc, vc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["emb"].T.astype(DTYPE))[:, 0].astype(jnp.float32)
    return logits, cache


def _serve_hybrid(cfg: ArchConfig, params, cache, x, pos, mesh):
    """jamba: cache = (RawCache for the per-period attn layers,
    (conv_tail [P, n_mamba, B, K-1, Di], ssm_h [P, n_mamba, B, Di, N]))."""
    attn_cache, (tails, hs) = cache
    n_per = cfg.attn_period

    def period(h, xs):
        pp, kc, vc, tail_p, h_p = xs
        mamba_i = dense_i = moe_i = 0
        new_tails, new_hs = [], []
        for blk in range(n_per):
            if blk == n_per - 1:
                ap = pp["attn"]
                h, kc, vc = _attn_decode_raw(cfg, ap, h, kc, vc, pos)
            else:
                mp = jax.tree.map(lambda t: t[mamba_i], pp["mamba"])
                hn = L.rms_norm(h, mp["ln1"], cfg.norm_eps)
                y, (tail, hh) = M.mamba_block(
                    mp, hn, state=(tail_p[mamba_i], h_p[mamba_i]))
                h = h + y
                new_tails.append(tail)
                new_hs.append(hh)
                mamba_i += 1
            if (blk % cfg.moe_every) == cfg.moe_every - 1:
                fp = jax.tree.map(lambda t: t[moe_i], pp["moe_ffn"])
                moe_i += 1
            else:
                fp = jax.tree.map(lambda t: t[dense_i], pp["dense_ffn"])
                dense_i += 1
            h = _ffn_decode(cfg, fp, h, mesh)
        return h, (kc, vc, jnp.stack(new_tails), jnp.stack(new_hs))

    x, (kc, vc, tails, hs) = jax.lax.scan(
        period, x, (params["periods"], attn_cache.k, attn_cache.v,
                    tails, hs))
    return x, (RawCache(kc, vc), (tails, hs))
