"""xLSTM model stack (alternating mLSTM / sLSTM pairs under scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import layers as L
from .params import ParamSpec
from .transformer import DTYPE
from .xlstm import (mlstm_block, mlstm_params_shape, slstm_block,
                    slstm_params_shape)


def param_specs(cfg: ArchConfig):
    pairs = cfg.n_layers // 2
    d = cfg.d_model

    def from_shapes(shapes, lead):
        ax = tuple(None for _ in lead)
        out = {}
        for name, (shape, dt) in shapes.items():
            # shard only the LAST matching wide dim (square/multi-wide
            # projections would otherwise duplicate the 'model' axis)
            axes = [None] * len(shape)
            for i in range(len(shape) - 1, -1, -1):
                if shape[i] in (2 * d, 4 * 2 * d, 3 * 2 * d, 8 * d):
                    axes[i] = "mlp"
                    break
            out[name] = ParamSpec(lead + shape, dt, ax + tuple(axes))
        return out

    return {
        "emb": ParamSpec((cfg.padded_vocab, d), DTYPE,
                         ("vocab", "embed")),
        "final_norm": ParamSpec((d,), jnp.float32, (None,), -1.0),
        "m_norm": ParamSpec((pairs, d), jnp.float32, (None, None), -1.0),
        "s_norm": ParamSpec((pairs, d), jnp.float32, (None, None), -1.0),
        "mlstm": from_shapes(mlstm_params_shape(d, cfg.n_heads, DTYPE),
                             (pairs,)),
        "slstm": from_shapes(slstm_params_shape(d, cfg.n_heads, DTYPE),
                             (pairs,)),
    }


def forward(cfg: ArchConfig, params, tokens, mesh=None, remat=True):
    ctx = L.ShardCtx(mesh)
    x = ctx(params["emb"][tokens].astype(DTYPE), 'dp', None, None)

    def pair(h, pp):
        h = ctx(h, 'dp', None, None)
        hn = L.rms_norm(h, pp["m_norm"], cfg.norm_eps)
        y, _ = mlstm_block(pp["mlstm"], hn, cfg.n_heads, ctx=ctx)
        h = h + y
        hn = L.rms_norm(h, pp["s_norm"], cfg.norm_eps)
        y, _ = slstm_block(pp["slstm"], hn, cfg.n_heads, ctx=ctx)
        return h + y, None

    body = jax.checkpoint(pair) if remat else pair
    x, _ = jax.lax.scan(body, x, {"mlstm": params["mlstm"],
                                  "slstm": params["slstm"],
                                  "m_norm": params["m_norm"],
                                  "s_norm": params["s_norm"]})
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ctx(x @ params["emb"].T.astype(DTYPE), 'dp', None, 'model')
    return logits, jnp.float32(0)


def make_cache(cfg: ArchConfig, batch, _seq):
    """Recurrent state replaces the KV cache: O(1) in context length —
    this is why xlstm runs long_500k."""
    pairs = cfg.n_layers // 2
    d = cfg.d_model
    di = 2 * d
    h, dh = cfg.n_heads, (2 * d) // cfg.n_heads
    return {
        "m": (jnp.zeros((pairs, batch, h, dh, dh), jnp.float32),
              jnp.zeros((pairs, batch, h, dh), jnp.float32),
              jnp.full((pairs, batch, h), -1e30, jnp.float32)),
        "s": (jnp.zeros((pairs, batch, h, dh), jnp.float32),
              jnp.ones((pairs, batch, h, dh), jnp.float32),
              jnp.zeros((pairs, batch, h, dh), jnp.float32),
              jnp.zeros((pairs, batch, h, dh), jnp.float32)),
    }


def serve_step(cfg: ArchConfig, params, cache, tokens, pos, mesh=None,
               kv_cfg=None):
    x = params["emb"][tokens].astype(DTYPE)

    def pair(h, xs):
        pp, m_state, s_state = xs
        hn = L.rms_norm(h, pp["m_norm"], cfg.norm_eps)
        y, m_state = mlstm_block(pp["mlstm"], hn, cfg.n_heads, state=m_state)
        h = h + y
        hn = L.rms_norm(h, pp["s_norm"], cfg.norm_eps)
        y, s_state = slstm_block(pp["slstm"], hn, cfg.n_heads, state=s_state)
        return h + y, (m_state, s_state)

    x, (m_s, s_s) = jax.lax.scan(
        pair, x, ({"mlstm": params["mlstm"], "slstm": params["slstm"],
                   "m_norm": params["m_norm"], "s_norm": params["s_norm"]},
                  cache["m"], cache["s"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["emb"].T.astype(DTYPE))[:, 0].astype(jnp.float32)
    return logits, {"m": m_s, "s": s_s}
