"""Decoder-only transformer stack: dense (internlm2 / stablelm / chatglm3 /
deepseek / chameleon), MoE (olmoe / qwen3-moe), and the jamba hybrid
(Mamba+attention 1:7 with MoE every 2nd layer).

Layout principles:
  * per-layer params are STACKED on a leading 'layers' axis and the stack
    runs under jax.lax.scan -> HLO is O(1) in depth (95-layer deepseek
    compiles in seconds on the 512-device dry-run).
  * each scan body is jax.checkpoint'd (full remat baseline; policy is a
    §Perf lever) so train memory is one layer's activations.
  * attention is the pure-JAX flash pattern (O(S) memory), GQA KV repeat
    for train/prefill, grouped-einsum for decode (no repeat at 512k).
  * MoE goes through shard_map expert parallelism (models/moe.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import layers as L
from . import mamba as M
from .moe import moe_ffn
from .params import ParamSpec

DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def _attn_specs(cfg: ArchConfig, lead=()):
    d, hd = cfg.d_model, cfg.head_dim
    h, g = cfg.n_heads, cfg.n_kv_heads
    ax = tuple(None for _ in lead)
    return {
        "ln1": ParamSpec(lead + (d,), jnp.float32, ax + (None,), -1.0),
        "wq": ParamSpec(lead + (d, h * hd), DTYPE, ax + ("embed", "heads")),
        "wkv": ParamSpec(lead + (d, 2 * g * hd), DTYPE, ax + ("embed", "heads")),
        "wo": ParamSpec(lead + (h * hd, d), DTYPE, ax + ("heads", "embed")),
    }


def _ffn_specs(cfg: ArchConfig, lead=()):
    d, f = cfg.d_model, cfg.d_ff
    ax = tuple(None for _ in lead)
    s = {
        "ln2": ParamSpec(lead + (d,), jnp.float32, ax + (None,), -1.0),
        "w1": ParamSpec(lead + (d, f), DTYPE, ax + ("embed", "mlp")),
        "w2": ParamSpec(lead + (f, d), DTYPE, ax + ("mlp", "embed")),
    }
    if cfg.act == "swiglu":
        s["w3"] = ParamSpec(lead + (d, f), DTYPE, ax + ("embed", "mlp"))
    return s


def _moe_specs(cfg: ArchConfig, lead=()):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ax = tuple(None for _ in lead)
    return {
        "ln2": ParamSpec(lead + (d,), jnp.float32, ax + (None,), -1.0),
        "router": ParamSpec(lead + (d, e), jnp.float32, ax + ("embed", None)),
        "w1": ParamSpec(lead + (e, d, f), DTYPE,
                        ax + ("experts", "embed", None)),
        "w3": ParamSpec(lead + (e, d, f), DTYPE,
                        ax + ("experts", "embed", None)),
        "w2": ParamSpec(lead + (e, f, d), DTYPE,
                        ax + ("experts", None, "embed")),
    }


_MAMBA_AXES = {
    # explicit FSDP ('embed'->data) + TP ('mlp'->model) per projection;
    # a divisibility matcher missed (d, 4d) shapes and left jamba's
    # in_proj master copies REPLICATED (63 GiB/device, measured)
    "in_proj": ("embed", "mlp"),
    "conv_w": (None, "mlp"),
    "a_log": ("mlp", None),
    "d_skip": ("mlp",),
    "bc_proj": ("mlp", None),
    "dt_proj": ("embed", "mlp"),
    "dt_bias": ("mlp",),
    "out_proj": ("mlp", "embed"),
}


def _mamba_specs(cfg: ArchConfig, lead=()):
    ax = tuple(None for _ in lead)
    out = {"ln1": ParamSpec(lead + (cfg.d_model,), jnp.float32,
                            ax + (None,), -1.0)}
    for name, (shape, dt) in M.mamba_params_shape(
            cfg.d_model, cfg.ssm_state, DTYPE).items():
        scale = 0.02 if name not in ("a_log", "d_skip", "dt_bias") else -1.0
        out[name] = ParamSpec(lead + shape, dt, ax + _MAMBA_AXES[name],
                              scale)
    return out


def param_specs(cfg: ArchConfig):
    v, d, l_ = cfg.vocab, cfg.d_model, cfg.n_layers
    specs: dict = {
        "emb": ParamSpec((cfg.padded_vocab, d), DTYPE, ("vocab", "embed")),
        "final_norm": ParamSpec((d,), jnp.float32, (None,), -1.0),
    }
    if cfg.family in ("dense", "vlm"):
        specs["layers"] = {**_attn_specs(cfg, (l_,)), **_ffn_specs(cfg, (l_,))}
    elif cfg.family == "moe":
        specs["layers"] = {**_attn_specs(cfg, (l_,)), **_moe_specs(cfg, (l_,))}
    elif cfg.family == "hybrid":
        n_per = cfg.attn_period                  # blocks per period
        periods = l_ // n_per
        n_mamba = n_per - 1
        n_moe = n_per // cfg.moe_every
        n_dense = n_per - n_moe
        specs["periods"] = {
            "mamba": _mamba_specs(cfg, (periods, n_mamba)),
            "attn": _attn_specs(cfg, (periods,)),
            "dense_ffn": _ffn_specs(cfg, (periods, n_dense)),
            "moe_ffn": _moe_specs(cfg, (periods, n_moe)),
        }
    else:
        raise ValueError(cfg.family)
    return specs


# --------------------------------------------------------------------------
# blocks (global math; scan over stacked layer params)
# --------------------------------------------------------------------------

def _attention(cfg: ArchConfig, p, x, positions, ctx=L.NULL_CTX, *,
               causal=True):
    b, s, d = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hx = ctx(L.rms_norm(x, p["ln1"], cfg.norm_eps), 'dp', None, None)
    q = ctx((hx @ p["wq"]).reshape(b, s, h, hd), 'dp', None, 'model', None)
    kv = (hx @ p["wkv"]).reshape(b, s, 2, g, hd)
    k, v = kv[:, :, 0], kv[:, :, 1]
    cos, sin = L.rope_tables(positions, hd if cfg.rope == "full" else hd // 2)
    q = L.apply_rope(q, cos, sin, cfg.rope)
    k = L.apply_rope(k, cos, sin, cfg.rope)
    # un-shard S BEFORE the GQA broadcast: feeding an S-sharded KV into
    # repeat_kv makes GSPMD emit a pathological resharding copy that
    # crashes XLA's AllReducePromotion pass (seen on jamba prefill)
    k = ctx(k, 'dp', None, None, None)
    v = ctx(v, 'dp', None, None, None)
    k = ctx(L.repeat_kv(k, cfg.group_size), 'dp', None, 'model', None)
    v = ctx(L.repeat_kv(v, cfg.group_size), 'dp', None, 'model', None)
    o = L.flash_attention(q, k, v, causal=causal, ctx=ctx)
    # NOTE: a full Megatron-SP residual (S-sharded between sublayers) was
    # measured in §Perf A-2 (memory −8%, temp −37%) but destabilizes
    # XLA:CPU's SPMD partitioner on some archs (upstream crash) — the
    # boundary-seam variant below is the stable default.
    return x + ctx(o.reshape(b, s, h * hd) @ p["wo"], 'dp', None, None)


def _ffn_block(cfg: ArchConfig, p, x, mesh, moe_data_axes=None,
               ctx=L.NULL_CTX):
    hx = ctx(L.rms_norm(x, p["ln2"], cfg.norm_eps), 'dp', None, None)
    if "router" in p:
        if moe_data_axes is None:
            moe_data_axes = ("pod", "data") if (
                mesh is not None and "pod" in mesh.axis_names) else ("data",)
        y, aux = moe_ffn(hx, p["router"], p["w1"], p["w3"], p["w2"],
                         top_k=cfg.moe_top_k, mesh=mesh,
                         data_axes=moe_data_axes, act=cfg.act)
        return x + y, aux
    y = L.ffn(hx, p["w1"], p.get("w3"), p["w2"], cfg.act, ctx=ctx)
    return x + y, jnp.float32(0)


def _layer_group(n_layers: int, max_group: int = 8) -> int:
    """Largest divisor of n_layers <= max_group (hierarchical remat)."""
    for g in range(min(max_group, n_layers), 0, -1):
        if n_layers % g == 0:
            return g
    return 1


def scan_grouped_remat(body, carry, stacked, n: int, max_group: int = 8):
    """Two-level remat: outer scan over layer GROUPS with only group
    boundaries saved; each group's backward replays its inner scan.  Also
    defeats an XLA pessimization where the full per-layer bf16 carry stack
    was hoisted to one f32 buffer (measured: 20 GiB on stablelm-3b
    train_4k before this change)."""
    g = _layer_group(n, max_group)
    grouped = jax.tree.map(lambda t: t.reshape(n // g, g, *t.shape[1:]),
                           stacked)

    body_ckpt = jax.checkpoint(body)   # inner: attention/ffn rematted

    @jax.checkpoint
    def group_body(c, gp):
        c, _ = jax.lax.scan(body_ckpt, c, gp)
        return c, None

    carry, _ = jax.lax.scan(group_body, carry, grouped)
    return carry


def _dense_or_moe_stack(cfg: ArchConfig, params, x, positions, mesh,
                        remat=True, moe_data_axes=None):
    # inside the pod-manual compressed-DP region, constraints must not
    # name the manual 'pod' axis -> dp follows moe_data_axes
    ctx = L.ShardCtx(mesh, dp=moe_data_axes)

    def body(carry, lp):
        h, aux = carry
        h = ctx(h, 'dp', None, None)
        h = _attention(cfg, lp, h, positions, ctx)
        h, a = _ffn_block(cfg, lp, h, mesh, moe_data_axes, ctx)
        # sequence-parallel seam: the layer boundary (what remat SAVES) is
        # S-sharded over 'model' -> boundary-save memory /16
        h = ctx(h, 'dp', 'model', None)
        return (h, aux + a), None

    if not remat:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                                   params["layers"])
        return x, aux
    x, aux = scan_grouped_remat(body, (x, jnp.float32(0)),
                                params["layers"], cfg.n_layers)
    return x, aux


def _hybrid_stack(cfg: ArchConfig, params, x, positions, mesh, remat=True,
                  moe_data_axes=None):
    n_per = cfg.attn_period
    ctx = L.ShardCtx(mesh, dp=moe_data_axes)

    def period(carry, pp):
        h, aux = carry
        h = ctx(h, 'dp', None, None)
        _seam = True
        mamba_i = dense_i = moe_i = 0
        for blk in range(n_per):
            is_attn = blk == n_per - 1
            if is_attn:
                ap = pp["attn"]
                h = _attention(cfg, ap, h, positions, ctx)
            else:
                mp = jax.tree.map(lambda t: t[mamba_i], pp["mamba"])
                hn = L.rms_norm(h, mp["ln1"], cfg.norm_eps)
                y, _ = M.mamba_block(mp, hn, ctx=ctx)
                h = h + y
                mamba_i += 1
            if (blk % cfg.moe_every) == cfg.moe_every - 1:
                fp = jax.tree.map(lambda t: t[moe_i], pp["moe_ffn"])
                moe_i += 1
            else:
                fp = jax.tree.map(lambda t: t[dense_i], pp["dense_ffn"])
                dense_i += 1
            h, a = _ffn_block(cfg, fp, h, mesh, moe_data_axes, ctx)
            aux = aux + a
        h = ctx(h, 'dp', 'model', None)   # sequence-parallel boundary save
        return (h, aux), None

    periods = cfg.n_layers // n_per
    if not remat:
        (x, aux), _ = jax.lax.scan(period, (x, jnp.float32(0)),
                                   params["periods"])
        return x, aux
    # a period (8 blocks) is already a big remat unit: group=1
    x, aux = scan_grouped_remat(period, (x, jnp.float32(0)),
                                params["periods"], periods, max_group=1)
    return x, aux


def forward(cfg: ArchConfig, params, tokens, mesh=None, remat=True,
            moe_data_axes=None):
    """tokens: int32 [B, S] -> logits [B, S, V] (bf16), aux loss."""
    x = params["emb"][tokens].astype(DTYPE)
    positions = jnp.arange(tokens.shape[1])[None, :]
    if cfg.family == "hybrid":
        x, aux = _hybrid_stack(cfg, params, x, positions, mesh, remat,
                               moe_data_axes)
    else:
        x, aux = _dense_or_moe_stack(cfg, params, x, positions, mesh, remat,
                                     moe_data_axes)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    ctx = L.ShardCtx(mesh, dp=moe_data_axes)
    logits = ctx(x @ params["emb"].T.astype(DTYPE), 'dp', None, 'model')
    return logits, aux


def loss_fn(cfg: ArchConfig, params, tokens, labels, mesh=None, remat=True,
            aux_weight=0.01):
    logits, aux = forward(cfg, params, tokens, mesh, remat)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                             axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    return ce + aux_weight * aux, (ce, aux)
