"""xLSTM blocks (arXiv:2405.04517): alternating mLSTM (matrix memory,
parallelizable) and sLSTM (scalar memory, true recurrence), both with
exponential gating and log-domain stabilizers.

Both cores run as lax.scan over time with carried state — the state tuple
is the arch's "KV cache" analogue for decode (and the target of the
SSM-state compression variant in compression/kv.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import chunked_scan


def mlstm_params_shape(d_model, n_heads, dtype):
    di = 2 * d_model
    dh = di // n_heads
    return {
        "up_proj": ((d_model, 2 * di), dtype),
        "qkv": ((di, 3 * di), dtype),
        "gates": ((di, 3 * n_heads), dtype),   # i, f, o per head
        "down_proj": ((di, d_model), dtype),
    }


def slstm_params_shape(d_model, n_heads, dtype):
    di = 2 * d_model
    dh = di // n_heads
    return {
        "up_proj": ((d_model, 2 * di), dtype),
        "wx": ((di, 4 * di), dtype),           # z, i, f, o from input
        "rh": ((n_heads, dh, 4 * dh), dtype),  # block-diagonal recurrence
        "down_proj": ((di, d_model), dtype),
    }


def _mlstm_step(carry, inp):
    c, n, m = carry                    # [B,H,dh,dh], [B,H,dh], [B,H]
    q, k, v, ig, fg = inp              # q/k/v [B,H,dh]; gates [B,H]
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    m_new = jnp.maximum(fg + m, ig)    # log-domain stabilizer
    i_ = jnp.exp(ig - m_new)
    f_ = jnp.exp(fg + m - m_new)
    c = f_[..., None, None] * c + i_[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = f_[..., None] * n + i_[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_new))
    h = jnp.einsum("bhij,bhj->bhi", c, q) / denom[..., None]
    return (c, n, m_new), h


def _mlstm_chunkwise(q, k, v, ig, fg, state, chunk=64):
    """Chunkwise-parallel mLSTM (the xLSTM training formulation).

    The sequential scan is exact but its backward must save the [dh, dh]
    matrix state EVERY step — measured 12 TiB/device on train_4k.  The
    chunkwise form materializes state only at chunk boundaries and turns
    within-chunk work into masked attention-like matmuls, with log-domain
    stabilizers m carried per (batch, head).

    q/k/v: [B, T, H, dh] (k pre-scaled); ig/fg: [B, T, H] (fg already
    log-sigmoid).  state: (C_hat [B,H,dh,dh], n_hat [B,H,dh], m [B,H]).
    Returns (h [B,T,H,dh], state_out).
    """
    b, t, hh, dh = q.shape
    while t % chunk:
        chunk //= 2
    nc = t // chunk

    def to_chunks(a):
        return (a.reshape(b, nc, chunk, *a.shape[2:])
                .transpose(*(1, 0, 2) + tuple(range(3, a.ndim + 1))))

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    igs, fgs = to_chunks(ig), to_chunks(fg)       # [nc, B, L, H]

    def chunk_step(carry, xs):
        c_hat, n_hat, m_in = carry
        qc, kc, vc, ic, fc = xs                   # [B, L, H, dh] / [B, L, H]
        qc = qc.astype(jnp.float32).transpose(0, 2, 1, 3)   # [B,H,L,dh]
        kc = kc.astype(jnp.float32).transpose(0, 2, 1, 3)
        vc = vc.astype(jnp.float32).transpose(0, 2, 1, 3)
        ic = ic.transpose(0, 2, 1)                # [B,H,L]
        fc = fc.transpose(0, 2, 1)

        cum = jnp.cumsum(fc, axis=-1)             # [B,H,L] inclusive
        a = cum + m_in[..., None]                 # decayed-state log scale
        # b_ij = cum_i - cum_j + li_j for j <= i
        bmat = (cum[..., :, None] - cum[..., None, :]
                + ic[..., None, :])               # [B,H,L,L]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        bmat = jnp.where(mask, bmat, -1e30)
        m_i = jnp.maximum(a, bmat.max(-1))        # [B,H,L]
        d = jnp.exp(bmat - m_i[..., None])        # masked decay weights
        scores = jnp.einsum("bhid,bhjd->bhij", qc, kc)
        intra = jnp.einsum("bhij,bhjd->bhid", d * scores, vc)
        # C @ q (C = v (x) k, matching the sequential step's orientation)
        inter = jnp.einsum("bhde,bhie->bhid", c_hat, qc) \
            * jnp.exp(a - m_i)[..., None]
        n_i = (jnp.einsum("bhij,bhjd->bhid", d, kc)
               + n_hat[:, :, None] * jnp.exp(a - m_i)[..., None])
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhid,bhid->bhi", n_i, qc)),
                            jnp.exp(-m_i))
        h = (intra + inter) / denom[..., None]    # [B,H,L,dh]

        # boundary update
        a_l = cum[..., -1] + m_in                 # [B,H]
        b_l = cum[..., -1:] - cum + ic            # [B,H,L]
        m_out = jnp.maximum(a_l, b_l.max(-1))
        w = jnp.exp(b_l - m_out[..., None])
        c_hat = (c_hat * jnp.exp(a_l - m_out)[..., None, None]
                 + jnp.einsum("bhj,bhjd,bhje->bhde", w, vc, kc))
        n_hat = (n_hat * jnp.exp(a_l - m_out)[..., None]
                 + jnp.einsum("bhj,bhjd->bhd", w, kc))
        return (c_hat, n_hat, m_out), h.transpose(0, 2, 1, 3)

    body = jax.checkpoint(chunk_step)
    state, hs = jax.lax.scan(body, state, (qs, ks, vs, igs, fgs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, t, hh, dh)
    return h, state


def mlstm_block(p, x, n_heads, state=None, ctx=None):
    """x: [B, T, D] -> (y, state).  Matrix-memory LSTM: chunkwise-parallel
    form for training/prefill, exact sequential step for decode (T==1)."""
    b, t, d = x.shape
    up = x @ p["up_proj"]
    if ctx is not None:
        up = ctx(up, 'dp', None, 'model')
    u, z = jnp.split(up, 2, axis=-1)                        # [B, T, Di]
    di = u.shape[-1]
    dh = di // n_heads
    # keep the scan xs in bf16 (converted per-step): the stacked [T, ...]
    # buffers dominated prefill memory in f32
    qkv = (u @ p["qkv"]).reshape(b, t, 3, n_heads, dh)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    k = k / jnp.asarray(dh ** 0.5, k.dtype)
    gates = (u @ p["gates"]).reshape(b, t, 3, n_heads).astype(jnp.float32)
    ig, fg = gates[:, :, 0], jax.nn.log_sigmoid(gates[:, :, 1])
    og = jax.nn.sigmoid(gates[:, :, 2])

    if state is None:
        c0 = jnp.zeros((b, n_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, n_heads, dh), jnp.float32)
        m0 = jnp.full((b, n_heads), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state
    if t == 1:      # decode: exact sequential step
        (c, n, m), hs = jax.lax.scan(
            _mlstm_step, (c0, n0, m0),
            (q.transpose(1, 0, 2, 3).astype(jnp.float32),
             k.transpose(1, 0, 2, 3).astype(jnp.float32),
             v.transpose(1, 0, 2, 3).astype(jnp.float32),
             ig.transpose(1, 0, 2), fg.transpose(1, 0, 2)))
        h = hs.transpose(1, 0, 2, 3)                        # [B, T, H, dh]
    else:
        h, (c, n, m) = _mlstm_chunkwise(q, k, v, ig, fg, (c0, n0, m0))
    h = (h * og[..., None]).reshape(b, t, di).astype(x.dtype)
    y = h * jax.nn.silu(z)
    return y @ p["down_proj"], (c, n, m)


def slstm_block(p, x, n_heads, state=None, ctx=None):
    """Scalar-memory LSTM with block-diagonal recurrence, scan over T."""
    b, t, d = x.shape
    up = x @ p["up_proj"]
    if ctx is not None:
        up = ctx(up, 'dp', None, 'model')
    u, zgate = jnp.split(up, 2, axis=-1)
    di = u.shape[-1]
    dh = di // n_heads
    wx = (u @ p["wx"]).reshape(b, t, 4, n_heads, dh)   # bf16 xs
    rh = p["rh"].astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, n_heads, dh), jnp.float32)
        n0 = jnp.ones((b, n_heads, dh), jnp.float32)
        h0 = jnp.zeros((b, n_heads, dh), jnp.float32)
        m0 = jnp.zeros((b, n_heads, dh), jnp.float32)
    else:
        c0, n0, h0, m0 = state

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("bhd,hdk->bhk", h, rh).reshape(
            h.shape[0], n_heads, 4, dh)
        g = xt.astype(jnp.float32) + rec.transpose(0, 2, 1, 3)  # [B,4,H,dh]
        zt = jnp.tanh(g[:, 0])
        it = g[:, 1]
        ft = jax.nn.log_sigmoid(g[:, 2])
        ot = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c = f_ * c + i_ * zt
        n = f_ * n + i_
        h = ot * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    (c, n, h, m), hs = chunked_scan(step, (c0, n0, h0, m0),
                                    wx.transpose(1, 0, 2, 3, 4), chunk=256)
    y = hs.transpose(1, 0, 2, 3).reshape(b, t, di).astype(x.dtype)
    y = y * jax.nn.silu(zgate)
    return y @ p["down_proj"], (c, n, h, m)
