"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: `input_specs()`
provides precomputed frame embeddings [B, enc_context, D].  Encoder =
bidirectional self-attention + GELU FFN; decoder = causal self-attention +
cross-attention + GELU FFN; learned positional embeddings; pre-LayerNorm
with bias (whisper convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import layers as L
from .params import ParamSpec
from .serve import RawCache
from .transformer import DTYPE

MAX_DEC_LEN = 32_768          # covers decode_32k / prefill_32k shapes


def _ln(lead, d):
    ax = tuple(None for _ in lead)
    return {"w": ParamSpec(lead + (d,), jnp.float32, ax + (None,), -1.0),
            "b": ParamSpec(lead + (d,), jnp.float32, ax + (None,), 0.0)}


def _attn(cfg, lead):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ax = tuple(None for _ in lead)
    return {
        "ln": _ln(lead, d),
        "wq": ParamSpec(lead + (d, h * hd), DTYPE, ax + ("embed", "heads")),
        "wkv": ParamSpec(lead + (d, 2 * h * hd), DTYPE, ax + ("embed", "heads")),
        "wo": ParamSpec(lead + (h * hd, d), DTYPE, ax + ("heads", "embed")),
    }


def _ffn(cfg, lead):
    d, f = cfg.d_model, cfg.d_ff
    ax = tuple(None for _ in lead)
    return {
        "ln": _ln(lead, d),
        "w1": ParamSpec(lead + (d, f), DTYPE, ax + ("embed", "mlp")),
        "w2": ParamSpec(lead + (f, d), DTYPE, ax + ("mlp", "embed")),
    }


def param_specs(cfg: ArchConfig):
    d = cfg.d_model
    el, dl = cfg.enc_layers, cfg.n_layers
    return {
        "emb": ParamSpec((cfg.padded_vocab, d), DTYPE,
                         ("vocab", "embed")),
        "enc_pos": ParamSpec((cfg.enc_context, d), DTYPE, (None, "embed")),
        "dec_pos": ParamSpec((MAX_DEC_LEN, d), DTYPE, (None, "embed")),
        "enc": {"self": _attn(cfg, (el,)), "ffn": _ffn(cfg, (el,))},
        "dec": {"self": _attn(cfg, (dl,)), "cross": _attn(cfg, (dl,)),
                "ffn": _ffn(cfg, (dl,))},
        "enc_norm": _ln((), d),
        "final_norm": _ln((), d),
    }


def _mha(cfg, p, xq, xkv, causal, ctx=L.NULL_CTX):
    b, sq, d = xq.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = ctx((xq @ p["wq"]).reshape(b, sq, h, hd), 'dp', None, 'model', None)
    kv = (xkv @ p["wkv"]).reshape(b, xkv.shape[1], 2, h, hd)
    o = L.flash_attention(q, kv[:, :, 0], kv[:, :, 1], causal=causal,
                          ctx=ctx)
    return ctx(o.reshape(b, sq, h * hd) @ p["wo"], 'dp', None, None)


def _block_ln(p, x, eps):
    return L.layer_norm(x, p["w"], p["b"], eps)


def encode(cfg: ArchConfig, params, frames, ctx=L.NULL_CTX):
    """frames: [B, enc_context, D] (stubbed frontend output)."""
    x = ctx(frames.astype(DTYPE) + params["enc_pos"][None].astype(DTYPE),
            'dp', None, None)

    def body2(h, lp):
        h = ctx(h, 'dp', None, None)
        hn = _block_ln(lp["self"]["ln"], h, cfg.norm_eps)
        h = h + _mha(cfg, lp["self"], hn, hn, causal=False, ctx=ctx)
        hn = _block_ln(lp["ffn"]["ln"], h, cfg.norm_eps)
        h = h + L.ffn(hn, lp["ffn"]["w1"], None, lp["ffn"]["w2"], "gelu",
                      ctx=ctx)
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body2), x, params["enc"])
    return _block_ln(params["enc_norm"], x, cfg.norm_eps)


def forward(cfg: ArchConfig, params, tokens, frames, mesh=None, remat=True):
    """Teacher-forced decoder over stubbed audio frames."""
    ctx = L.ShardCtx(mesh)
    enc_out = encode(cfg, params, frames, ctx)
    b, s = tokens.shape
    x = ctx((params["emb"][tokens]
             + params["dec_pos"][:s][None]).astype(DTYPE), 'dp', None, None)

    def body(h, lp):
        h = ctx(h, 'dp', None, None)
        hn = _block_ln(lp["self"]["ln"], h, cfg.norm_eps)
        h = h + _mha(cfg, lp["self"], hn, hn, causal=True, ctx=ctx)
        hn = _block_ln(lp["cross"]["ln"], h, cfg.norm_eps)
        h = h + _mha(cfg, lp["cross"], hn, enc_out, causal=False, ctx=ctx)
        hn = _block_ln(lp["ffn"]["ln"], h, cfg.norm_eps)
        h = h + L.ffn(hn, lp["ffn"]["w1"], None, lp["ffn"]["w2"], "gelu",
                      ctx=ctx)
        return h, None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = _block_ln(params["final_norm"], x, cfg.norm_eps)
    logits = ctx(x @ params["emb"].T.astype(DTYPE), 'dp', None, 'model')
    return logits, jnp.float32(0)


def make_cache(cfg: ArchConfig, batch, seq):
    """(decoder self-attn KV cache, cross-attn KV computed at prefill)."""
    dl, h, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    self_kv = RawCache(
        jnp.zeros((dl, batch, seq, h, hd), DTYPE),
        jnp.zeros((dl, batch, seq, h, hd), DTYPE))
    cross_kv = RawCache(
        jnp.zeros((dl, batch, cfg.enc_context, h, hd), DTYPE),
        jnp.zeros((dl, batch, cfg.enc_context, h, hd), DTYPE))
    return (self_kv, cross_kv)


def serve_step(cfg: ArchConfig, params, cache, tokens, pos, mesh=None,
               kv_cfg=None):
    """One decoder token; cross-attn KV precomputed in the cache."""
    self_kv, cross_kv = cache
    b = tokens.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    pos_emb = jax.lax.dynamic_slice(params["dec_pos"],
                                    (pos, 0), (1, cfg.d_model))
    x = (params["emb"][tokens] + pos_emb[None]).astype(DTYPE)

    def body(hh, xs):
        lp, kc, vc, ck, cv = xs
        hn = _block_ln(lp["self"]["ln"], hh, cfg.norm_eps)
        q = (hn @ lp["self"]["wq"]).reshape(b, 1, h, hd)
        kv = (hn @ lp["self"]["wkv"]).reshape(b, 1, 2, h, hd)
        kc = jax.lax.dynamic_update_slice(kc, kv[:, :, 0].astype(kc.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, kv[:, :, 1].astype(vc.dtype),
                                          (0, pos, 0, 0))
        lengths = jnp.full((b,), pos + 1, jnp.int32)
        o = L.decode_attention(q, kc, vc, lengths)
        hh = hh + o.reshape(b, 1, h * hd) @ lp["self"]["wo"]

        hn = _block_ln(lp["cross"]["ln"], hh, cfg.norm_eps)
        q = (hn @ lp["cross"]["wq"]).reshape(b, 1, h, hd)
        o = L.decode_attention(
            q, ck, cv, jnp.full((b,), ck.shape[1], jnp.int32))
        hh = hh + o.reshape(b, 1, h * hd) @ lp["cross"]["wo"]

        hn = _block_ln(lp["ffn"]["ln"], hh, cfg.norm_eps)
        hh = hh + L.ffn(hn, lp["ffn"]["w1"], None, lp["ffn"]["w2"], "gelu")
        return hh, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["dec"], self_kv.k, self_kv.v, cross_kv.k,
                  cross_kv.v))
    x = _block_ln(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["emb"].T.astype(DTYPE))[:, 0].astype(jnp.float32)
    return logits, (RawCache(kc, vc), cross_kv)
