"""Parameter specification trees: one source of truth for shapes, dtypes,
logical sharding axes, and initialization.

Each leaf is a ParamSpec(shape, dtype, axes) where `axes` are LOGICAL names
('embed', 'heads', 'vocab', 'experts', 'layers', ...).  launch/mesh.py maps
logical names to mesh axes (FSDP/TP/EP rules) — models never mention the
mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: tuple
    dtype: object
    axes: tuple          # logical axis name (or None) per dim
    init_scale: float = 0.02


def is_spec(x):
    return isinstance(x, ParamSpec)


def abstract(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree, is_leaf=is_spec)


def axes_tree(tree):
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=is_spec)


def materialize(tree, key):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def init_one(spec, k):
        if spec.init_scale == 0.0:
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init_scale == -1.0:          # ones (norm scales)
            return jnp.ones(spec.shape, spec.dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = min(spec.init_scale, 1.0 / np.sqrt(max(fan_in, 1)))
        return (jax.random.truncated_normal(k, -2, 2, spec.shape, jnp.float32)
                * scale).astype(spec.dtype)

    return jax.tree.unflatten(treedef, [init_one(s, k)
                                        for s, k in zip(leaves, keys)])


def count_params(tree) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(tree, is_leaf=is_spec))
