"""Continuous-batching decode engine over the quantized KV cache
(DESIGN.md §10).

`models/serve.py` gives one aligned-batch decode step; production serving
is a slot machine: requests arrive at different times, prefill on another
host, and their pages migrate into decode slots mid-flight.  This module
drives the wire primitives (`PackedKV`, `PackedCache`,
`Transport.send_pages`) at request rate, in the style of MaxText's decode
microbenchmark:

    engine = DecodeEngine(cfg, params, n_slots=8, seq=2048)
    pre    = engine.prefill(prompt)        # -> pages (PackedCache wire)
    slot   = engine.allocate()
    engine.insert(slot, pre)               # decode through §7/§9 inverses
    logits, tokens = engine.generate_step()  # one batched step, all slots

Slot/page lifecycle: **allocate** (claim a free slot) → **fill** (each
step writes the slot's open hot page) → **close** (the filled page
quantizes in-step — serve.py's lax.cond) → **evict** (pack the slot back
to a `PackedCache` wire and free it: preemption / decode-host
rebalancing).  Closed pages cross any boundary ONLY as `PackedKV` wires:
`prefill` hands over a `PackedCache`, `evict` emits one, and streaming
migration ships single-page `PageWire`s — `stats()["wire_bytes"]`
accounts every transfer through `Transport.bytes_moved`, and nothing in
the engine ever moves a dequantized plane.

Bit-identity: every slot is a batch-1 `QuantCache` stacked on a leading
slot axis, and `generate_step` is `jax.vmap(serve_step)` over that axis
with per-slot positions.  Slot computations are data-independent, and
insertion decodes through the exact pack/unpack inverses, so each slot's
logits are bit-identical to the single-request `serve_step` path at the
same position (pinned by tests/test_engine.py, including through
evict → insert churn and cross-host migration).

Streaming migration (`stream_prefill`): on the prefill host each page is
packed and handed to `Transport.send_pages` the moment it closes, while
the host keeps enqueueing prefill steps — dispatch is async and the
page-p send has no data dependency on the page-p+1 compute, so the
transfer overlaps ongoing prefill instead of serializing behind a
monolithic end-of-prompt `transfer_cache`.  The open hot page rides raw
in the final tail send (it is not quantized yet — the serve.py §8
contract); every closed page crosses as a `PackedKV` wire.
"""
from __future__ import annotations

import collections
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import QuantizerConfig
from repro.core import audit as A
from repro.core.transport import TRANSPORT, Transport
from repro.compression import kv as KVC
from . import serve as S


class PageWire(NamedTuple):
    """One closed page on the wire: the K and V `PackedKV` slices for a
    single page — the unit of streaming migration (DESIGN.md §10)."""
    k: KVC.PackedKV
    v: KVC.PackedKV


class TailWire(NamedTuple):
    """The end-of-prefill remainder: the open hot page (raw by the §8
    contract — not quantized yet) plus the last prompt-position logits the
    decode host needs to pick the first generated token."""
    hot_k: jnp.ndarray
    hot_v: jnp.ndarray
    logits: jnp.ndarray


class PrefillResult(NamedTuple):
    """What `prefill`/`evict` hand to `insert`: closed pages as `PackedKV`
    wires inside a `PackedCache`, the next token to feed, and the insert
    position.  `logits` is the last computed position's logits (None on
    evict — the token is already chosen)."""
    pages: S.PackedCache
    next_token: jnp.ndarray          # int32 [1, 1]
    logits: Optional[jnp.ndarray]    # f32 [1, V]
    pos: int                         # next write position


class StreamedPrefill(NamedTuple):
    """`stream_prefill` result on the decode host: the slot cache
    assembled from per-page wires (use `DecodeEngine.insert_cache`), the
    first token, the insert position, and the transfer ledger."""
    cache: S.QuantCache              # batch-1, bit-identical to the source
    next_token: jnp.ndarray          # int32 [1, 1]
    logits: jnp.ndarray              # f32 [1, V]
    pos: int
    stats: dict


class DecodeEngine:
    """Continuous-batching decode over `n_slots` independent requests at
    per-slot positions, each slot a batch-1 quantized cache (DESIGN.md
    §10).  Host-side slot table; device state advances through one
    vmapped `serve_step` per `generate_step` call.

    `stages` is the per-page chain every boundary wire uses (a
    `KV_PAGE_CHAINS` preset value or raw fragment), or "auto"/"auto:SET"
    to let the §11 selector pick per page at page close — `pack_kv`
    resolves it, so prefill/evict/stream_prefill wires all inherit the
    choice and stay self-describing.

    `integrity` (DESIGN.md §12) names a degradation policy
    (`core.audit.DEGRADATION_POLICIES`: "raise" / "rerequest" / a
    registered custom handler).  When set, every boundary wire the
    engine emits carries the §12 checksum and `insert` re-verifies it:
    a clean check bumps `stats()["audit_checks"]`, a failed one bumps
    `audit_failures`, routes through the policy, and — unless the
    policy raised — the insert is refused (returns False) so the
    caller can re-request the pages."""

    def __init__(self, cfg: ArchConfig, params, *, n_slots: int, seq: int,
                 kv_cfg: QuantizerConfig | None = None, stages="zero",
                 transport: Transport | None = None,
                 integrity: str | None = None):
        assert seq % S.PAGE == 0, (seq, S.PAGE)
        assert cfg.family != "hybrid", "engine serves the QuantCache path"
        self.cfg, self.params = cfg, params
        self.n_slots, self.seq = int(n_slots), int(seq)
        self.kv_cfg = (KVC.kv_quantizer_config() if kv_cfg is None
                       else kv_cfg)
        self.stages = stages
        self.integrity = integrity
        if integrity is not None:
            A.get_policy(integrity)          # fail fast on unknown names
        self.transport = TRANSPORT if transport is None else transport
        one = S.make_quant_cache(cfg, 1, seq)
        self._cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_slots,) + x.shape), one)
        self._pos = jnp.zeros((self.n_slots,), jnp.int32)
        self._tok = jnp.zeros((self.n_slots, 1, 1), jnp.int32)
        self.requests: list = [None] * self.n_slots   # host-side slot table
        self._stats = dict(prefill_tokens=0, generated_tokens=0, steps=0,
                           wire_bytes=0.0, sends=0, inserts=0, evictions=0,
                           audit_checks=0, audit_failures=0,
                           audit_reports=0, audit_violations=0,
                           audit_nonfinite=0, audit_overflow=0,
                           audit_max_err=0.0)
        self._slot_audit = [dict(checks=0, failures=0)
                            for _ in range(self.n_slots)]
        self._step1 = jax.jit(self._one_step)
        self._vstep = jax.jit(self._slots_step)

    # --- jitted programs --------------------------------------------------

    def _one_step(self, params, cache, tok, pos):
        """The single-request serve path — the bit-identity reference."""
        return S.serve_step(self.cfg, params, cache, tok, pos, None,
                            self.kv_cfg)

    def _slots_step(self, params, cache, tok, pos, live):
        """vmap the batch-1 serve_step over the slot axis; freeze dead
        slots (their cache/pos/token must not drift while free)."""
        logits, new = jax.vmap(
            self._one_step, in_axes=(None, 0, 0, 0))(params, cache, tok, pos)
        keep = lambda n, o: jnp.where(
            live.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
        new = jax.tree.map(keep, new, cache)
        nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        tok = jnp.where(live, nxt, tok[:, 0, 0]).reshape(-1, 1, 1)
        pos = jnp.where(live, pos + 1, pos)
        return logits[:, 0], tok, pos, new

    # --- slot lifecycle ---------------------------------------------------

    def allocate(self) -> Optional[int]:
        """Claim a free slot (lifecycle step 1), or None when saturated."""
        for slot in range(self.n_slots):
            if self.requests[slot] is None:
                return slot
        return None

    def prefill(self, prompt) -> PrefillResult:
        """Run one request's prompt through the batch-1 `serve_step` chain
        and emit the slot-insert wire: closed pages leave as `PackedKV`
        (per-page chain `self.stages`), the open hot page rides raw."""
        prompt = jnp.asarray(prompt, jnp.int32).reshape(-1)
        m = int(prompt.shape[0])
        assert 0 < m < self.seq, (m, self.seq)
        cache = S.make_quant_cache(self.cfg, 1, self.seq)
        logits = None
        for i in range(m):
            logits, cache = self._step1(self.params, cache,
                                        prompt[i].reshape(1, 1),
                                        jnp.int32(i))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32).reshape(1, 1)
        wire = self._seal(S.pack_cache(cache, stages=self.stages))
        self._stats["prefill_tokens"] += m
        return PrefillResult(wire, nxt, logits, m)

    def _seal(self, wire: S.PackedCache) -> S.PackedCache:
        """Attach the §12 checksum to both wire planes (integrity on)."""
        if self.integrity is None:
            return wire
        return wire._replace(k=A.attach_checksum(wire.k),
                             v=A.attach_checksum(wire.v))

    def _verify_pages(self, slot: int, pages: S.PackedCache) -> bool:
        """§12 receive-side check: re-verify any carried checksum on the
        K/V wire planes.  Clean → True.  On mismatch the failure is
        counted (engine-wide and per slot) and routed through the
        configured degradation policy; returns False unless the policy
        raised."""
        ok = True
        for name, plane in (("k", pages.k), ("v", pages.v)):
            if not A.has_checksum(plane):
                continue
            self._stats["audit_checks"] += 1
            self._slot_audit[slot]["checks"] += 1
            if bool(A.verify_wire(plane)):
                continue
            ok = False
            self._stats["audit_failures"] += 1
            self._slot_audit[slot]["failures"] += 1
            A.get_policy(self.integrity or "raise")(dict(
                site="engine.insert", slot=slot, plane=name,
                what="PackedCache"))
        return ok

    def insert(self, slot: int, pre: PrefillResult, *, request=True) -> bool:
        """Insert a prefilled/evicted request into `slot`.  The wire
        decodes through the exact §7/§9 page-chain inverses
        (`unpack_cache`), so the slot history is bit-identical to the
        source cache and subsequent logits are bit-identical to the
        single-request path.  Accounts the wire via
        `Transport.bytes_moved(op='send_pages')`.

        Returns True on success.  With checksummed wires (§12), a failed
        check routes through the `integrity` policy first; if it returns
        (rerequest-style policies), the slot is left free and this
        returns False so the caller can fetch the pages again."""
        assert self.requests[slot] is None, f"slot {slot} is live"
        assert isinstance(pre.pages.k, KVC.PackedKV), type(pre.pages.k)
        assert isinstance(pre.pages.v, KVC.PackedKV), type(pre.pages.v)
        self._account(pre.pages)
        if not self._verify_pages(slot, pre.pages):
            return False
        self.insert_cache(slot, S.unpack_cache(pre.pages),
                          next_token=pre.next_token, pos=pre.pos,
                          request=request)
        return True

    def insert_cache(self, slot: int, cache1: S.QuantCache, *,
                     next_token, pos: int, request=True):
        """Landing-side insert of an already-decoded batch-1 cache (the
        streaming-migration path: its pages arrived one `PageWire` at a
        time and were assembled with `paste_pages`)."""
        assert self.requests[slot] is None, f"slot {slot} is live"
        self._cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice(
                full, one[None].astype(full.dtype),
                (slot,) + (0,) * one.ndim),
            self._cache, cache1)
        self._pos = self._pos.at[slot].set(pos)
        self._tok = self._tok.at[slot].set(
            jnp.asarray(next_token, jnp.int32).reshape(1, 1))
        self.requests[slot] = request
        self._stats["inserts"] += 1

    def generate_step(self):
        """One batched decode step over every live slot (lifecycle step 2:
        fill — and, on page boundaries, step 3: close).  Returns
        (logits f32 [n_slots, V], tokens int32 [n_slots]); dead-slot rows
        are stale and must be ignored by the caller."""
        live = [r is not None for r in self.requests]
        if not any(live):
            raise RuntimeError("generate_step with no live slot")
        for slot, on in enumerate(live):
            assert not on or int(self._pos[slot]) < self.seq, (
                f"slot {slot} ran past seq={self.seq}; release it first")
        logits, self._tok, self._pos, self._cache = self._vstep(
            self.params, self._cache, self._tok, self._pos,
            jnp.asarray(live))
        self._stats["steps"] += 1
        self._stats["generated_tokens"] += sum(live)
        return logits, self._tok[:, 0, 0]

    def evict(self, slot: int) -> PrefillResult:
        """Pack `slot` back to the `PackedCache` wire (lifecycle step 4 —
        preemption / rebalancing) and free it.  The result re-`insert`s
        into any engine bit-exactly."""
        assert self.requests[slot] is not None, f"slot {slot} is free"
        cache1 = jax.tree.map(lambda full: full[slot], self._cache)
        wire = self._seal(S.pack_cache(cache1, stages=self.stages))
        out = PrefillResult(wire, self._tok[slot], None,
                            int(self._pos[slot]))
        self._account(wire)
        self._stats["evictions"] += 1
        self.release(slot)
        return out

    def release(self, slot: int):
        """Free a slot without packing (request finished)."""
        self.requests[slot] = None

    # --- accounting -------------------------------------------------------

    def _account(self, wire):
        moved = float(self.transport.bytes_moved(wire, op="send_pages"))
        self._stats["wire_bytes"] += moved
        self._stats["sends"] += 1
        return moved

    def raw_slot_bytes(self) -> int:
        """bf16 K+V footprint of ONE slot's history at full `seq` — the
        wire-bytes-vs-raw denominator every report uses."""
        g, hd = self.cfg.n_kv_heads, self.cfg.head_dim
        return 2 * self.cfg.n_layers * self.seq * g * hd * 2

    def record_audit(self, report) -> None:
        """Fold a §12 `AuditReport` (or a list of them — the per-layer
        shape quantize-side callers produce with verify=True) into the
        engine's cumulative audit_* counters, surfaced by `stats()`.
        Mirrors `train_loop.AuditCounters` on the training side, so both
        runtimes report run-level bound violations the same way."""
        # AuditReport IS a NamedTuple — dispatch on the counter field,
        # not on tuple-ness, to tell one report from a list of them
        for rep in (report,) if hasattr(report, "violations") else report:
            if rep is None:
                continue
            self._stats["audit_reports"] += 1
            self._stats["audit_violations"] += int(rep.violations)
            self._stats["audit_nonfinite"] += int(rep.n_nonfinite)
            self._stats["audit_overflow"] += int(rep.overflow)
            self._stats["audit_max_err"] = max(
                self._stats["audit_max_err"], float(rep.max_err))

    def stats(self) -> dict:
        out = dict(self._stats)
        out["slot_audit"] = [dict(d) for d in self._slot_audit]
        return out

    # --- reference scheduler ----------------------------------------------

    def run(self, prompts, max_new_tokens: int, *, prefill_fn=None):
        """Reference continuous-batching loop: admit pending requests as
        slots free (churn), step every live slot, release finished ones.
        `prefill_fn(prompt)` may return a `PrefillResult` (local prefill,
        the default `self.prefill`) or a `StreamedPrefill` (pages already
        migrated from another host).  Returns {request index: [generated
        token ids]} — `max_new_tokens` each, greedy."""
        prefill_fn = self.prefill if prefill_fn is None else prefill_fn
        prompts = list(prompts)
        pending = collections.deque(enumerate(prompts))
        out = {rid: [] for rid in range(len(prompts))}
        budget = {}
        while pending or any(r is not None for r in self.requests):
            while pending:
                slot = self.allocate()
                if slot is None:
                    break
                rid, prompt = pending.popleft()
                pre = prefill_fn(prompt)
                if isinstance(pre, StreamedPrefill):
                    self.insert_cache(slot, pre.cache,
                                      next_token=pre.next_token,
                                      pos=pre.pos, request=rid)
                    self._stats["wire_bytes"] += pre.stats["wire_bytes"]
                    self._stats["sends"] += pre.stats["sends"]
                else:
                    self.insert(slot, pre, request=rid)
                out[rid].append(int(jnp.reshape(pre.next_token, ())))
                budget[rid] = max_new_tokens - 1
                if budget[rid] <= 0:
                    self.release(slot)
            if not any(r is not None for r in self.requests):
                continue
            _, toks = self.generate_step()
            toks = np.asarray(toks)
            for slot, rid in enumerate(list(self.requests)):
                if rid is None:
                    continue
                out[rid].append(int(toks[slot]))
                budget[rid] -= 1
                if budget[rid] <= 0 or int(self._pos[slot]) >= self.seq:
                    self.release(slot)          # slot churn
        return out


# --------------------------------------------------- streaming migration ---

def _shard_map(f, mesh, in_specs, out_specs, axis: str):
    """Version-compat shard_map (this repo supports pre-AxisType JAX)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names={axis},
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def stream_prefill(cfg: ArchConfig, params, prompt, *, seq: int, mesh,
                   axis: str, src: int = 0, dst: int = 1,
                   kv_cfg: QuantizerConfig | None = None, stages="zero",
                   transport: Transport | None = None) -> StreamedPrefill:
    """Prefill on mesh rank `src`, shipping each KV page to rank `dst`
    the moment it closes (DESIGN.md §10).  Every closed page crosses the
    link as a single-page `PageWire` (two `PackedKV`s) through
    `Transport.send_pages`; the open hot page and the final-position
    logits follow in one raw `TailWire`.  Sends are dispatched
    asynchronously between prefill steps, so page p's transfer overlaps
    page p+1's compute — slot churn never waits for (and never moves) a
    monolithic raw plane.

    Returns a `StreamedPrefill` whose cache is assembled on `dst` from
    the received wires and is bit-identical to the source cache; its
    `stats` carry the per-wire byte ledger
    (`[(kind, page index, bytes), ...]`, accounted via
    `Transport.bytes_moved`)."""
    from jax.sharding import PartitionSpec as P

    tp = TRANSPORT if transport is None else transport
    kv_cfg = KVC.kv_quantizer_config() if kv_cfg is None else kv_cfg
    prompt = jnp.asarray(prompt, jnp.int32).reshape(-1)
    m = int(prompt.shape[0])
    assert 0 < m < seq, (m, seq)

    def _send(wire):
        moved = tp.send_pages(wire, src, dst, axis)
        return jax.tree.map(lambda a: a[None], moved)

    send = jax.jit(_shard_map(_send, mesh, P(), P(axis), axis))
    take = lambda out: jax.tree.map(lambda a: a[dst], out)

    step = jax.jit(lambda p, c, t, i: S.serve_step(cfg, p, c, t, i, None,
                                                   kv_cfg))
    cache = S.make_quant_cache(cfg, 1, seq)
    ledger, inflight = [], []
    logits = None
    for i in range(m):
        logits, cache = step(params, cache, prompt[i].reshape(1, 1),
                             jnp.int32(i))
        if (i + 1) % S.PAGE == 0:
            p = i // S.PAGE
            wire = PageWire(
                KVC.pack_kv(KVC.slice_pages(cache.k, p, page=S.PAGE),
                            page=S.PAGE, stages=stages),
                KVC.pack_kv(KVC.slice_pages(cache.v, p, page=S.PAGE),
                            page=S.PAGE, stages=stages))
            # async dispatch: this send overlaps the next page's prefill
            inflight.append((p, send(wire)))
            ledger.append(("PageWire", p,
                           float(tp.bytes_moved(wire, op="send_pages"))))
    tail = TailWire(cache.hot_k, cache.hot_v, logits)
    got_tail = take(send(tail))
    ledger.append(("TailWire", m // S.PAGE,
                   float(tp.bytes_moved(tail, op="send_pages"))))

    # --- decode host: assemble the slot cache from the received wires ---
    recv = S.make_quant_cache(cfg, 1, seq)
    k, v = recv.k, recv.v
    for p, got in inflight:
        w = take(got)
        k = KVC.paste_pages(k, KVC.unpack_kv(w.k, page=S.PAGE), p,
                            page=S.PAGE)
        v = KVC.paste_pages(v, KVC.unpack_kv(w.v, page=S.PAGE), p,
                            page=S.PAGE)
    assembled = S.QuantCache(k, v, got_tail.hot_k, got_tail.hot_v)
    nxt = jnp.argmax(got_tail.logits, -1).astype(jnp.int32).reshape(1, 1)
    stats = dict(wire_bytes=sum(b for *_, b in ledger), sends=len(ledger),
                 pages_streamed=len(inflight), ledger=ledger,
                 prefill_tokens=m)
    return StreamedPrefill(assembled, nxt, got_tail.logits, m, stats)
