"""Shared model layers, written as GLOBAL math (GSPMD-style): functions
compute on full logical shapes; layout is imposed by in_shardings +
with_sharding_constraint at the few activation seams that matter (see
launch/mesh.py).  No manual collective bookkeeping — the dry-run roofline
reads whatever GSPMD inserts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

NEG_BIG = -1e30


class ShardCtx:
    """Activation sharding constraints (MaxText-style).

    GSPMD propagation alone replicates attention internals through the
    nested flash scans (measured: 530 GiB/device temp on stablelm
    train_4k).  `ctx(x, 'dp', None, 'model', None)` pins batch to the data
    axes and heads/ff to 'model' at the few seams that matter; axes whose
    size does not divide the dimension are dropped (e.g. whisper's 8 heads
    on a 16-way model axis -> replicated, visible in the roofline).
    """

    def __init__(self, mesh, dp=None):
        self.mesh = mesh
        if mesh is None:
            self.dp = ()
            self.sizes = {}
        else:
            self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            self.dp = tuple(dp) if dp is not None else tuple(
                a for a in ("pod", "data") if a in self.sizes)

    def _axis_size(self, a):
        if isinstance(a, tuple):
            n = 1
            for x in a:
                n *= self.sizes[x]
            return n
        return self.sizes[a]

    def __call__(self, x, *axes):
        if self.mesh is None:
            return x
        spec = []
        for dim, a in zip(x.shape, axes):
            if a == "dp":
                a = self.dp if len(self.dp) != 1 else self.dp[0]
            if a is None or a == () or dim % self._axis_size(a) != 0:
                spec.append(None)
            else:
                spec.append(a)
        # P-only constraint: resolved against the CONTEXT mesh, so it
        # works identically under jit and inside partial-manual shard_map
        # regions (a concrete NamedSharding's mesh would mismatch there)
        return jax.lax.with_sharding_constraint(x, P(*spec))


NULL_CTX = ShardCtx(None)


def rms_norm(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# --- rotary embeddings ------------------------------------------------------

def rope_tables(positions, dim, base=10000.0):
    """positions: int32 [...]; returns (cos, sin) of shape [..., dim/2]."""
    inv_freq = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, mode="full"):
    """x: [B, S, H, hd]; cos/sin: [B or 1, S, rot/2].

    mode 'full': rotate the whole head dim; 'partial' (chatglm3 2d-RoPE):
    rotate only the first half of the head dim, pass the rest through;
    'none': identity."""
    if mode == "none":
        return x
    hd = x.shape[-1]
    rot = hd if mode == "full" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    c = cos[..., None, :].astype(x.dtype)       # [B, S, 1, rot/2]
    s = sin[..., None, :].astype(x.dtype)
    y = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([y, xp], axis=-1) if mode == "partial" else y


# --- attention --------------------------------------------------------------

def repeat_kv(kv, group_size):
    """[B, S, G, hd] -> [B, S, G*group_size, hd]."""
    if group_size == 1:
        return kv
    b, s, g, hd = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, s, g, group_size, hd)
                            ).reshape(b, s, g * group_size, hd)


def flash_attention(q, k, v, *, causal=True, q_block=512, kv_block=1024,
                    ctx=NULL_CTX):
    """Online-softmax blocked attention in pure JAX (TPU flash pattern):
    memory O(q_block * kv_block) per step instead of O(S^2).

    q: [B, Sq, H, hd]; k, v: [B, Skv, H, hd] (GQA repeat done by caller).
    Block loops are lax.scans so the HLO stays O(1) in sequence length and
    the dry-run compiles for 512k contexts.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]

    def pick(n, target):
        # largest divisor <= target (whisper's 1500-frame encoder etc.)
        for c in range(min(target, n), 0, -1):
            if n % c == 0:
                return c
        return n

    q_block = pick(sq, q_block)
    kv_block = pick(skv, kv_block)
    nq, nk = sq // q_block, skv // kv_block
    scale = 1.0 / (hd ** 0.5)

    qs = ctx(q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 2, 3, 4),
             None, 'dp', None, 'model', None)
    ks = ctx(k.reshape(b, nk, kv_block, h, hd).transpose(1, 0, 2, 3, 4),
             None, 'dp', None, 'model', None)
    vs = ctx(v.reshape(b, nk, kv_block, h, hd).transpose(1, 0, 2, 3, 4),
             None, 'dp', None, 'model', None)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, k_blk, v_blk = ki_kv
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = ctx(s, 'dp', 'model', None, None)
            if causal:
                qpos = qi * q_block + jax.lax.broadcasted_iota(
                    jnp.int32, (q_block, kv_block), 0)
                kpos = ki * kv_block + jax.lax.broadcasted_iota(
                    jnp.int32, (q_block, kv_block), 1)
                s = jnp.where((kpos <= qpos)[None, None], s, NEG_BIG)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new,
                    ctx(acc_new, 'dp', 'model', None, None)), None

        m0 = jnp.full((b, h, q_block), NEG_BIG, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        a0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        # checkpoint: flash BACKWARD recomputes block scores instead of
        # saving the effectively-S^2 score stack across scan iterations
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (jnp.arange(nk), ks, vs))
        out = acc / l[..., None]
        return None, out.transpose(0, 2, 1, 3)        # [B, qb, H, hd]

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    return (outs.transpose(1, 0, 2, 3, 4)
            .reshape(b, sq, h, hd).astype(q.dtype))


def decode_attention(q, k_cache, v_cache, lengths, ctx=NULL_CTX):
    """Single-token GQA decode: q [B, 1, H, hd]; caches [B, S, G, hd];
    lengths int32 [B].  Grouped einsum keeps memory O(S), no KV repeat
    (S can be 512k)."""
    b, _, h, hd = q.shape
    s, g = k_cache.shape[1], k_cache.shape[2]
    gs = h // g
    qg = q.reshape(b, g, gs, hd)
    scores = ctx(jnp.einsum("bgqd,bsgd->bgqs", qg.astype(jnp.float32),
                            k_cache.astype(jnp.float32)) / (hd ** 0.5),
                 'dp', 'model', None, None)
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_BIG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgqs,bsgd->bgqd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def chunked_scan(step, carry, xs, chunk: int = 64, remat: bool = True):
    """Time scan in remat'd chunks: the backward pass saves carries only at
    chunk boundaries and replays inside.  A flat scan over T saves the
    carry EVERY step — for mLSTM's matrix state that was 12 TiB/device on
    train_4k.  xs leaves are [T, ...]."""
    t = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, t)
    if t % chunk:
        chunk = 1
    n_chunks = t // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape(n_chunks, chunk, *a.shape[1:]), xs)

    def chunk_body(c, xc):
        return jax.lax.scan(step, c, xc)

    body = jax.checkpoint(chunk_body) if remat else chunk_body
    carry, ys = jax.lax.scan(body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(t, *a.shape[2:]), ys)
    return carry, ys


# --- FFN --------------------------------------------------------------------

def ffn(x, w1, w3, w2, act="swiglu", ctx=NULL_CTX):
    if act == "swiglu":
        h = jax.nn.silu(x @ w1) * (x @ w3)
    else:  # gelu (whisper)
        h = jax.nn.gelu(x @ w1, approximate=True)
    h = ctx(h, 'dp', None, 'model')
    return h @ w2


# --- init helpers -----------------------------------------------------------

def trunc_init(key, shape, dtype, scale=0.02):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)
