"""Mamba (selective SSM) block — the sub-quadratic half of jamba.

Baseline implementation uses a sequential lax.scan over time (exact
recurrence, O(T) memory via carry; the HLO stays O(1) in T).  A chunked
parallel form is a known perf lever (§Perf notes) — the roofline for the
hybrid arch is dominated by attention+MoE layers, so the scan is not the
bottleneck at the assigned shapes.

State per layer: conv tail [B, K-1, Di] + ssm state [B, Di, N] — this is
what replaces the KV cache for decode (and what compression/kv.py
quantizes for the 'SSM state compression' variant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CONV_K = 4


def mamba_params_shape(d_model, d_state, dtype):
    di = 2 * d_model
    return {
        "in_proj": ((d_model, 2 * di), dtype),
        "conv_w": ((CONV_K, di), jnp.float32),
        "a_log": ((di, d_state), jnp.float32),
        "d_skip": ((di,), jnp.float32),
        "bc_proj": ((di, 2 * d_state), dtype),
        "dt_proj": ((di, di), dtype),
        "dt_bias": ((di,), jnp.float32),
        "out_proj": ((di, d_model), dtype),
    }


def _ssm_step_factory(a):
    """a: [Di, N] static per layer.  The [B,Di,N] da/dbx terms are formed
    INSIDE the step from [B,Di]/[B,N] inputs — materializing them for all
    T as scan xs cost 17+ GiB/device on jamba train_4k."""

    def step(h, inputs):
        dt_u, bmat, c, dt = inputs  # [B,Di], [B,N], [B,N], [B,Di]
        da = dt[..., None] * a
        h = jnp.exp(da) * h + dt_u[..., None] * bmat[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    return step


def mamba_block(p, x, state=None, ctx=None):
    """x: [B, T, D].  state: (conv_tail [B, K-1, Di], h [B, Di, N]) for
    decode; None for training (zero init).  Returns (y, new_state)."""
    b, t, d = x.shape
    di = p["conv_w"].shape[1]
    n = p["a_log"].shape[1]
    if ctx is None:
        from .layers import NULL_CTX as ctx
    xz = ctx(x @ p["in_proj"], 'dp', None, 'model')
    xin, z = jnp.split(xz, 2, axis=-1)                     # [B, T, Di]

    # causal depthwise conv over time
    if state is None:
        tail = jnp.zeros((b, CONV_K - 1, di), xin.dtype)
    else:
        tail = state[0]
    xpad = jnp.concatenate([tail, xin], axis=1)            # [B, T+K-1, Di]
    conv = sum(xpad[:, i: i + t] * p["conv_w"][i].astype(xin.dtype)
               for i in range(CONV_K))
    new_tail = xpad[:, -(CONV_K - 1):]
    u = jax.nn.silu(conv)                                  # [B, T, Di]

    bc = u @ p["bc_proj"]
    bmat, cmat = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # [B,T,N]
    dt = jax.nn.softplus((u @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])                   # [B, T, Di]
    dt = ctx(dt, 'dp', None, 'model')
    a = -jnp.exp(p["a_log"])                               # [Di, N]
    dt_u = dt * u.astype(jnp.float32)

    h0 = jnp.zeros((b, di, n), jnp.float32) if state is None else state[1]
    from .layers import chunked_scan
    h, ys = chunked_scan(
        _ssm_step_factory(a), h0,
        (dt_u.transpose(1, 0, 2), bmat.transpose(1, 0, 2),
         cmat.transpose(1, 0, 2), dt.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2).astype(x.dtype)              # [B, T, Di]
    y = y + u * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], (new_tail, h)
