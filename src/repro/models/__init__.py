"""Model zoo: dense/MoE/hybrid/SSM/enc-dec families behind one dispatcher
(models.model.build)."""
from .model import ModelBundle, build
