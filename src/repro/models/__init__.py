"""Model zoo: dense/MoE/hybrid/SSM/enc-dec families behind one dispatcher
(models.model.build), plus the continuous-batching decode engine
(models.engine, DESIGN.md §10)."""
from .model import ModelBundle, build
