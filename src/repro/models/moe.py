"""Mixture-of-Experts FFN with expert parallelism.

Routing is done shard-locally and experts are exchanged with explicit
all-to-alls inside a shard_map — the production MoE pattern (GSPMD's
auto-sharding of gather/scatter would otherwise replicate the token
stream, and the GShard one-hot dispatch einsum would add O(S^2 * D)
FAKE dispatch FLOPs that corrupt the roofline).

Dispatch is capacity-based scatter into static [E, C, D] buffers:
  slot = expert_id * C + position_within_expert  (position via a one-hot
  cumsum; over-capacity (token, k) pairs are dropped, standard practice).
Expert weights shard over the 'model' mesh axis (EP); tokens over the data
axes.  The two all-to-alls per layer are what the collective-roofline term
sees for MoE architectures.

For single-device smoke tests pass axis_name=None: identical math minus
the collectives.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _route(x_flat, router_w, top_k):
    logits = (x_flat @ router_w.astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [N, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # [N, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    # Shazeer load-balance aux: E * sum_e mean_prob_e * token_frac_e
    e = router_w.shape[-1]
    me = probs.mean(0)
    ce = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).sum(1).mean(0)
    aux = e * jnp.sum(me * ce) / top_k
    return gate_vals, gate_idx, aux


def moe_ffn_local(x, router_w, w1, w3, w2, *, top_k,
                  capacity_factor=1.0, act="swiglu",
                  model_axis: Optional[str] = None, all_axes=None):
    """x: [B?, T, D] LOCAL shard; w1/w3 [El, D, F], w2 [El, F, D] LOCAL
    expert shard (El = E / ep_size; ep_size = 1 when model_axis is None).
    Returns (out, aux)."""
    orig_shape = x.shape
    d = x.shape[-1]
    x_flat = x.reshape(-1, d)
    n = x_flat.shape[0]
    el = w1.shape[0]
    ep = 1 if model_axis is None else jax.lax.axis_size(model_axis)
    e = el * ep
    cap = max(1, int(capacity_factor * top_k * n / e))

    gate_vals, gate_idx, aux = _route(x_flat, router_w, top_k)

    # position of each (token, k) within its expert (one-hot cumsum)
    oh = jax.nn.one_hot(gate_idx.reshape(-1), e, dtype=jnp.int32)  # [N*K, E]
    pos = jnp.take_along_axis(jnp.cumsum(oh, 0) - oh,
                              gate_idx.reshape(-1, 1), axis=1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, gate_idx.reshape(-1) * cap + pos, e * cap)

    # scatter tokens into expert buffers [E*C, D] (drop over-capacity);
    # a single scatter keeps backward to one gather (a per-k python loop
    # kept 8 [N*K, D] f32 cotangents alive — measured 34 GiB on qwen3)
    xk = jnp.repeat(x_flat, top_k, axis=0)                   # [N*K, D]
    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(xk, mode="drop")
    buf = buf.reshape(e, cap, d)

    if model_axis is not None:
        # exchange: every shard sends its per-expert buffers to the owner
        # -> [El, ep*C, D] local expert batches
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
    h = jnp.einsum("ecd,edf->ecf", buf, w1.astype(x.dtype))
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf,
                                        w3.astype(x.dtype))
    else:
        h = jax.nn.gelu(h, approximate=True)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2.astype(x.dtype))
    if model_axis is not None:
        out_buf = jax.lax.all_to_all(out_buf, model_axis, split_axis=1,
                                     concat_axis=0, tiled=True)

    # gather own tokens back and combine with gate weights
    y = out_buf.reshape(e * cap, d).at[slot].get(mode="fill", fill_value=0)
    y = (y.reshape(n, top_k, d)
         * gate_vals[..., None].astype(x.dtype)).sum(axis=1)
    if all_axes is not None:
        aux = jax.lax.pmean(aux, all_axes)
    return y.reshape(orig_shape), aux


def moe_ffn_decode_local(x, router_w, w1, w3, w2, *, top_k, act,
                         model_axis):
    """Decode-step MoE: a handful of tokens, so capacity dispatch and
    all-to-alls are pure overhead (and 1 token cannot shard over 32 data
    shards).  Each model shard runs its LOCAL experts over all (already
    dp-sharded) tokens and a psum combines — compute is tiny at B tokens.
    """
    orig_shape = x.shape
    d = x.shape[-1]
    x_flat = x.reshape(-1, d)
    el = w1.shape[0]
    ep = jax.lax.axis_size(model_axis)
    gate_vals, gate_idx, aux = _route(x_flat, router_w, top_k)
    e0 = jax.lax.axis_index(model_axis) * el

    h = jnp.einsum("nd,edf->enf", x_flat, w1.astype(x.dtype))
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("nd,edf->enf", x_flat,
                                        w3.astype(x.dtype))
    else:
        h = jax.nn.gelu(h, approximate=True)
    y_e = jnp.einsum("enf,efd->end", h, w2.astype(x.dtype))  # [el, N, D]

    # weight of each LOCAL expert for each token
    eids = e0 + jnp.arange(el)                                # [el]
    w_ne = jnp.sum(gate_vals[None, :, :]
                   * (gate_idx[None, :, :] == eids[:, None, None]),
                   axis=-1).astype(x.dtype)                   # [el, N]
    y = jnp.einsum("end,en->nd", y_e, w_ne)
    # f32 psum: XLA:CPU's AllReducePromotion pass crashes cloning a bf16
    # all-reduce here (upstream bug); f32 makes the promotion a no-op and
    # is also the numerically right accumulation dtype
    y = jax.lax.psum(y.astype(jnp.float32), model_axis).astype(x.dtype)
    aux = jax.lax.pmean(aux, model_axis)
    return y.reshape(orig_shape), aux


def moe_ffn(x, router_w, w1, w3, w2, *, top_k, mesh=None,
            capacity_factor=1.0, act="swiglu",
            data_axes=("data",), model_axis="model"):
    """Global entry point: shard_map over (data_axes x model_axis) when a
    mesh is given, plain local math otherwise (smoke tests).  Single-token
    (decode) calls use the replicated-token expert-parallel path."""
    if mesh is None:
        return moe_ffn_local(x, router_w, w1, w3, w2, top_k=top_k,
                             capacity_factor=capacity_factor, act=act)
    if x.ndim >= 2 and x.shape[-2] == 1:          # decode step
        fn = functools.partial(moe_ffn_decode_local, top_k=top_k, act=act,
                               model_axis=model_axis)
        mapped = jax.shard_map(
            fn, mesh=mesh,
            in_specs=(P(), P(None, None), P(model_axis, None, None),
                      P(model_axis, None, None), P(model_axis, None, None)),
            out_specs=(P(), P()),
            axis_names={model_axis}, check_vma=False)
        return mapped(x, router_w, w1, w3, w2)

    all_axes = tuple(data_axes) + (model_axis,)
    fn = functools.partial(moe_ffn_local, top_k=top_k,
                           capacity_factor=capacity_factor, act=act,
                           model_axis=model_axis, all_axes=all_axes)
    mapped = jax.shard_map(
        lambda xx, rw, a, bb, c: fn(xx, rw, a, bb, c),
        mesh=mesh,
        in_specs=(P(data_axes, None, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(data_axes, None, None), P()),
        check_vma=False)
    out, aux = mapped(x, router_w, w1, w3, w2)
    return out, aux
