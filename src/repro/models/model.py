"""Family dispatcher: ArchConfig -> (param specs, loss fn, serve fn,
cache factory, input specs).  The single public surface used by smoke
tests, the launcher, and the dry-run.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from . import encdec, serve, transformer, xlstm_stack
from .params import abstract, axes_tree, count_params, materialize
from .serve import PAGE, QuantCache, RawCache
from .transformer import DTYPE


class ModelBundle(NamedTuple):
    cfg: ArchConfig
    specs: dict

    # --- params -----------------------------------------------------------
    def init(self, key):
        return materialize(self.specs, key)

    def abstract_params(self):
        return abstract(self.specs)

    def axes(self):
        return axes_tree(self.specs)

    def n_params(self) -> int:
        return count_params(self.specs)

    # --- training ---------------------------------------------------------
    def loss(self, params, batch, mesh=None, remat=True,
             moe_data_axes=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, aux = encdec.forward(cfg, params, batch["tokens"],
                                         batch["frames"], mesh, remat)
        elif cfg.family == "ssm":
            logits, aux = xlstm_stack.forward(cfg, params, batch["tokens"],
                                              mesh, remat)
        else:
            logits, aux = transformer.forward(cfg, params, batch["tokens"],
                                              mesh, remat, moe_data_axes)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits.astype(jnp.float32),
                                 labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - ll)
        return ce + 0.01 * aux, (ce, aux)

    # --- serving ----------------------------------------------------------
    def make_cache(self, batch, seq, quantized=False):
        cfg = self.cfg
        if cfg.family == "ssm":
            return xlstm_stack.make_cache(cfg, batch, seq)
        if cfg.family == "encdec":
            return encdec.make_cache(cfg, batch, seq)
        if cfg.family == "hybrid":
            periods = cfg.n_layers // cfg.attn_period
            n_mamba = cfg.attn_period - 1
            di = 2 * cfg.d_model
            attn = serve.make_raw_cache(cfg, batch, seq, n_layers=periods)
            tails = jnp.zeros((periods, n_mamba, batch, serve.M.CONV_K - 1,
                               di), DTYPE)
            hs = jnp.zeros((periods, n_mamba, batch, di, cfg.ssm_state),
                           jnp.float32)
            return (attn, (tails, hs))
        if quantized:
            return serve.make_quant_cache(cfg, batch, seq)
        return serve.make_raw_cache(cfg, batch, seq)

    def serve_step(self, params, cache, tokens, pos, mesh=None, kv_cfg=None):
        cfg = self.cfg
        if cfg.family == "ssm":
            return xlstm_stack.serve_step(cfg, params, cache, tokens, pos,
                                          mesh, kv_cfg)
        if cfg.family == "encdec":
            return encdec.serve_step(cfg, params, cache, tokens, pos, mesh,
                                     kv_cfg)
        return serve.serve_step(cfg, params, cache, tokens, pos, mesh,
                                kv_cfg)

    # --- dry-run inputs ----------------------------------------------------
    def input_specs(self, shape: ShapeConfig, quantized_kv=False):
        """ShapeDtypeStruct stand-ins for every model input of this
        (arch, shape) cell — no device allocation."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            d = {"tokens": tok, "labels": tok}
            if cfg.family == "encdec":
                d["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_context, cfg.d_model), DTYPE)
            return d
        if shape.kind == "prefill":
            d = {"tokens": tok}
            if cfg.family == "encdec":
                d["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_context, cfg.d_model), DTYPE)
            return d
        # decode: one new token against a seq_len cache
        cache = jax.eval_shape(
            lambda: self.make_cache(b, s, quantized=quantized_kv))
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": cache,
        }

    def prefill(self, params, batch, mesh=None):
        """Forward pass without loss (the prefill_32k shape's program)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, _ = encdec.forward(cfg, params, batch["tokens"],
                                       batch["frames"], mesh, remat=False)
        elif cfg.family == "ssm":
            logits, _ = xlstm_stack.forward(cfg, params, batch["tokens"],
                                            mesh, remat=False)
        else:
            logits, _ = transformer.forward(cfg, params, batch["tokens"],
                                            mesh, remat=False)
        return logits[:, -1].astype(jnp.float32)


def build(cfg: ArchConfig) -> ModelBundle:
    if cfg.family == "ssm":
        specs = xlstm_stack.param_specs(cfg)
    elif cfg.family == "encdec":
        specs = encdec.param_specs(cfg)
    else:
        specs = transformer.param_specs(cfg)
    return ModelBundle(cfg, specs)
