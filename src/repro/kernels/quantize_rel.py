"""Pallas TPU kernel: fused REL quantize with the paper's bit-manipulation
log2/pow2 INSIDE the kernel.

The parity-safe transcendentals are bitcast + integer ops — exactly the
operations the TPU VPU does natively, so the paper's CPU/GPU trick becomes
a zero-transcendental TPU kernel (no lookup-table exp/log units touched,
fully deterministic).  Math is the bit-exact twin of
core.quantizer.quantize_rel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .quantize_abs import DEFAULT_ROWS, LANES


def _log2approx(x, mb, emask, bias):
    int_t = jnp.int32 if x.dtype == jnp.float32 else jnp.int64
    orig_i = lax.bitcast_convert_type(x, int_t)
    expo = (orig_i >> mb) & emask
    frac_i = (bias << mb) | (orig_i & ((1 << mb) - 1))
    frac_f = lax.bitcast_convert_type(frac_i.astype(int_t), x.dtype)
    return frac_f + (expo - (bias + 1)).astype(x.dtype)


def _pow2approx(l, mb, bias):
    int_t = jnp.int32 if l.dtype == jnp.float32 else jnp.int64
    biased = l + bias        # FMA-immune: l is an exact pow2-step product
    expo = biased.astype(int_t)
    frac_f = biased - (expo - 1).astype(l.dtype)
    frac_i = lax.bitcast_convert_type(frac_f, int_t)
    exp_i = (expo << mb) | (frac_i & ((1 << mb) - 1))
    return lax.bitcast_convert_type(exp_i, l.dtype)


def _kernel(x_ref, bins_ref, out_ref, recon_ref, sign_ref, *, maxbin, tighten,
            eb, log_step, inv_log_step, screen, tiny, mb, emask, bias):
    x = x_ref[...]
    dt = x.dtype
    int_t = jnp.int32 if dt == jnp.float32 else jnp.int64

    finite = jnp.isfinite(x)
    ax = jnp.abs(x)
    too_small = ~(ax >= jnp.asarray(screen, dt))           # FTZ screen
    safe = jnp.where(finite & ~too_small, ax, jnp.ones((), dt))
    lg = _log2approx(safe, mb, emask, bias)
    bin_f = jnp.rint(lg * jnp.asarray(inv_log_step, dt))
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)   # paper §3.3 form

    neg = lax.bitcast_convert_type(x, int_t) < 0           # bit-pattern sign
    mag = _pow2approx(bin_i.astype(dt) * jnp.asarray(log_step, dt), mb, bias)
    recon = jnp.where(neg, -mag, mag)
    ebT = jnp.asarray(dt.type(eb) * dt.type(tighten), dt)
    ok = (jnp.abs(x - recon) <= ebT * ax) & jnp.isfinite(recon)
    ok &= mag >= jnp.asarray(tiny, dt)
    outlier = (~finite) | too_small | range_bad | range_bad_i | ~ok

    bins_ref[...] = jnp.where(outlier, 0, bin_i)
    out_ref[...] = outlier
    recon_ref[...] = jnp.where(outlier, jnp.zeros((), dt), recon)
    sign_ref[...] = neg


def quantize_rel_pallas(x2d: jnp.ndarray, *, cfg, rows: int = DEFAULT_ROWS,
                        interpret: bool = True):
    """x2d: [R_total, 128] with R_total % rows == 0."""
    import numpy as np

    r_total, lanes = x2d.shape
    assert lanes == LANES and r_total % rows == 0
    dt = x2d.dtype
    eb_, log_step, inv_log_step = cfg.rel_constants()
    mb, emask, bias = (23, 0xFF, 127) if dt == jnp.float32 else (52, 0x7FF, 1023)
    body = functools.partial(
        _kernel, maxbin=cfg.maxbin, tighten=cfg.tighten, eb=float(eb_),
        log_step=float(log_step), inv_log_step=float(inv_log_step),
        screen=float(cfg.rel_screen_threshold()), tiny=float(np.finfo(dt).tiny),
        mb=mb, emask=emask, bias=bias)
    spec = pl.BlockSpec((rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        body,
        grid=(r_total // rows,),
        in_specs=[spec],
        out_specs=[spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((r_total, LANES), jnp.int32),
            jax.ShapeDtypeStruct((r_total, LANES), jnp.bool_),
            jax.ShapeDtypeStruct((r_total, LANES), dt),
            jax.ShapeDtypeStruct((r_total, LANES), jnp.bool_),
        ],
        interpret=interpret,
    )(x2d)
