"""Pallas TPU kernel: fused ABS quantize + double-check + outlier flag.

One pass over HBM: reads x, writes (bins, outlier, recon).  The math is the
bit-exact twin of core.quantizer.quantize_abs (the oracle); the kernel
exists because on TPU the quantize step of gradient/KV compression runs on
the critical path between the backward pass and the inter-pod collective.

Design notes (TPU adaptation of the paper's GPU codec, DESIGN.md §3):
  * pure VPU elementwise work at ~1 flop/byte -> memory-bound; the paper's
    "double-checking is throughput-free" claim holds structurally because
    the extra compare/select ops ride along under the same HBM stream.
  * block shape (ROWS, 128): lane-dim 128 matches the VPU; ROWS=256 gives
    128 KiB per f32 buffer, 4 buffers ~= 0.5 MiB VMEM of ~16 MiB -> plenty
    of headroom for double buffering.
  * eb arrives as a (1,1) operand (not a compile-time constant) so the SAME
    compiled kernel serves per-tensor traced bounds (NOA-style gradient
    compression) and static config bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_ROWS = 256
LANES = 128


def _kernel(x_ref, eb_ref, bins_ref, out_ref, recon_ref, *, maxbin, tighten,
            eb_floor):
    x = x_ref[...]
    dt = x.dtype
    eb_in = eb_ref[0, 0]
    degenerate = ~(eb_in >= eb_floor)            # FTZ guard (see core.config)
    eb = jnp.maximum(eb_in, eb_floor)
    mant_mask = (1 << 23) - 1 if dt == jnp.float32 else (1 << 52) - 1
    int_t = jnp.int32 if dt == jnp.float32 else jnp.int64
    # pow2-floored step: bin*eb2 and x*inv_eb2 become exact -> FMA-immune
    eb2 = lax.bitcast_convert_type(
        lax.bitcast_convert_type(jnp.asarray(2.0, dt) * eb, int_t) & ~mant_mask,
        dt)
    inv_eb2 = jnp.asarray(1.0, dt) / eb2

    finite = jnp.isfinite(x)
    xs = jnp.where(finite, x, jnp.zeros((), dt))
    bin_f = jnp.rint(xs * inv_eb2)
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)   # paper §3.3 form

    recon = bin_i.astype(dt) * eb2               # exact (pow2 step)
    fails = ~(jnp.abs(x - recon) <= eb * jnp.asarray(tighten, dt))
    fails |= ~jnp.isfinite(recon)    # recon-overflow guard (see quantizer.py)
    outlier = (~finite) | range_bad | range_bad_i | fails | degenerate

    bins_ref[...] = jnp.where(outlier, 0, bin_i)
    out_ref[...] = outlier
    recon_ref[...] = jnp.where(outlier, jnp.zeros((), dt), recon)


def quantize_abs_pallas(x2d: jnp.ndarray, eb: jnp.ndarray, *, maxbin: int,
                        tighten: float, eb_floor: float,
                        rows: int = DEFAULT_ROWS, interpret: bool = True):
    """x2d: [R_total, 128] with R_total % rows == 0.  eb: [1, 1]."""
    r_total, lanes = x2d.shape
    assert lanes == LANES and r_total % rows == 0
    grid = (r_total // rows,)
    dt = x2d.dtype
    body = functools.partial(_kernel, maxbin=maxbin, tighten=tighten,
                             eb_floor=eb_floor)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),      # eb broadcast
        ],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r_total, LANES), jnp.int32),
            jax.ShapeDtypeStruct((r_total, LANES), jnp.bool_),
            jax.ShapeDtypeStruct((r_total, LANES), dt),
        ],
        interpret=interpret,
    )(x2d, eb)
