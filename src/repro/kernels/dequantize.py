"""Pallas TPU kernel: fused dequantize + bit-exact outlier restore.

Decoder side of the ABS/REL codec over the DENSE layout: recon = bin * eb2
(or sign * pow2approx(bin * w)), then outlier positions are overwritten by
bitcasting the lossless payload back to float.  Elementwise, memory-bound;
the fusion saves one full HBM round-trip vs dequantize-then-select.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .quantize_abs import DEFAULT_ROWS, LANES
from .quantize_rel import _pow2approx


def _abs_kernel(bins_ref, payload_ref, out_mask_ref, eb_ref, y_ref, *,
                eb_floor):
    dt = y_ref.dtype
    eb = jnp.maximum(eb_ref[0, 0], jnp.asarray(eb_floor, dt))
    mant_mask = (1 << 23) - 1 if dt == jnp.float32 else (1 << 52) - 1
    int_t = jnp.int32 if dt == jnp.float32 else jnp.int64
    eb2 = lax.bitcast_convert_type(
        lax.bitcast_convert_type(jnp.asarray(2.0, dt) * eb, int_t) & ~mant_mask,
        dt)                                      # pow2 step, matches encoder
    recon = bins_ref[...].astype(dt) * eb2       # exact
    exact = lax.bitcast_convert_type(payload_ref[...], dt)
    y_ref[...] = jnp.where(out_mask_ref[...], exact, recon)


def _rel_kernel(bins_ref, payload_ref, out_mask_ref, sign_ref, y_ref, *,
                log_step, mb, bias):
    dt = y_ref.dtype
    mag = _pow2approx(bins_ref[...].astype(dt) * jnp.asarray(log_step, dt),
                      mb, bias)
    recon = jnp.where(sign_ref[...], -mag, mag)
    exact = lax.bitcast_convert_type(payload_ref[...], dt)
    y_ref[...] = jnp.where(out_mask_ref[...], exact, recon)


def dequantize_abs_pallas(bins2d, payload2d, outlier2d, eb, *, dtype,
                          eb_floor, rows=DEFAULT_ROWS, interpret=True):
    r_total, lanes = bins2d.shape
    assert lanes == LANES and r_total % rows == 0
    spec = pl.BlockSpec((rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_abs_kernel, eb_floor=eb_floor),
        grid=(r_total // rows,),
        in_specs=[spec, spec, spec, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r_total, LANES), dtype),
        interpret=interpret,
    )(bins2d, payload2d, outlier2d, eb)


def dequantize_rel_pallas(bins2d, payload2d, outlier2d, sign2d, *, cfg,
                          dtype, rows=DEFAULT_ROWS, interpret=True):
    r_total, lanes = bins2d.shape
    assert lanes == LANES and r_total % rows == 0
    _, log_step, _ = cfg.rel_constants()
    mb, bias = (23, 127) if jnp.dtype(dtype) == jnp.float32 else (52, 1023)
    spec = pl.BlockSpec((rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_rel_kernel, log_step=float(log_step), mb=mb,
                          bias=bias),
        grid=(r_total // rows,),
        in_specs=[spec] * 4,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r_total, LANES), dtype),
        interpret=interpret,
    )(bins2d, payload2d, outlier2d, sign2d)
