"""Pallas TPU kernels: device-side lossless stage over the packed words.

The paper's LC pipeline wins its compression ratio in the lossless coder
that FOLLOWS quantize+pack — the stage GPU compressors keep resident
(cuSZ's Huffman over quantization codes, arXiv 2007.09625; FZ-GPU's
bitshuffle + zero-suppression fused after quantization, arXiv 2304.12557).
These kernels are the TPU-shaped equivalent of that stage for the chunked
zero/narrow scheme of DESIGN.md §6 (reference: core.codec.encode_words_lc):

  * a chunk is LC_CHUNK = 512 words = 4 sublane rows x 128 lanes, so the
    per-chunk reduction (max word) and the width-narrowing are pure
    sublane operations on the VPU — narrowing IS the same _pack_block
    shift/or the quantize+pack kernels already use, at chunk granularity;
  * the fused path (`encode_packed_lc`) extends the quantize+pack kernel
    of kernels/pack.py with the chunk scan, so x is read ONCE from HBM
    and what comes back is already the narrowed chunk image + the 2-bit
    header codes — the lossless stage rides the existing memory stream;
  * the variable-length compaction (cumsum of chunk lengths + scatter)
    and its inverse gather are NOT kernels: they are cheap O(n_words)
    XLA ops over the narrowed intermediate, shared verbatim with the
    reference (core.codec.lc_compact_payload / lc_gather_chunks), which
    is what makes kernel and reference bit-identical by construction.

Everything validates in interpret mode on CPU (tests/test_lossless.py);
block shapes are TPU-native but unmeasured on hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import QuantizerConfig
from repro.core import codec as C
from repro.core.bitops import float_to_bits

from .pack import (LANES, _abs_quantize_block, _narrow_mask, _pack_block,
                   _rel_quantize_block, _tile_words, _unpack_block,
                   _use_interpret)
from .quantize_abs import DEFAULT_ROWS

CHUNK_ROWS = C.LC_CHUNK // LANES        # word rows per chunk (= 4)


# ------------------------------------------------------------- in-kernel --

def _chunk_select_block(words, stage):
    """words: uint32[wrows, 128], wrows % CHUNK_ROWS == 0.  Returns
    (sel uint32[wrows, 128], codes uint32[wrows/CHUNK_ROWS, 128]): each
    chunk's narrowed image left-aligned in its own rows (zero-padded), and
    its 2-bit width code broadcast across lanes."""
    wrows = words.shape[0]
    nck = wrows // CHUNK_ROWS
    grp = words.reshape(nck, CHUNK_ROWS, LANES)
    mx = jnp.max(grp, axis=(1, 2))                         # [nck]
    zero = mx == 0
    if stage == "zero":
        codes = jnp.where(zero, 0, 3)
    else:
        codes = jnp.where(zero, 0,
                          jnp.where(mx < (1 << 8), 1,
                                    jnp.where(mx < (1 << 16), 2, 3)))
    # CHUNK_ROWS == vpw at width 8 and 2*vpw at width 16, so the whole-block
    # _pack_block groups exactly one chunk per candidate row group — same
    # grouping as the reference's full-stream pack_words.
    cand1 = _pack_block(words, 4, 8).reshape(nck, 1, LANES)
    cand2 = _pack_block(words, 2, 16).reshape(nck, 2, LANES)
    z1 = jnp.zeros((nck, CHUNK_ROWS - 1, LANES), jnp.uint32)
    z2 = jnp.zeros((nck, CHUNK_ROWS - 2, LANES), jnp.uint32)
    pad1 = jnp.concatenate([cand1, z1], axis=1)
    pad2 = jnp.concatenate([cand2, z2], axis=1)
    cb = codes[:, None, None]
    sel = jnp.where(cb == 1, pad1,
                    jnp.where(cb == 2, pad2,
                              jnp.where(cb == 3, grp, jnp.uint32(0))))
    codes_b = jnp.broadcast_to(codes.astype(jnp.uint32)[:, None],
                               (nck, LANES))
    return sel.reshape(wrows, LANES), codes_b


def _chunk_expand_block(padded, codes_b):
    """Inverse of _chunk_select_block: padded uint32[wrows, 128] +
    codes uint32[wrows/CHUNK_ROWS, 128] -> words uint32[wrows, 128]."""
    wrows = padded.shape[0]
    nck = wrows // CHUNK_ROWS
    grp = padded.reshape(nck, CHUNK_ROWS, LANES)
    exp1 = _unpack_block(grp[:, 0, :], 4, 8,
                         signed=False).reshape(nck, CHUNK_ROWS, LANES)
    exp2 = _unpack_block(grp[:, :2, :].reshape(nck * 2, LANES), 2, 16,
                         signed=False).reshape(nck, CHUNK_ROWS, LANES)
    cb = codes_b[:, :1].reshape(nck, 1, 1)     # lanes carry identical codes
    words = jnp.where(cb == 1, exp1,
                      jnp.where(cb == 2, exp2,
                                jnp.where(cb == 3, grp, jnp.uint32(0))))
    return words.reshape(wrows, LANES)


def _lc_select_kernel(words_ref, sel_ref, codes_ref, *, stage):
    sel, codes = _chunk_select_block(words_ref[...], stage)
    sel_ref[...] = sel
    codes_ref[...] = codes


def _lc_expand_kernel(padded_ref, codes_ref, words_ref):
    words_ref[...] = _chunk_expand_block(padded_ref[...], codes_ref[...])


def _abs_pack_lc_kernel(x_ref, eb_ref, words_ref, out_ref, sel_ref,
                        codes_ref, *, maxbin, tighten, eb_floor, bin_bits,
                        stage):
    """Quantize + pack + chunk-narrow in ONE pass over x (DESIGN.md §3/§6:
    elementwise codec work is memory-bound, so the lossless scan rides the
    same HBM stream the pack already pays for)."""
    bins, outlier = _abs_quantize_block(x_ref[...], eb_ref[0, 0],
                                        maxbin=maxbin, tighten=tighten,
                                        eb_floor=eb_floor)
    words = _pack_block(bins.astype(jnp.uint32) & _narrow_mask(bin_bits),
                        32 // bin_bits, bin_bits)
    words_ref[...] = words
    out_ref[...] = outlier
    sel, codes = _chunk_select_block(words, stage)
    sel_ref[...] = sel
    codes_ref[...] = codes


def _rel_pack_lc_kernel(x_ref, words_ref, out_ref, sign_words_ref, sel_ref,
                        codes_ref, *, maxbin, tighten, eb, log_step,
                        inv_log_step, screen, tiny, mb, emask, bias,
                        bin_bits, stage):
    bins, outlier, neg = _rel_quantize_block(
        x_ref[...], maxbin=maxbin, tighten=tighten, eb=eb, log_step=log_step,
        inv_log_step=inv_log_step, screen=screen, tiny=tiny, mb=mb,
        emask=emask, bias=bias)
    words = _pack_block(bins.astype(jnp.uint32) & _narrow_mask(bin_bits),
                        32 // bin_bits, bin_bits)
    words_ref[...] = words
    out_ref[...] = outlier
    sign_words_ref[...] = _pack_block(neg.astype(jnp.uint32), 32, 1)
    sel, codes = _chunk_select_block(words, stage)
    sel_ref[...] = sel
    codes_ref[...] = codes


# -------------------------------------------------------------- wrappers --

def _check_wrows(wrows):
    assert wrows % CHUNK_ROWS == 0, \
        f"word rows per block must cover whole chunks, got {wrows}"


def chunk_select_pallas(words2d, stage, *, wrows=DEFAULT_ROWS,
                        interpret=True):
    """words2d: uint32[W_total, 128], W_total % wrows == 0.  Returns
    (sel [W_total, 128], codes [W_total/CHUNK_ROWS, 128])."""
    w_total, lanes = words2d.shape
    _check_wrows(wrows)
    assert lanes == LANES and w_total % wrows == 0
    return pl.pallas_call(
        functools.partial(_lc_select_kernel, stage=stage),
        grid=(w_total // wrows,),
        in_specs=[pl.BlockSpec((wrows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((wrows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((wrows // CHUNK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w_total, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((w_total // CHUNK_ROWS, LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(words2d)


def chunk_expand_pallas(padded2d, codes2d, *, wrows=DEFAULT_ROWS,
                        interpret=True):
    w_total, lanes = padded2d.shape
    _check_wrows(wrows)
    assert lanes == LANES and w_total % wrows == 0
    return pl.pallas_call(
        _lc_expand_kernel,
        grid=(w_total // wrows,),
        in_specs=[
            pl.BlockSpec((wrows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((wrows // CHUNK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((wrows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w_total, LANES), jnp.uint32),
        interpret=interpret,
    )(padded2d, codes2d)


def _finish_encode(sel2d, codes2d, n_words):
    """Shared tail: truncate the kernel's (block-padded) chunk stream to
    the reference chunk count, then run the SAME compaction as the
    reference — pad chunks beyond n_words are all-zero by the zero-pad
    invariant, so truncation is exact."""
    n_chunks = C.lc_chunk_count(n_words)
    codes = codes2d.reshape(-1, LANES)[:n_chunks, 0].astype(jnp.int32)
    sel = sel2d.reshape(-1)[:n_chunks * C.LC_CHUNK].reshape(
        n_chunks, C.LC_CHUNK)
    payload, plen = C.lc_compact_payload(sel, codes)
    return C.pack_words(codes, 2), payload, plen


# ------------------------------------------------------ jit'd public API --

@functools.partial(jax.jit, static_argnames=("stage", "wrows", "interpret"))
def encode_words_lc(words, stage="narrow", *, wrows=DEFAULT_ROWS,
                    interpret=None):
    """Pallas twin of core.codec.encode_words_lc (bit-exact): lossless-code
    an existing packed word stream."""
    interpret = _use_interpret() if interpret is None else interpret
    n_words = words.shape[0]
    w2d = _tile_words(words, wrows)
    sel2d, codes2d = chunk_select_pallas(w2d, stage, wrows=wrows,
                                         interpret=interpret)
    return _finish_encode(sel2d, codes2d, n_words)


@functools.partial(jax.jit,
                   static_argnames=("n_words", "wrows", "interpret"))
def decode_words_lc(header_words, payload, n_words, *, wrows=DEFAULT_ROWS,
                    interpret=None):
    """Pallas twin of core.codec.decode_words_lc (bit-exact)."""
    interpret = _use_interpret() if interpret is None else interpret
    n_chunks = C.lc_chunk_count(n_words)
    codes = C.unpack_words(header_words, n_chunks, 2,
                           signed=False).astype(jnp.int32)
    padded = C.lc_gather_chunks(payload, codes)            # XLA gather
    p2d = _tile_words(padded.reshape(-1), wrows)
    blocks = p2d.shape[0] // wrows
    c_need = blocks * (wrows // CHUNK_ROWS)
    cpad = jnp.pad(codes.astype(jnp.uint32), (0, c_need - n_chunks))
    c2d = jnp.broadcast_to(cpad[:, None], (c_need, LANES))
    words2d = chunk_expand_pallas(p2d, c2d, wrows=wrows, interpret=interpret)
    return words2d.reshape(-1)[:n_words]


def encode_lossless(enc: C.EncodedPacked, stage: str = "narrow", *,
                    wrows=DEFAULT_ROWS, interpret=None) -> C.EncodedLC:
    """Pallas twin of core.codec.encode_lossless for an EncodedPacked."""
    hw, payload, plen = encode_words_lc(enc.words, stage, wrows=wrows,
                                        interpret=interpret)
    return C.EncodedLC(hw, payload, plen, enc.out_idx, enc.out_payload,
                       enc.n_outliers, enc.overflow, enc.sign_words, enc.eb)


def decode_lossless(lc: C.EncodedLC, n_words: int, *, wrows=DEFAULT_ROWS,
                    interpret=None) -> C.EncodedPacked:
    words = decode_words_lc(lc.header_words, lc.payload, n_words,
                            wrows=wrows, interpret=interpret)
    return C.EncodedPacked(words, lc.out_idx, lc.out_payload, lc.n_outliers,
                           lc.overflow, lc.sign_words, lc.eb)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "stage", "rows", "interpret"))
def encode_packed_lc(x, cfg: QuantizerConfig, eb=None, stage="narrow", *,
                     rows=DEFAULT_ROWS, interpret=None) -> C.EncodedLC:
    """FUSED quantize + pack + lossless: one HBM pass over x emits packed
    words, the outlier mask, AND the narrowed chunk image + header codes.
    Bit-exact twin of core.codec.encode_lossless(encode_packed(x))."""
    import numpy as np

    interpret = _use_interpret() if interpret is None else interpret
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = cfg.outlier_cap(n)
    vpw = 32 // cfg.bin_bits
    assert rows % 32 == 0 and (rows // vpw) % CHUNK_ROWS == 0, rows
    if cfg.mode == "noa":
        finite = jnp.isfinite(flat)
        big = jnp.asarray(np.finfo(flat.dtype).max, flat.dtype)
        hi = jnp.max(jnp.where(finite, flat, -big))
        lo = jnp.min(jnp.where(finite, flat, big))
        eb = jnp.asarray(cfg.error_bound, flat.dtype) * (hi - lo)

    block = rows * LANES
    pad = (-n) % block
    x2d = jnp.pad(flat, (0, pad)).reshape(-1, LANES)
    r_total = x2d.shape[0]
    grid = (r_total // rows,)
    sign_words = None
    if cfg.mode == "rel":
        eb_, log_step, inv_log_step = cfg.rel_constants()
        mb, emask, bias = ((23, 0xFF, 127) if x2d.dtype == jnp.float32
                           else (52, 0x7FF, 1023))
        body = functools.partial(
            _rel_pack_lc_kernel, maxbin=cfg.maxbin, tighten=cfg.tighten,
            eb=float(eb_), log_step=float(log_step),
            inv_log_step=float(inv_log_step),
            screen=float(cfg.rel_screen_threshold()),
            tiny=float(np.finfo(x2d.dtype).tiny), mb=mb, emask=emask,
            bias=bias, bin_bits=cfg.bin_bits, stage=stage)
        words2d, out2d, sw2d, sel2d, codes2d = pl.pallas_call(
            body,
            grid=grid,
            in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
            out_specs=[
                pl.BlockSpec((rows // vpw, LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows // 32, LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows // vpw, LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows // vpw // CHUNK_ROWS, LANES),
                             lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((r_total // vpw, LANES), jnp.uint32),
                jax.ShapeDtypeStruct((r_total, LANES), jnp.bool_),
                jax.ShapeDtypeStruct((r_total // 32, LANES), jnp.uint32),
                jax.ShapeDtypeStruct((r_total // vpw, LANES), jnp.uint32),
                jax.ShapeDtypeStruct((r_total // vpw // CHUNK_ROWS, LANES),
                                     jnp.uint32),
            ],
            interpret=interpret,
        )(x2d)
        sign_words = sw2d.reshape(-1)[:C.packed_word_count(n, 1)]
    else:
        eb_arr = jnp.full((1, 1), cfg.error_bound if eb is None else eb,
                          x2d.dtype)
        body = functools.partial(_abs_pack_lc_kernel, maxbin=cfg.maxbin,
                                 tighten=cfg.tighten, eb_floor=cfg.eb_floor,
                                 bin_bits=cfg.bin_bits, stage=stage)
        words2d, out2d, sel2d, codes2d = pl.pallas_call(
            body,
            grid=grid,
            in_specs=[
                pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                pl.BlockSpec((1, 1), lambda i: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((rows // vpw, LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows // vpw, LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows // vpw // CHUNK_ROWS, LANES),
                             lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((r_total // vpw, LANES), jnp.uint32),
                jax.ShapeDtypeStruct((r_total, LANES), jnp.bool_),
                jax.ShapeDtypeStruct((r_total // vpw, LANES), jnp.uint32),
                jax.ShapeDtypeStruct((r_total // vpw // CHUNK_ROWS, LANES),
                                     jnp.uint32),
            ],
            interpret=interpret,
        )(x2d, eb_arr)

    n_words = C.packed_word_count(n, cfg.bin_bits)
    outlier = out2d.reshape(-1)[:n]
    n_out = jnp.sum(outlier).astype(jnp.int32)
    (idx,) = jnp.nonzero(outlier, size=k, fill_value=n)
    safe_idx = jnp.minimum(idx, n - 1)
    payload_out = jnp.where(idx < n, float_to_bits(flat)[safe_idx], 0)
    hw, payload, plen = _finish_encode(sel2d, codes2d, n_words)
    return C.EncodedLC(hw, payload, plen, idx.astype(jnp.int32),
                       payload_out.astype(jnp.uint32), n_out, n_out > k,
                       sign_words,
                       None if eb is None else jnp.asarray(eb, flat.dtype))
