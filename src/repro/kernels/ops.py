"""jit'd public wrappers around the Pallas kernels.

Handles layout (flatten -> pad -> [rows,128] tiles -> unpad), backend
selection (interpret=True off-TPU so the same code validates on CPU), and
dtype plumbing.  API mirrors core.quantizer so callers can switch between
the pure-jnp path and the kernel path with one flag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig
from repro.core.bitops import float_to_bits
from repro.core.quantizer import Quantized

from . import dequantize as _dq
from . import quantize_abs as _qa
from . import quantize_rel as _qr

LANES = _qa.LANES


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tile(x: jnp.ndarray, rows: int, pad_value=1.0):
    """Flatten + pad to a [R_total, 128] tile grid; returns (tiled, n).

    Default pad 1.0 quantizes cleanly for any eb; padding is stripped after
    the call either way."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = rows * LANES
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad), constant_values=pad_value)
    return flat.reshape(-1, LANES), n


def _untile(y2d: jnp.ndarray, n: int, shape):
    return y2d.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("cfg", "rows", "interpret"))
def quantize_abs(x, cfg: QuantizerConfig, eb=None, *, rows=_qa.DEFAULT_ROWS,
                 interpret=None) -> Quantized:
    interpret = _use_interpret() if interpret is None else interpret
    x2d, n = _tile(x, rows)
    eb_arr = jnp.full((1, 1), cfg.error_bound if eb is None else eb, x2d.dtype)
    bins, outlier, recon = _qa.quantize_abs_pallas(
        x2d, eb_arr, maxbin=cfg.maxbin, tighten=cfg.tighten,
        eb_floor=cfg.eb_floor, rows=rows, interpret=interpret)
    return Quantized(_untile(bins, n, x.shape), _untile(outlier, n, x.shape),
                     _untile(recon, n, x.shape))


@functools.partial(jax.jit, static_argnames=("cfg", "rows", "interpret"))
def quantize_rel(x, cfg: QuantizerConfig, *, rows=_qa.DEFAULT_ROWS,
                 interpret=None) -> Quantized:
    interpret = _use_interpret() if interpret is None else interpret
    x2d, n = _tile(x, rows)
    bins, outlier, recon, sign = _qr.quantize_rel_pallas(
        x2d, cfg=cfg, rows=rows, interpret=interpret)
    return Quantized(_untile(bins, n, x.shape), _untile(outlier, n, x.shape),
                     _untile(recon, n, x.shape), _untile(sign, n, x.shape))


@functools.partial(jax.jit, static_argnames=("cfg", "rows", "interpret"))
def dequantize_abs(bins, payload_bits, outlier, cfg: QuantizerConfig,
                   eb=None, *, rows=_qa.DEFAULT_ROWS, interpret=None):
    interpret = _use_interpret() if interpret is None else interpret
    dt = jnp.dtype(cfg.dtype)
    shape = bins.shape
    b2d, n = _tile(bins.astype(jnp.int32), rows, pad_value=0)
    p2d, _ = _tile(payload_bits.astype(jnp.int32), rows, pad_value=0)
    o2d, _ = _tile(outlier, rows, pad_value=False)
    eb_arr = jnp.full((1, 1), cfg.error_bound if eb is None else eb, dt)
    y2d = _dq.dequantize_abs_pallas(b2d, p2d, o2d, eb_arr, dtype=dt,
                                    eb_floor=cfg.eb_floor, rows=rows,
                                    interpret=interpret)
    return _untile(y2d, n, shape)
