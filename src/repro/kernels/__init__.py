"""Pallas TPU kernels for the codec hot paths (validated in interpret mode
on CPU; see EXAMPLE.md-style layout: <name>.py kernel, ops.py wrappers,
ref.py oracles)."""
