"""Pallas TPU kernel: flash-decode attention over an int8-quantized KV cache
with inline guaranteed-error-bound outlier corrections.

This is the paper's technique fused into the serving hot loop: the cache
stays compressed in HBM (int8 bins + per-page pow2 scale + exact-outlier
side table), and ONE kernel streams it page by page, dequantizing in VMEM
and applying outlier corrections before the MXU dot — the attention never
sees a value outside the guaranteed bound.

TPU adaptation (DESIGN.md §3): a GPU codec would scatter outlier fixes into
shared memory; TPUs have no efficient scatter, so corrections are applied
as DENSE ONE-HOT EINSUMS — `corr = onehot_t(idx)ᵀ @ (val ⊙ onehot_d(idx))`,
[P,cap] @ [cap,D] on the MXU.  Because the encoder zeroes outlier bins, the
correction is a pure add of the exact value (bit-exact restore).

Memory/roofline: per (b, g, page) step the kernel reads P*D int8 (K) + P*D
int8 (V) + 2*cap*8 B sides vs P*D*2*2 B for a bf16 cache — 4x less HBM
traffic for the bandwidth-bound decode attention.  Arithmetic per step:
2*Hg*P*D (scores) + 2*Hg*P*D (acc) + 2*2*cap*P*D (corrections) MACs; at
cap=8 corrections are ~2x the attention dots for Hg=8 — still far below
the bandwidth roofline (decode attention AI ~ Hg flops/byte << ridge).

Layout: grid (B, G, S/P); flash accumulation in VMEM scratch across the
innermost (page) grid axis.  Blocks: K/V page [P=128, D=128] int8 (16 KiB),
q [Hg<=16, 128], acc f32 [Hg, 128] — comfortably < 1 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1e30


def _kernel(len_ref, q_ref, kb_ref, keb_ref, ki_ref, kv_ref_,
            vb_ref, veb_ref, vi_ref, vv_ref, o_ref,
            acc_ref, m_ref, l_ref, *, page, softmax_scale, cap):
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                   # [Hg, D]
    hg, d = q.shape

    def dequant_corrected(bins_ref, eb_ref, idx_ref, val_ref):
        x = bins_ref[0, 0].astype(jnp.float32) * eb_ref[0, 0, 0]  # exact mul
        idx = idx_ref[0, 0, 0]                        # [cap], -1 = empty
        val = val_ref[0, 0, 0]                        # [cap] exact values
        t = idx // d
        dd = jnp.where(idx >= 0, idx % d, -1)
        # dense one-hot correction: encoder zeroed outlier bins, so adding
        # the exact value restores it bit-for-bit
        oh_t = (jax.lax.broadcasted_iota(jnp.int32, (cap, page), 1)
                == t[:, None]).astype(jnp.float32)
        oh_d = (jax.lax.broadcasted_iota(jnp.int32, (cap, d), 1)
                == dd[:, None]).astype(jnp.float32)
        corr = jnp.dot(oh_t.T, val[:, None] * oh_d,
                       preferred_element_type=jnp.float32)
        return x + corr                               # [P, D]

    k = dequant_corrected(kb_ref, keb_ref, ki_ref, kv_ref_)
    v = dequant_corrected(vb_ref, veb_ref, vi_ref, vv_ref)

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    scores = scores * jnp.float32(softmax_scale)      # [Hg, P]
    t0 = p * page
    valid = (t0 + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
             < len_ref[0])
    scores = jnp.where(valid, scores, NEG_BIG)

    m_prev = m_ref[...]                               # [Hg, 1]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(scores - m_new)                    # [Hg, P]
    l_ref[...] = l_ref[...] * alpha + pexp.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pexp, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def kv_decode_attention(q, kq, vq, lengths, *, page=128, cap=8,
                        interpret=True):
    """q: [B, G, Hg, D]; kq/vq: compression.kv.QuantizedKV with
    bins [B, G, S, D]; lengths: int32 [B].  Returns [B, G, Hg, D]."""
    b, g, hg, d = q.shape
    s = kq.bins.shape[2]
    assert s % page == 0
    n_pages = s // page
    scale = 1.0 / (d ** 0.5)

    grid = (b, g, n_pages)
    body = functools.partial(_kernel, page=page, softmax_scale=scale, cap=cap)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, p: (i,)),                 # lengths
            pl.BlockSpec((1, 1, hg, d), lambda i, j, p: (i, j, 0, 0)),  # q
            pl.BlockSpec((1, 1, page, d), lambda i, j, p: (i, j, p, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j, p: (i, j, p)),       # k eb2
            pl.BlockSpec((1, 1, 1, cap), lambda i, j, p: (i, j, p, 0)),
            pl.BlockSpec((1, 1, 1, cap), lambda i, j, p: (i, j, p, 0)),
            pl.BlockSpec((1, 1, page, d), lambda i, j, p: (i, j, p, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j, p: (i, j, p)),       # v eb2
            pl.BlockSpec((1, 1, 1, cap), lambda i, j, p: (i, j, p, 0)),
            pl.BlockSpec((1, 1, 1, cap), lambda i, j, p: (i, j, p, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hg, d), lambda i, j, p: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, hg, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hg, d), jnp.float32),    # acc
            pltpu.VMEM((hg, 1), jnp.float32),    # running max m
            pltpu.VMEM((hg, 1), jnp.float32),    # running denom l
        ],
        interpret=interpret,
    )(lengths, q, kq.bins, kq.eb2, kq.out_idx, kq.out_val,
      vq.bins, vq.eb2, vq.out_idx, vq.out_val)
