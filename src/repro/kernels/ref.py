"""Pure-jnp oracles for every Pallas kernel in this package.

The quantizer oracles ARE the core library functions (single source of
truth for the guarantee); the attention oracle is a direct softmax over the
dequantized + outlier-corrected cache.  Kernel tests assert bit-equality
(quantizers) or allclose (attention accumulation order differs) against
these on shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig
from repro.core import quantizer as q


def quantize_abs_ref(x, cfg: QuantizerConfig, eb=None):
    qt = q.quantize_abs(x, cfg, eb=eb)
    return qt.bins, qt.outlier, qt.recon


def quantize_rel_ref(x, cfg: QuantizerConfig):
    qt = q.quantize_rel(x, cfg)
    return qt.bins, qt.outlier, qt.recon, qt.sign


def dequantize_abs_ref(bins, payload_bits, outlier, cfg: QuantizerConfig,
                       eb=None, dtype=jnp.float32):
    recon = q.dequantize_abs(bins, cfg, eb=eb, dtype=dtype)
    from repro.core.bitops import bits_to_float
    return jnp.where(outlier, bits_to_float(payload_bits, dtype), recon)


def kv_decode_attention_ref(q, kq, vq, lengths, *, page=128):
    """Decode attention over a quantized KV cache — plain softmax over the
    fully dequantized cache (compression.kv.dequantize_kv), one batch/head
    at a time.  q: [B, G, Hg, D]; kq/vq: QuantizedKV; lengths: [B]."""
    from repro.compression.kv import dequantize_kv

    b, g, hg, d = q.shape
    s = kq.bins.shape[2]
    k = dequantize_kv(kq, page=page)                    # [B, G, S, D]
    v = dequantize_kv(vq, page=page)
    scores = jnp.einsum("bghd,bgsd->bghs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    mask = jnp.arange(s)[None, :] < lengths[:, None]    # [B, S]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p_att = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bghs,bgsd->bghd", p_att,
                      v.astype(jnp.float32)).astype(q.dtype)
