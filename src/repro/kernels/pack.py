"""Pallas TPU kernels: fused quantize + bit-pack (and unpack + dequantize).

The paper's LC pipeline wins on throughput because quantize -> pack ->
lossless runs GPU-resident; the seed quantize kernel wrote full-width
int32 bins plus bool-outlier and f32-recon planes to HBM (9 B/element)
and narrowing to bin_bits was a separate XLA pass — another full HBM
round trip.  These kernels close that gap on TPU: ONE HBM pass reads x
and writes bin_bits-wide bins already packed into uint32 lanes (plus the
outlier mask used to build the capped exact table) — the same fusion
FZ-GPU (arXiv 2304.12557) and cuSZ (arXiv 2007.09625) use on GPU,
adapted to the VPU:

  * packing is a SUBLANE shift/or: a (rows, 128) bin block is viewed as
    (rows/vpw, vpw, 128) and reduced over the middle axis, so no lane
    crossings are needed (lane shuffles are the expensive op on TPU).
  * the layout is block-height invariant (any rows % vpw == 0), so kernel
    words are bit-identical to the jit-safe reference in core.codec
    (pack_words) — which is the oracle the tests pin these kernels to.
  * quantize math is the bit-exact twin of core.quantizer (same as
    kernels/quantize_abs.py / quantize_rel.py); the pack rides for free
    under the same HBM stream (still ~1 flop/byte, memory-bound).

HBM accounting at bin_bits=8: fused output is words + bool = 2 B/element
vs the seed pipeline's 9 B/element kernel output, and no recon plane or
full-width bins are ever materialized (outliers ride the capped
(idx, payload) table; the REL sign plane packs at 1 bit/value vs a
byte-wide bool).

The device-side lossless stage (DESIGN.md §6) rides this same HBM pass:
kernels/lossless.py reuses _abs/_rel_quantize_block and _pack_block below
to fuse quantize + pack + per-chunk zero-detection/width-narrowing into
one kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import QuantizerConfig
from repro.core import codec as C
from repro.core.bitops import float_to_bits
from repro.core.quantizer import Quantized

from .quantize_abs import DEFAULT_ROWS, LANES
from .quantize_rel import _log2approx, _pow2approx

assert LANES == C.PACK_LANES, "kernel tile width must match the wire layout"


# ------------------------------------------------------------- in-kernel --

def _pack_block(u32, vpw, bin_bits):
    """(rows, 128) uint32 -> (rows/vpw, 128) packed words (sublane or)."""
    if vpw == 1:
        return u32
    grp = u32.reshape(-1, vpw, LANES)
    word = grp[:, 0, :]
    for i in range(1, vpw):
        word = word | (grp[:, i, :] << jnp.uint32(i * bin_bits))
    return word


def _unpack_block(words, vpw, bin_bits, signed=True):
    """(rows/vpw, 128) words -> (rows, 128) int32 (sign-extended bins)."""
    if vpw == 1:
        return words.astype(jnp.int32) if signed else words
    mask = jnp.uint32((1 << bin_bits) - 1)
    cols = [(words >> jnp.uint32(i * bin_bits)) & mask for i in range(vpw)]
    flat = jnp.stack(cols, axis=1).reshape(-1, LANES)
    if not signed:
        return flat
    sh = jnp.int32(32 - bin_bits)
    return (flat.astype(jnp.int32) << sh) >> sh


def _narrow_mask(bin_bits):
    return jnp.uint32((1 << bin_bits) - 1) if bin_bits != 32 else jnp.uint32(
        0xFFFFFFFF)


# ---------------------------------------------------- fused quantize+pack --

def _abs_quantize_block(x, eb_in, *, maxbin, tighten, eb_floor):
    """In-kernel ABS quantize math (bit-exact twin of core.quantizer).
    Returns (bins int32 with outliers zeroed, outlier bool).  Shared by the
    pack kernels here and the fused lossless kernels (kernels/lossless.py)."""
    dt = x.dtype
    degenerate = ~(eb_in >= eb_floor)            # FTZ guard (see core.config)
    eb = jnp.maximum(eb_in, eb_floor)
    mant_mask = (1 << 23) - 1 if dt == jnp.float32 else (1 << 52) - 1
    int_t = jnp.int32 if dt == jnp.float32 else jnp.int64
    eb2 = lax.bitcast_convert_type(
        lax.bitcast_convert_type(jnp.asarray(2.0, dt) * eb, int_t) & ~mant_mask,
        dt)                                      # pow2 step -> FMA-immune
    inv_eb2 = jnp.asarray(1.0, dt) / eb2

    finite = jnp.isfinite(x)
    xs = jnp.where(finite, x, jnp.zeros((), dt))
    bin_f = jnp.rint(xs * inv_eb2)
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)   # paper §3.3 form

    recon = bin_i.astype(dt) * eb2               # exact (pow2 step)
    fails = ~(jnp.abs(x - recon) <= eb * jnp.asarray(tighten, dt))
    fails |= ~jnp.isfinite(recon)    # recon-overflow guard (see quantizer.py)
    outlier = (~finite) | range_bad | range_bad_i | fails | degenerate
    return jnp.where(outlier, 0, bin_i), outlier


def _rel_quantize_block(x, *, maxbin, tighten, eb, log_step, inv_log_step,
                        screen, tiny, mb, emask, bias):
    """In-kernel REL quantize math.  Returns (bins, outlier, neg)."""
    dt = x.dtype
    int_t = jnp.int32 if dt == jnp.float32 else jnp.int64

    finite = jnp.isfinite(x)
    ax = jnp.abs(x)
    too_small = ~(ax >= jnp.asarray(screen, dt))           # FTZ screen
    safe = jnp.where(finite & ~too_small, ax, jnp.ones((), dt))
    lg = _log2approx(safe, mb, emask, bias)
    bin_f = jnp.rint(lg * jnp.asarray(inv_log_step, dt))
    range_bad = jnp.abs(bin_f) >= jnp.asarray(float(maxbin), dt)
    bin_i = jnp.where(range_bad, jnp.zeros_like(bin_f), bin_f).astype(jnp.int32)
    range_bad_i = (bin_i >= maxbin) | (bin_i <= -maxbin)   # paper §3.3 form

    neg = lax.bitcast_convert_type(x, int_t) < 0           # bit-pattern sign
    mag = _pow2approx(bin_i.astype(dt) * jnp.asarray(log_step, dt), mb, bias)
    recon = jnp.where(neg, -mag, mag)
    ebT = jnp.asarray(dt.type(eb) * dt.type(tighten), dt)
    ok = (jnp.abs(x - recon) <= ebT * ax) & jnp.isfinite(recon)
    ok &= mag >= jnp.asarray(tiny, dt)
    outlier = (~finite) | too_small | range_bad | range_bad_i | ~ok
    return jnp.where(outlier, 0, bin_i), outlier, neg


def _abs_pack_kernel(x_ref, eb_ref, words_ref, out_ref, *, maxbin, tighten,
                     eb_floor, bin_bits):
    bins, outlier = _abs_quantize_block(x_ref[...], eb_ref[0, 0],
                                        maxbin=maxbin, tighten=tighten,
                                        eb_floor=eb_floor)
    words_ref[...] = _pack_block(
        bins.astype(jnp.uint32) & _narrow_mask(bin_bits),
        32 // bin_bits, bin_bits)
    out_ref[...] = outlier


def _rel_pack_kernel(x_ref, words_ref, out_ref, sign_words_ref, *, maxbin,
                     tighten, eb, log_step, inv_log_step, screen, tiny, mb,
                     emask, bias, bin_bits):
    bins, outlier, neg = _rel_quantize_block(
        x_ref[...], maxbin=maxbin, tighten=tighten, eb=eb, log_step=log_step,
        inv_log_step=inv_log_step, screen=screen, tiny=tiny, mb=mb,
        emask=emask, bias=bias)
    words_ref[...] = _pack_block(
        bins.astype(jnp.uint32) & _narrow_mask(bin_bits),
        32 // bin_bits, bin_bits)
    out_ref[...] = outlier
    sign_words_ref[...] = _pack_block(neg.astype(jnp.uint32), 32, 1)


# -------------------------------------------------- fused unpack+dequant --

def _abs_unpack_kernel(words_ref, eb_ref, y_ref, *, eb_floor, bin_bits):
    dt = y_ref.dtype
    eb = jnp.maximum(eb_ref[0, 0], jnp.asarray(eb_floor, dt))
    mant_mask = (1 << 23) - 1 if dt == jnp.float32 else (1 << 52) - 1
    int_t = jnp.int32 if dt == jnp.float32 else jnp.int64
    eb2 = lax.bitcast_convert_type(
        lax.bitcast_convert_type(jnp.asarray(2.0, dt) * eb, int_t) & ~mant_mask,
        dt)                                      # pow2 step, matches encoder
    bins = _unpack_block(words_ref[...], 32 // bin_bits, bin_bits)
    y_ref[...] = bins.astype(dt) * eb2           # exact


def _rel_unpack_kernel(words_ref, sign_words_ref, y_ref, *, log_step, mb,
                       bias, bin_bits):
    dt = y_ref.dtype
    bins = _unpack_block(words_ref[...], 32 // bin_bits, bin_bits)
    sign = _unpack_block(sign_words_ref[...], 32, 1, signed=False) != 0
    mag = _pow2approx(bins.astype(dt) * jnp.asarray(log_step, dt), mb, bias)
    y_ref[...] = jnp.where(sign, -mag, mag)


# -------------------------------------------------------------- wrappers --

def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _check_rows(rows):
    # the sign plane packs 32 rows/word, so rows must cover whole words for
    # every output plane
    assert rows % 32 == 0, f"rows must be a multiple of 32, got {rows}"


def quantize_pack_abs_pallas(x2d, eb, *, maxbin, tighten, eb_floor, bin_bits,
                             rows=DEFAULT_ROWS, interpret=True):
    """x2d: [R_total, 128], R_total % rows == 0.  eb: [1, 1].
    Returns (words [R_total/vpw, 128] uint32, outlier [R_total, 128])."""
    r_total, lanes = x2d.shape
    _check_rows(rows)
    assert lanes == LANES and r_total % rows == 0
    vpw = 32 // bin_bits
    grid = (r_total // rows,)
    body = functools.partial(_abs_pack_kernel, maxbin=maxbin, tighten=tighten,
                             eb_floor=eb_floor, bin_bits=bin_bits)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),      # eb broadcast
        ],
        out_specs=[
            pl.BlockSpec((rows // vpw, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r_total // vpw, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((r_total, LANES), jnp.bool_),
        ],
        interpret=interpret,
    )(x2d, eb)


def quantize_pack_rel_pallas(x2d, *, cfg, rows=DEFAULT_ROWS, interpret=True):
    """Returns (words [R/vpw, 128], outlier [R, 128], sign_words [R/32, 128])."""
    import numpy as np

    r_total, lanes = x2d.shape
    _check_rows(rows)
    assert lanes == LANES and r_total % rows == 0
    dt = x2d.dtype
    vpw = 32 // cfg.bin_bits
    eb_, log_step, inv_log_step = cfg.rel_constants()
    mb, emask, bias = (23, 0xFF, 127) if dt == jnp.float32 else (52, 0x7FF, 1023)
    body = functools.partial(
        _rel_pack_kernel, maxbin=cfg.maxbin, tighten=cfg.tighten, eb=float(eb_),
        log_step=float(log_step), inv_log_step=float(inv_log_step),
        screen=float(cfg.rel_screen_threshold()), tiny=float(np.finfo(dt).tiny),
        mb=mb, emask=emask, bias=bias, bin_bits=cfg.bin_bits)
    return pl.pallas_call(
        body,
        grid=(r_total // rows,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows // vpw, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows // 32, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r_total // vpw, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((r_total, LANES), jnp.bool_),
            jax.ShapeDtypeStruct((r_total // 32, LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(x2d)


def unpack_dequant_abs_pallas(words2d, eb, *, dtype, eb_floor, bin_bits,
                              rows=DEFAULT_ROWS, interpret=True):
    """words2d: [W_total, 128] with W_total % (rows/vpw) == 0.
    Returns recon [W_total*vpw, 128] (outliers NOT restored — the caller
    scatters the capped exact table afterwards)."""
    w_total, lanes = words2d.shape
    _check_rows(rows)
    vpw = 32 // bin_bits
    wrows = rows // vpw
    assert lanes == LANES and w_total % wrows == 0
    return pl.pallas_call(
        functools.partial(_abs_unpack_kernel, eb_floor=eb_floor,
                          bin_bits=bin_bits),
        grid=(w_total // wrows,),
        in_specs=[
            pl.BlockSpec((wrows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w_total * vpw, LANES), dtype),
        interpret=interpret,
    )(words2d, eb)


def unpack_dequant_rel_pallas(words2d, sign_words2d, *, cfg, dtype,
                              rows=DEFAULT_ROWS, interpret=True):
    w_total, lanes = words2d.shape
    _check_rows(rows)
    vpw = 32 // cfg.bin_bits
    wrows = rows // vpw
    assert lanes == LANES and w_total % wrows == 0
    _, log_step, _ = cfg.rel_constants()
    mb, bias = (23, 127) if jnp.dtype(dtype) == jnp.float32 else (52, 1023)
    return pl.pallas_call(
        functools.partial(_rel_unpack_kernel, log_step=float(log_step),
                          mb=mb, bias=bias, bin_bits=cfg.bin_bits),
        grid=(w_total // wrows,),
        in_specs=[
            pl.BlockSpec((wrows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows // 32, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w_total * vpw, LANES), dtype),
        interpret=interpret,
    )(words2d, sign_words2d)


# ------------------------------------------------------ jit'd public API --

def _tile_zero(x, rows):
    """Flatten + zero-pad to [R_total, 128].  Zero pad (not ops._tile's 1.0)
    so pad bins/signs are 0 for both ABS and REL — bit-matching the
    reference, which packs zero-padded bin streams."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = rows * LANES
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


@functools.partial(jax.jit, static_argnames=("cfg", "rows", "interpret"))
def encode_packed(x, cfg: QuantizerConfig, eb=None, *, rows=DEFAULT_ROWS,
                  interpret=None) -> C.EncodedPacked:
    """Fused-kernel twin of core.codec.encode_packed (bit-exact)."""
    interpret = _use_interpret() if interpret is None else interpret
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = cfg.outlier_cap(n)
    if cfg.mode == "noa":
        # NOA = ABS with eb from the global value range (needs the full
        # tensor -> computed here, quantized by the ABS kernel)
        finite = jnp.isfinite(flat)
        import numpy as np
        big = jnp.asarray(np.finfo(flat.dtype).max, flat.dtype)
        hi = jnp.max(jnp.where(finite, flat, -big))
        lo = jnp.min(jnp.where(finite, flat, big))
        eb = jnp.asarray(cfg.error_bound, flat.dtype) * (hi - lo)

    x2d, _ = _tile_zero(flat, rows)
    sign_words = None
    if cfg.mode == "rel":
        words2d, out2d, sw2d = quantize_pack_rel_pallas(
            x2d, cfg=cfg, rows=rows, interpret=interpret)
        sign_words = sw2d.reshape(-1)[:C.packed_word_count(n, 1)]
    else:
        eb_arr = jnp.full((1, 1), cfg.error_bound if eb is None else eb,
                          x2d.dtype)
        words2d, out2d = quantize_pack_abs_pallas(
            x2d, eb_arr, maxbin=cfg.maxbin, tighten=cfg.tighten,
            eb_floor=cfg.eb_floor, bin_bits=cfg.bin_bits, rows=rows,
            interpret=interpret)
    # pad words beyond the reference tile count are all-zero (zero pad in,
    # zero bins out) — truncate to the canonical wire length
    words = words2d.reshape(-1)[:C.packed_word_count(n, cfg.bin_bits)]
    outlier = out2d.reshape(-1)[:n]

    n_out = jnp.sum(outlier).astype(jnp.int32)
    (idx,) = jnp.nonzero(outlier, size=k, fill_value=n)
    safe_idx = jnp.minimum(idx, n - 1)
    payload = jnp.where(idx < n, float_to_bits(flat)[safe_idx], 0)
    return C.EncodedPacked(words, idx.astype(jnp.int32),
                           payload.astype(jnp.uint32), n_out, n_out > k,
                           sign_words,
                           None if eb is None else jnp.asarray(eb, flat.dtype))


def _tile_words(words, wrows):
    n_w = words.shape[0]
    pad = (-n_w) % (wrows * LANES)
    return jnp.pad(words, (0, pad)).reshape(-1, LANES)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n", "shape", "dtype", "rows",
                                    "interpret"))
def decode_packed(enc: C.EncodedPacked, cfg: QuantizerConfig, n=None,
                  shape=None, dtype=None, *, rows=DEFAULT_ROWS,
                  interpret=None):
    """Fused-kernel twin of core.codec.decode_packed (bit-exact)."""
    import numpy as np
    interpret = _use_interpret() if interpret is None else interpret
    if n is None:
        if shape is None:
            raise ValueError("decode_packed needs n or shape")
        n = int(np.prod(shape))
    dt = jnp.dtype(dtype or cfg.dtype)
    vpw = 32 // cfg.bin_bits
    if cfg.mode == "rel":
        w2d = _tile_words(enc.words, rows // vpw)
        # the sign plane must cover exactly the element rows the bin words
        # cover (both planes' pad bits are zero, so pad/truncate is exact)
        blocks = w2d.shape[0] // (rows // vpw)
        s_need = blocks * (rows // 32) * LANES
        sw = enc.sign_words
        sw = jnp.pad(sw, (0, max(0, s_need - sw.shape[0])))[:s_need]
        y2d = unpack_dequant_rel_pallas(w2d, sw.reshape(-1, LANES), cfg=cfg,
                                        dtype=dt, rows=rows,
                                        interpret=interpret)
    else:
        w2d = _tile_words(enc.words, rows // vpw)
        eb_arr = jnp.full((1, 1),
                          cfg.error_bound if enc.eb is None else enc.eb, dt)
        y2d = unpack_dequant_abs_pallas(w2d, eb_arr, dtype=dt,
                                        eb_floor=cfg.eb_floor,
                                        bin_bits=cfg.bin_bits, rows=rows,
                                        interpret=interpret)
    recon = y2d.reshape(-1)[:n]
    vals = lax.bitcast_convert_type(enc.out_payload.astype(jnp.int32), dt)
    recon = recon.at[enc.out_idx].set(vals, mode="drop")
    return recon.reshape(shape) if shape is not None else recon
