"""Deterministic synthetic token pipeline, host-sharded.

Restart-exactness (fault tolerance): batch(step) is a pure function of
(seed, step, host_shard), so resuming from a checkpoint at step k replays
the identical stream with no iterator state to save.  Each host generates
only its shard of the global batch (scales to any number of input hosts).

The generator mimics natural-text statistics (Zipfian unigram over the
vocab + short-range repetition) so compression/benchmark numbers are not
degenerate, while staying 100% offline.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _zipf_probs(vocab: int, a: float = 1.1) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / r ** a
    return p / p.sum()


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg.vocab)

    def batch(self, step: int) -> dict:
        """{'tokens': [host_batch, S], 'labels': [host_batch, S]} int32."""
        cfg = self.cfg
        # repro: noqa GL006 -- seed is a SeedSequence tuple that is a pure
        # function of (config seed, step, host): deterministic by
        # construction, and restart-exact resume REQUIRES step-keyed
        # seeding rather than a fixed suite name (tests/test_runtime.py)
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id))          # pure function of step
        toks = rng.choice(cfg.vocab, size=(cfg.host_batch, cfg.seq_len + 1),
                          p=self._probs).astype(np.int32)
        # short-range repetition: copy a window forward with prob .3
        w_hi = min(32, max(5, cfg.seq_len // 4))
        for b in range(cfg.host_batch):
            if rng.random() < 0.3:
                w = int(rng.integers(4, w_hi))
                if cfg.seq_len - 2 * w > 0:
                    s = int(rng.integers(0, cfg.seq_len - 2 * w))
                    toks[b, s + w: s + 2 * w] = toks[b, s: s + w]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
