"""repro.data"""
