"""repro.optim"""
