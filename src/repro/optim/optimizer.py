"""AdamW with f32 master copies over (possibly bf16) params, cosine
schedule with warmup, global-norm clipping.  Optimizer state shards like
the params (FSDP over 'data') — ZeRO-style; see launch/mesh.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray      # i32 scalar
    mu: dict               # f32, like params
    nu: dict               # f32, like params
    master: dict           # f32 master copy of params


def init(params, cfg: AdamWConfig) -> OptState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros), f32(params))


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * master
        return mu, nu, master - lr * delta

    flat_g, tree = jax.tree.flatten(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    flat_ma = jax.tree.leaves(state.master)
    new_mu, new_nu, new_ma = [], [], []
    for g, mu, nu, ma in zip(flat_g, flat_mu, flat_nu, flat_ma):
        a, b, c = upd(g, mu, nu, ma)
        new_mu.append(a)
        new_nu.append(b)
        new_ma.append(c)
    unf = lambda l: jax.tree.unflatten(tree, l)
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype),
                              unf(new_ma), params)
    return new_params, OptState(step, unf(new_mu), unf(new_nu),
                                unf(new_ma)), {
        "grad_norm": gnorm, "lr": lr}
