"""Train-step factories + CLI launcher.

Two step variants:
  * make_train_step            — baseline: GSPMD owns every axis; the
    cross-pod gradient reduce is a full-precision all-reduce.
  * make_train_step_compressed — the paper's technique on the wire: a
    partial-manual shard_map owns the 'pod' axis; each pod computes local
    gradients (GSPMD still auto-shards 'data'/'model' INSIDE), then
    compression/grads.py runs the guaranteed-error-bounded compressed
    all-reduce with error feedback.  State gains a pod-stacked residual
    tree (checkpointed — restart-exact).

CLI:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-67b \
      --steps 100 --batch 8 --seq 256 [--reduced] [--compress-grads]
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compression.grads import (GradCompressionConfig,
                                     compressed_mean_tree)
from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import build
from repro.optim import optimizer as opt
from . import mesh as M


def make_train_step(bundle, mesh, opt_cfg: opt.AdamWConfig):
    def step(state, batch):
        params, ostate = state
        (loss, (ce, aux)), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(params, batch, mesh)
        params, ostate, metrics = opt.apply(params, grads, ostate, opt_cfg)
        metrics.update(loss=loss, ce=ce, aux=aux)
        return (params, ostate), metrics

    return step


def make_train_step_compressed(bundle, mesh, opt_cfg: opt.AdamWConfig,
                               gc_cfg: GradCompressionConfig):
    """Pod-manual shard_map: grads stay pod-local until the compressed
    exchange.  moe_data_axes=('data',) because tokens inside are already
    pod-split."""
    assert "pod" in mesh.axis_names

    def pod_local(params, batch, resid):
        # shard_map keeps rank: the pod-sliced residual arrives [1, ...];
        # squeeze it or it broadcasts a phantom leading dim into the grads
        # (and from there into the params — caught by the e2e example)
        resid = jax.tree.map(lambda t: t[0], resid)
        (loss, (ce, aux)), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(params, batch, mesh,
                                       moe_data_axes=("data",))
        grads, resid = compressed_mean_tree(grads, resid, gc_cfg, "pod")
        loss = jax.lax.pmean(loss, "pod")
        return loss, ce, aux, grads, jax.tree.map(lambda t: t[None], resid)

    def specs_like(tree, leading_pod=False):
        return jax.tree.map(
            lambda s: P("pod", *(None,) * (s.ndim - 1)) if leading_pod
            else P(*(None,) * s.ndim), tree)

    def step(state, batch):
        params, ostate, resid = state
        abstract_p = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        abstract_b = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        abstract_r = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), resid)
        mapped = jax.shard_map(
            pod_local, mesh=mesh,
            in_specs=(specs_like(abstract_p),
                      specs_like(abstract_b, leading_pod=True),
                      specs_like(abstract_r, leading_pod=True)),
            out_specs=(P(), P(), P(), specs_like(abstract_p),
                       specs_like(abstract_r, leading_pod=True)),
            axis_names={"pod"}, check_vma=False)
        loss, ce, aux, grads, resid = mapped(params, batch, resid)
        params, ostate, metrics = opt.apply(params, grads, ostate, opt_cfg)
        metrics.update(loss=loss, ce=ce, aux=aux)
        return (params, ostate, resid), metrics

    return step


def init_residuals(params, n_pods: int):
    """Pod-stacked error-feedback buffers (f32, checkpointed)."""
    return jax.tree.map(
        lambda x: jnp.zeros((n_pods,) + x.shape, jnp.float32), params)


# ---------------------------------------------------------------- CLI ----

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt_cfg = opt.AdamWConfig(lr=args.lr, total_steps=args.steps)
    ostate = opt.init(params, opt_cfg)
    pipe = TokenPipeline(DataConfig(cfg.vocab, args.seq, args.batch))
    step = jax.jit(make_train_step(bundle, None, opt_cfg))

    state = (params, ostate)
    for i in range(args.steps):
        b = pipe.batch(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, cfg.enc_context,
                                        cfg.d_model), jnp.bfloat16)
        state, metrics = step(state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
    print("done")


if __name__ == "__main__":
    main()
