"""repro.launch"""
