"""HLO-text analysis for the roofline: collective bytes and dot FLOPs with
while-loop trip multipliers.

XLA's cost_analysis() counts each while-loop BODY ONCE (measured in the
feasibility spike: a 95-layer scan reported ~1/40 of the analytic FLOPs).
This parser fixes that structurally:

  1. split the module into computations;
  2. find every `while` op, read its TRIP COUNT from the integer constant
     in its condition computation (lax.scan lowers to 0..K counters);
  3. propagate multipliers down the (while-body) call graph;
  4. sum collective op bytes and dot FLOPs, each scaled by its
     computation's multiplier.

Byte sizes come from the printed shapes (e.g. `bf16[8,4096,1024]`).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) (?:\([^)]*\) -> .*?)?\{",
                      re.M)

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def split_computations(hlo: str) -> dict:
    """name -> list of op lines."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        if line.endswith("{") and ("(" in line or line.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


def _trip_count(cond_lines) -> int:
    """lax.scan conditions compare the counter against constant(K).  Use
    the constant OPERAND of the compare op (the condition may contain
    unrelated constants which previously inflated trip counts)."""
    const_vals = {}
    for ln in cond_lines:
        m = re.match(r"%?([\w\.\-]+) = [^=]*constant\((\d+)\)", ln)
        if m:
            const_vals[m.group(1)] = int(m.group(2))
    trips = []
    for ln in cond_lines:
        if " compare(" not in ln:
            continue
        for name in re.findall(r"%([\w\.\-]+)", ln.split("compare(", 1)[1]):
            if name in const_vals:
                trips.append(const_vals[name])
    if trips:
        return max(trips)
    return max(const_vals.values()) if const_vals else 1


def fused_computations(comps: dict) -> set:
    """Computations reached via fusion/custom-call `calls=` — their
    internal ops live in VMEM/registers, not HBM."""
    out = set()
    call_re = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
    for lines in comps.values():
        for ln in lines:
            if "fusion(" in ln or "custom-call" in ln or "reduce(" in ln \
                    or "map(" in ln or "sort(" in ln or "scatter(" in ln:
                for m in call_re.finditer(ln):
                    out.add(m.group(1))
    return out


def computation_multipliers(hlo: str) -> dict:
    """name -> how many times the computation executes per step."""
    comps = split_computations(hlo)
    mult = defaultdict(lambda: 0)
    entry = None
    for name in comps:
        if "main" in name or entry is None:
            if entry is None or name.startswith("main"):
                entry = name
    mult[entry] = 1

    # edges: while(body=..., condition=...), call/fusion(to_apply/calls=...)
    edge_re = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
    cond_re = re.compile(r"condition=%?([\w\.\-]+)")

    changed = True
    seen = set()
    while changed:
        changed = False
        for name, lines in comps.items():
            if mult[name] == 0 or name in seen:
                continue
            seen.add(name)
            for ln in lines:
                is_while = " while(" in ln or ln.startswith("while(")
                trip = 1
                if is_while:
                    cm = cond_re.search(ln)
                    if cm and cm.group(1) in comps:
                        trip = _trip_count(comps[cm.group(1)])
                for em in edge_re.finditer(ln):
                    child = em.group(1)
                    if child in comps:
                        new = mult[name] * (trip if is_while else 1)
                        if new > mult[child]:
                            mult[child] = new
                            changed = True
                            seen.discard(child)
    return dict(mult)


def collective_bytes(hlo: str) -> dict:
    """kind -> trip-multiplied operand bytes moved by collectives."""
    comps = split_computations(hlo)
    mult = computation_multipliers(hlo)
    out = defaultdict(int)
    per_op = []
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for ln in lines:
            for kind in COLLECTIVE_KINDS:
                if re.search(rf"= [^=]*{kind}(?:-start|-done)?\(", ln):
                    if f"{kind}-done" in ln:
                        continue          # counted at -start
                    shapes = _SHAPE_RE.findall(ln.split("=", 1)[1]
                                               .split("(")[0])
                    b = 0
                    m2 = re.match(r"\s*%?[\w\.\-]+ = (.*?) " + kind, ln)
                    if m2:
                        for tup in _SHAPE_RE.finditer(m2.group(1)):
                            b += _shape_bytes(tup.group(0))
                    if b == 0:  # fall back: first shape on the line
                        sm = _SHAPE_RE.search(ln)
                        b = _shape_bytes(sm.group(0)) if sm else 0
                    out[kind] += b * m
                    per_op.append((kind, name, b, m))
                    break
    out["__ops"] = per_op
    return dict(out)


def _name_shapes(comps: dict) -> dict:
    """op name -> shape string (operands are referenced by name in HLO)."""
    out = {}
    def_re = re.compile(r"^%?([\w\.\-]+) = (\w+\[[\d,]*\])")
    for lines in comps.values():
        for ln in lines:
            m = def_re.match(ln)
            if m:
                out[m.group(1)] = m.group(2)
    return out


def dot_flops(hlo: str) -> int:
    """Trip-multiplied MAC*2 flops over all dot ops (the compute term's
    dominant component; elementwise flops are <1% for these models)."""
    comps = split_computations(hlo)
    mult = computation_multipliers(hlo)
    shapes = _name_shapes(comps)
    total = 0
    dot_re = re.compile(
        r"^%?([\w\.\-]+) = (\w+\[[\d,]*\])[^=]* dot\(%?([\w\.\-]+)")
    contract_re = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for ln in lines:
            if " dot(" not in ln:
                continue
            dm = dot_re.match(ln)
            if not dm:
                continue
            _, out_s, lhs_name = dm.groups()
            sm = _SHAPE_RE.match(out_s)
            out_elems = 1
            for d in sm.group(2).split(","):
                if d:
                    out_elems *= int(d)
            lhs_s = shapes.get(lhs_name)
            k = 1
            cm = contract_re.search(ln)
            if lhs_s and cm and cm.group(1):
                lm = _SHAPE_RE.match(lhs_s)
                lhs_dims = [int(d) for d in lm.group(2).split(",") if d]
                for ci in cm.group(1).split(","):
                    if ci:
                        k *= lhs_dims[int(ci)]
            total += 2 * out_elems * k * m
    return total
