"""Production mesh + logical-axis sharding rules.

Mesh axes:
  single-pod  (16, 16)      ("data", "model")            = 256 chips
  multi-pod   (2, 16, 16)   ("pod", "data", "model")     = 512 chips

Parallelism mapping (DESIGN.md §2):
  * 'data'  — FSDP/ZeRO-3: weights + optimizer state sharded on their
    'embed' dimension; per-layer all-gather under the scan.
  * 'model' — tensor parallel (attention heads / MLP columns / vocab) and
    expert parallel (MoE 'experts' axis via shard_map all-to-alls).
  * 'pod'   — pure data parallelism across pods; the cross-pod gradient
    all-reduce is where compression/grads.py applies the paper's
    guaranteed-error-bounded quantizer to the slow inter-pod links.

Logical axis -> mesh axis:
  embed -> data (FSDP)   heads/mlp/vocab/experts -> model (TP/EP)
  layers/None -> replicated
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# 'embed' (the FSDP dimension) spans EVERY data axis: on the multi-pod
# mesh params/optimizer shard over pod x data (398B jamba state would
# otherwise replicate 22.6 GiB/device per pod).  Cross-pod weight
# all-gathers are the price; the compressed-DP variant (launch/train.py)
# instead keeps params pod-replicated and compresses gradients.
LOGICAL_RULES = {
    "heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    None: None,
}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def logical_to_spec(axes: tuple, mesh: Mesh, shape=None) -> P:
    dp = data_axes(mesh)
    rules = dict(LOGICAL_RULES)
    rules["embed"] = dp if len(dp) > 1 else dp[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ok(a, dim):
        if a is None or shape is None:
            return True
        n = sizes[a] if isinstance(a, str) else int(
            __import__("numpy").prod([sizes[x] for x in a]))
        return dim % n == 0

    spec = []
    for i, a in enumerate(axes):
        r = rules.get(a, None)
        # drop axes whose size does not divide the dim (whisper's vocab
        # 51865 is odd; small head counts < |model|; etc.) -> replicated
        spec.append(r if ok(r, shape[i] if shape else 0) else None)
    return P(*spec)


def param_shardings(mesh: Mesh, axes_tree, abstract_tree=None):
    if abstract_tree is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, logical_to_spec(ax, mesh)),
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda ax, ab: NamedSharding(
            mesh, logical_to_spec(ax, mesh, ab.shape)),
        axes_tree, abstract_tree, is_leaf=lambda x: isinstance(x, tuple))


def batch_sharding(mesh: Mesh, ndim: int = 2) -> NamedSharding:
    return NamedSharding(mesh, P(data_axes(mesh), *(None,) * (ndim - 1)))


def batch_shardings_for(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, P(data_axes(mesh), *(None,) * (s.ndim - 1))),
        tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def cache_shardings(mesh: Mesh, cache_tree):
    """KV caches & SSM states: shard the batch dim.  Layer-stacked leaves
    have batch at dim 1 ([L, B, ...]); hybrid mamba states at dim 2
    ([P, n_mamba, B, ...]); xlstm states at dim 1.  We find the first dim
    whose size matches none of the known leading structural dims by
    convention: leaves are [L(, n), B, ...] -> batch dim = ndim of leading
    structure.  Simpler and robust: shard dim 1 for >=2D leaves, unless the
    leaf is a hybrid mamba state (ndim >= 4 with dim0=periods, dim1=blocks)
    where dim 2 is batch — handled by the caller passing batch_dim trees.
    Default: dim 1."""
    def spec_for(leaf):
        if leaf.ndim >= 2:
            dp = data_axes(mesh)
            return NamedSharding(
                mesh, P(None, dp, *(None,) * (leaf.ndim - 2)))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec_for, cache_tree)
