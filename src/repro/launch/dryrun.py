import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes (16x16 single-pod = 256 chips, 2x16x16 multi-pod = 512
chips), print memory_analysis / cost_analysis, and persist per-cell JSON
for the roofline (results/dryrun/).

The XLA_FLAGS line above MUST run before any jax import (device count
locks at first init) — which is why this module sets it at line 1-2 and
why smoke tests / benches never import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
      --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --list
Per-cell results are cached; --force recompiles.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, runnable
from repro.models import build
from repro.optim import optimizer as opt
from . import hlo_analysis, mesh as M

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results",
                           "dryrun")

# Gradient-accumulation factor per arch for train_4k: chosen so the
# activation peak fits a 16 GiB v5e (measured per-device temp bytes; the
# big-d and MoE models need it, the small ones do not).
MICROBATCHES = {
    "deepseek-67b": 4,
    "chameleon-34b": 4,
    "internlm2-20b": 2,
    "qwen3-moe-235b-a22b": 8,
    "jamba-1.5-large-398b": 8,
    "olmoe-1b-7b": 2,
}


def _greedy_sharding(mesh, leaf, skip_dims=(), batch_size=None):
    """Assign mesh axes to array dims by divisibility (decode caches &
    batch-like inputs).  The data axes go ONLY to a dim that equals the
    global batch (sharding the layer-stack dim made the per-layer scan
    re-gather the whole 1.4 TB cache: 167 GiB/dev measured); 'model' goes
    to the largest remaining divisible dim, never dim 0 of stacked
    caches."""
    dims = list(leaf.shape)
    spec = [None] * len(dims)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = [a for a in ("pod", "data") if a in axes]
    dp_size = int(np.prod([axes[a] for a in dp])) if dp else 1
    for i, d in enumerate(dims):
        if i in skip_dims:
            continue
        if batch_size is not None and d != batch_size:
            continue
        if dp and d % dp_size == 0 and d >= dp_size:
            spec[i] = tuple(dp) if len(dp) > 1 else dp[0]
            break
    if "model" in axes:
        msize = axes["model"]
        best = None
        for i, d in enumerate(dims):
            if spec[i] is None and i not in skip_dims and d % msize == 0 \
                    and d >= msize:
                if best is None or d > dims[best]:
                    best = i
        if best is not None:
            spec[best] = "model"
    return NamedSharding(mesh, P(*spec))


def _batch_shardings(mesh, tree):
    return jax.tree.map(lambda s: _greedy_sharding(mesh, s), tree)


def _cell_programs(arch_name, shape_name, mesh, variant="baseline"):
    """Returns (fn, example_inputs, in_shardings) for lower()."""
    cfg = registry.get(arch_name)
    shape = SHAPES[shape_name]
    bundle = build(cfg)
    pspecs = M.param_shardings(mesh, bundle.axes(),
                               bundle.abstract_params())
    abstract_params = bundle.abstract_params()

    if shape.kind == "train" and variant == "gradcomp":
        # the paper's technique on the pod wire: compressed-DP train step
        from repro.compression.grads import GradCompressionConfig
        from .train import make_train_step_compressed

        assert "pod" in mesh.axis_names, "gradcomp needs the multi-pod mesh"
        opt_cfg = opt.AdamWConfig(total_steps=1000)
        ostate_abs = jax.eval_shape(lambda p: opt.init(p, opt_cfg),
                                    abstract_params)
        n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
        # compressed-DP design point: params/opt are POD-REPLICATED (FSDP
        # over 'data' only) and only the compressed gradient crosses pods
        def drop_pod(ns):
            spec = tuple(
                ("data" if (e == "pod" or e == ("pod",)) else
                 tuple(a for a in e if a != "pod") if isinstance(e, tuple)
                 else e)
                for e in ns.spec)
            spec = tuple(e[0] if isinstance(e, tuple) and len(e) == 1
                         else (None if e == () else e) for e in spec)
            return NamedSharding(mesh, P(*spec))

        pspecs = jax.tree.map(drop_pod, pspecs)
        resid_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_pods,) + s.shape, jnp.float32),
            abstract_params)
        resid_sh = jax.tree.map(
            lambda ns: NamedSharding(mesh, P("pod", *ns.spec)), pspecs)
        like_params = lambda: jax.tree.map(lambda s: s, pspecs)
        ostate_sh = opt.OptState(M.replicated(mesh), like_params(),
                                 like_params(), like_params())
        batch = bundle.input_specs(shape)
        b_sh = _batch_shardings(mesh, batch)
        step = make_train_step_compressed(
            bundle, mesh, opt_cfg, GradCompressionConfig())

        def train_step(params, ostate, resid, batch):
            (p2, o2, r2), m = step((params, ostate, resid), batch)
            return p2, o2, r2, m["loss"]

        return (train_step,
                (abstract_params, ostate_abs, resid_abs, batch),
                (pspecs, ostate_sh, resid_sh, b_sh), (0, 1, 2))

    if shape.kind == "train":
        opt_cfg = opt.AdamWConfig(total_steps=1000)
        ostate_abs = jax.eval_shape(lambda p: opt.init(p, opt_cfg),
                                    abstract_params)
        # moments/master shard like the params (ZeRO over 'data')
        like_params = lambda: jax.tree.map(lambda s: s, pspecs)
        ostate_sh = opt.OptState(M.replicated(mesh), like_params(),
                                 like_params(), like_params())
        batch = bundle.input_specs(shape)
        b_sh = _batch_shardings(mesh, batch)
        micro = MICROBATCHES.get(arch_name, 1)

        def train_step(params, ostate, batch):
            if micro == 1:
                (loss, (ce, aux)), grads = jax.value_and_grad(
                    bundle.loss, has_aux=True)(params, batch, mesh)
            else:
                # gradient accumulation: activation peak / micro at the
                # cost of `micro` sequential passes (standard at scale)
                mbs = jax.tree.map(
                    lambda x: x.reshape(micro, x.shape[0] // micro,
                                        *x.shape[1:]), batch)

                def one(acc, mb):
                    (l, _), g = jax.value_and_grad(
                        bundle.loss, has_aux=True)(params, mb, mesh)
                    return jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g), l

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, losses = jax.lax.scan(one, zeros, mbs)
                grads = jax.tree.map(lambda g: g / micro, grads)
                loss = losses.mean()
            params, ostate, _m = opt.apply(params, grads, ostate,
                                           opt_cfg)
            return params, ostate, loss

        return (train_step, (abstract_params, ostate_abs, batch),
                (pspecs, ostate_sh, b_sh), (0, 1))

    if shape.kind == "prefill":
        batch = bundle.input_specs(shape)
        b_sh = _batch_shardings(mesh, batch)

        def prefill(params, batch):
            return bundle.prefill(params, batch, mesh)

        return prefill, (abstract_params, batch), (pspecs, b_sh), ()

    # decode
    quantized = variant == "kvq"
    ins = bundle.input_specs(shape, quantized_kv=quantized)
    cache_sh = jax.tree.map(
        lambda s: _greedy_sharding(mesh, s, skip_dims=(0,),
                                   batch_size=shape.global_batch),
        ins["cache"])
    tok_sh = _greedy_sharding(mesh, ins["tokens"])
    kv_cfg = None
    if quantized:
        from repro.compression.kv import kv_quantizer_config
        kv_cfg = kv_quantizer_config()

    def serve_step(params, cache, tokens, pos):
        return bundle.serve_step(params, cache, tokens, pos, mesh,
                                 kv_cfg=kv_cfg)

    return (serve_step,
            (abstract_params, ins["cache"], ins["tokens"], ins["pos"]),
            (pspecs, cache_sh, tok_sh, M.replicated(mesh)), (1,))


def run_cell(arch_name, shape_name, mesh_kind, variant="baseline",
             force=False, save_hlo=True):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"{mesh_kind}.{arch_name}.{shape_name}" + (
        "" if variant == "baseline" else f".{variant}")
    out_path = os.path.join(RESULTS_DIR, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    mesh = M.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "status": "error"}
    t0 = time.time()
    try:
        fn, args, shardings, donate = _cell_programs(
            arch_name, shape_name, mesh, variant)
        with jax.set_mesh(mesh):
            # donation: train aliases old->new (params, opt state); decode
            # aliases the KV cache — without it the optimizer update keeps
            # two full f32 state copies alive (~40 GiB/dev on jamba)
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = hlo_analysis.collective_bytes(hlo)
        coll.pop("__ops", None)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            arg_bytes=int(ma.argument_size_in_bytes),
            out_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            code_bytes=int(ma.generated_code_size_in_bytes),
            cost_flops=float(ca.get("flops", 0) or 0),
            cost_bytes=float(ca.get("bytes accessed", 0) or 0),
            collective_bytes=coll,
            hlo_dot_flops=int(hlo_analysis.dot_flops(hlo)),
            n_devices=int(np.prod(mesh.devices.shape)),
        )
        if save_hlo:
            with open(os.path.join(RESULTS_DIR, tag + ".hlo"), "w") as f:
                f.write(hlo)
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def all_cells():
    cells = []
    for arch in sorted(registry.ARCHS):
        cfg = registry.get(arch)
        for shape_name, shape in SHAPES.items():
            if runnable(cfg, shape):
                cells.append((arch, shape_name))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if args.list:
        for c in cells:
            print(*c)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_err = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, variant=args.variant,
                           force=args.force)
            ok = rec["status"] == "ok"
            n_ok += ok
            n_err += (not ok)
            if ok:
                print(f"[OK ] {mk:6s} {arch:26s} {shape:12s} "
                      f"compile={rec['compile_s']:7.1f}s "
                      f"temp/dev={rec['temp_bytes']/2**30:6.2f}GiB "
                      f"args/dev={rec['arg_bytes']/2**30:6.2f}GiB")
            else:
                print(f"[ERR] {mk:6s} {arch:26s} {shape:12s} "
                      f"{rec['error'][:120]}")
    print(f"\n{n_ok} ok, {n_err} failed")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
