"""Fault-tolerant training runtime.

Responsibilities:
  * restart-exact resume: checkpoint (params, opt state, error-feedback
    residuals) + pure-function-of-step data pipeline -> kill -9 at any
    step resumes bit-compatibly (tests/test_runtime.py).
  * preemption handling: SIGTERM sets a flag; the loop checkpoints and
    exits cleanly at the next step boundary.
  * straggler mitigation: per-step wall-time EWMA; steps slower than
    `straggler_factor` x EWMA are logged with host attribution — at fleet
    scale this feeds the scheduler's drain decision.  (Single-process
    container: the detection path is fully exercised, the drain RPC is a
    hook.)
  * elastic re-mesh: `ElasticController.resize()` rebuilds the mesh at a
    new size and re-shards the restored checkpoint — shardings are pure
    functions of (param axes, mesh), never persisted, so any checkpoint
    restores onto any mesh size (tests cover 1->2 device resize).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    checkpoint_every: int = 100
    log_every: int = 10
    straggler_factor: float = 3.0


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful stop at the next step boundary."""

    def __init__(self):
        self.requested = False
        self._orig = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:          # non-main thread (tests)
                pass
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for sig, h in self._orig.items():
            signal.signal(sig, h)
        return False


class StragglerMonitor:
    def __init__(self, factor: float, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.ewma = None
        self.events: list[tuple[int, float]] = []
        self._n = 0

    def record(self, step: int, dt: float) -> bool:
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (self._n > self.warmup
                        and dt > self.factor * self.ewma)
        if is_straggler:
            self.events.append((step, dt))   # -> scheduler drain hook
        else:
            self.ewma = 0.9 * self.ewma + 0.1 * dt
        return is_straggler


class AuditCounters:
    """Cumulative §12 audit observability for the loop: step functions
    that encode with verify=True surface their `AuditReport`(s) under
    metrics["audit"] (one report or a list), and the loop folds them
    here so a run-level "how many bound violations so far" exists
    without the caller wiring its own accumulator.  Mirrors
    `DecodeEngine.stats()`'s audit_* counters on the serving side."""

    def __init__(self):
        self.reports = 0
        self.violations = 0
        self.n_nonfinite = 0
        self.overflow = 0
        self.max_err = 0.0

    def fold(self, metrics) -> None:
        if not isinstance(metrics, dict) or "audit" not in metrics:
            return
        reps = metrics["audit"]
        # AuditReport IS a (Named)tuple — a single report is one with
        # the counter fields, anything else iterable is a list of them
        if hasattr(reps, "violations"):
            reps = (reps,)
        for rep in reps:
            if rep is None:
                continue
            self.reports += 1
            self.violations += int(rep.violations)
            self.n_nonfinite += int(rep.n_nonfinite)
            self.overflow += int(rep.overflow)
            self.max_err = max(self.max_err, float(rep.max_err))

    def as_dict(self) -> dict:
        return dict(audit_reports=self.reports,
                    audit_violations=self.violations,
                    audit_nonfinite=self.n_nonfinite,
                    audit_overflow=self.overflow,
                    audit_max_err=self.max_err)


def run(step_fn: Callable, state, batch_fn: Callable,
        ckpt: CheckpointManager, cfg: TrainLoopConfig,
        start_step: int = 0, on_metrics: Optional[Callable] = None):
    """Generic loop: state = step_fn(state, batch) jitted by the caller.
    Returns (state, last_step, interrupted).

    When step_fn's metrics dict carries an "audit" entry (an
    `AuditReport` or list of them, from encode(verify=True)), the loop
    accumulates run-level counters and hands `on_metrics` the dict with
    an extra "audit_cumulative" key (see `AuditCounters`)."""
    monitor = StragglerMonitor(cfg.straggler_factor)
    audit = AuditCounters()
    step = start_step
    with PreemptionGuard() as guard:
        while step < cfg.total_steps:
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(step))
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.perf_counter() - t0
            straggle = monitor.record(step, dt)
            audit.fold(metrics)
            step += 1
            if on_metrics and (step % cfg.log_every == 0 or straggle):
                if isinstance(metrics, dict) and audit.reports:
                    metrics = dict(metrics,
                                   audit_cumulative=audit.as_dict())
                on_metrics(step, metrics, dt, straggle)
            if step % cfg.checkpoint_every == 0 or guard.requested:
                ckpt.save(step, state)
            if guard.requested:
                ckpt.wait()
                return state, step, True
    ckpt.wait()
    return state, step, False


def resume_or_init(ckpt: CheckpointManager, init_fn: Callable):
    """Restore the latest checkpoint or build fresh state."""
    template = jax.eval_shape(init_fn)
    restored, step = ckpt.restore(template)
    if restored is None:
        return init_fn(), 0
    return restored, step
