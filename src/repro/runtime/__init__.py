"""repro.runtime"""
