"""Fault-injection harness for the §12 guarantee-audit plane.

The audit subsystem (`core/audit.py`) makes two promises: the carried
checksum catches silent wire corruption, and `verify=` catches
bound/non-finite violations at decode cost.  Promises need adversaries —
this module is the deterministic corruption side of that contract, used
by `benchmarks/audit_bench.py` and `tests/test_audit.py` to prove
detection coverage over every registry preset:

    plan = FaultPlan("gradsmooth", "payload_bitflip")
    bad  = plan.corrupt_wire(wire)          # wire from encode(integrity=True)
    assert not bool(audit.verify_wire(bad))

Five fault classes (`FAULT_CLASSES`):

  payload_bitflip  flip one bit of one transmitted payload word
  header_bitflip   flip one bit of a header plane (falls back to the
                   outlier-count / eb2 plane on header-free chains)
  length_truncate  halve the transmitted `payload_len` and zero the tail
                   (models a cut-short transfer; the checksum covers the
                   length plane, so this is caught even when the dropped
                   words were already zero)
  chainid_swap     rotate the per-wire/per-page chain id to another
                   VALID id (silent mis-dispatch; selector wires only)
  nan_input        corrupt the *input* before encode — caught by the
                   `verify=` audit report (`n_nonfinite > 0`), not the
                   checksum, which by design covers the wire, not x
  hop_bitflip      flip one bit of an IN-FLIGHT ring-reduce hop payload
                   (`corrupt_hop` as a `Transport(fault=...)` hook) —
                   caught by the per-hop `plane_checksum` the verified
                   reduce carries (`reduce_mean(integrity='drop')`), not
                   by the whole-wire checksum, which never sees
                   intermediate hops

Determinism mirrors `benchmarks/datasets.py`: every plan seeds
`np.random.default_rng` from `zlib.crc32` of its suite/class name, so
fault positions reproduce across processes without PYTHONHASHSEED.
Corruption is host-side numpy on leaf copies — the original wire pytree
is never mutated.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import audit

FAULT_CLASSES = ("payload_bitflip", "header_bitflip", "length_truncate",
                 "chainid_swap", "nan_input", "hop_bitflip")


def _swap_leaf(wire, old_leaf, new_arr):
    """Rebuild `wire` with `old_leaf` (matched by identity) replaced."""
    flat, treedef = jax.tree_util.tree_flatten(wire)
    hits = [i for i, f in enumerate(flat) if f is old_leaf]
    assert len(hits) == 1, f"leaf identity match found {len(hits)} leaves"
    flat[hits[0]] = jnp.asarray(new_arr)
    return jax.tree_util.tree_unflatten(treedef, flat)


def applicable_classes(wire) -> tuple:
    """The wire-corruption classes that apply to this wire shape.
    `chainid_swap` needs a transmitted chain id (selector wires and
    selected `PackedKV`s); `nan_input` is an input fault, never a wire
    fault, so it is not listed here — harnesses add it via
    `FaultPlan.corrupt_input` + the encode-side audit report.
    `hop_bitflip` is likewise not a stored-wire fault: it corrupts an
    in-flight collective hop via `FaultPlan.corrupt_hop` mounted as a
    `Transport(fault=...)` hook."""
    out = ["payload_bitflip", "header_bitflip", "length_truncate"]
    if getattr(wire, "chain_id", None) is not None:
        out.append("chainid_swap")
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One deterministic corruption: (suite, fault class) → positions.

    `n_chains` bounds `chainid_swap` so the swapped id stays a valid
    dispatch target (the silent-corruption model: decode succeeds, the
    bits are wrong, only the checksum knows)."""
    suite: str
    cls: str
    n_chains: int = 2

    def __post_init__(self):
        assert self.cls in FAULT_CLASSES, self.cls

    def rng(self) -> np.random.Generator:
        return np.random.default_rng(
            zlib.crc32(f"fault:{self.suite}:{self.cls}".encode()))

    # --- input faults -----------------------------------------------------

    def corrupt_input(self, x) -> jnp.ndarray:
        """`nan_input`: plant NaN/±Inf in the pre-encode input.  The §12
        audit report (encode(verify=True)) must show n_nonfinite > 0."""
        assert self.cls == "nan_input", self.cls
        a = np.asarray(x, np.float32).copy()
        r = self.rng()
        idx = r.choice(a.size, size=min(3, a.size), replace=False)
        vals = [np.nan, np.inf, -np.inf]
        for i, j in enumerate(idx):
            a.flat[j] = vals[i % 3]
        return jnp.asarray(a)

    # --- in-flight faults -------------------------------------------------

    def corrupt_hop(self, hop):
        """`hop_bitflip`: in-graph corruption hook for the collective
        fault hook (`Transport(fault=plan.corrupt_hop)`).  Flips one
        deterministic bit in the largest uint32 leaf of whatever pytree
        the transport hands the hook — the ring hop's word plane, or the
        payload of a gathered wire on the fallback path — so the per-hop
        `plane_checksum` (ring) / whole-wire checksum (gather) must
        catch it.  Traceable: positions are fixed host-side from the
        plan's rng at trace time; `FaultPlan` is frozen, so the bound
        method is hashable as `Transport` requires."""
        assert self.cls == "hop_bitflip", self.cls
        leaves, treedef = jax.tree_util.tree_flatten(hop)
        targets = [(int(lf.size), i) for i, lf in enumerate(leaves)
                   if getattr(lf, "dtype", None) == jnp.uint32
                   and lf.size > 1]
        if not targets:
            return hop
        _, idx = max(targets)
        r = self.rng()
        flat = leaves[idx].reshape(-1)
        word = int(r.integers(0, flat.size))
        bit = jnp.uint32(1) << jnp.uint32(int(r.integers(0, 32)))
        flat = flat.at[word].set(flat[word] ^ bit)
        leaves[idx] = flat.reshape(leaves[idx].shape)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # --- wire faults ------------------------------------------------------

    def corrupt_wire(self, wire):
        """Apply this plan's wire fault to a copy of `wire` (any of
        `Encoded` / `SelectedWire` / `PackedKV`)."""
        assert self.cls not in ("nan_input", "hop_bitflip"), (
            f"{self.cls} is not a stored-wire fault (corrupt_input / "
            f"corrupt_hop)")
        assert self.cls in applicable_classes(wire), (
            f"{self.cls} not applicable to {type(wire).__name__}")
        return getattr(self, f"_{self.cls}")(wire)

    def _payload_bitflip(self, wire):
        r = self.rng()
        pay = np.asarray(wire.payload).copy()
        plen = np.asarray(wire.payload_len).reshape(-1)
        rows = pay.reshape(-1, pay.shape[-1])
        row = int(r.integers(0, rows.shape[0]))
        limit = int(plen[row]) if plen.size == rows.shape[0] else int(plen[0])
        col = int(r.integers(0, max(limit, 1)))
        rows[row, col] ^= np.uint32(1) << np.uint32(r.integers(0, 32))
        return _swap_leaf(wire, wire.payload, pay)

    def _header_plane(self, wire):
        """First non-empty header plane, else the accounting plane every
        wire shape carries (n_outliers / eb2)."""
        planes = getattr(wire, "headers", None)
        if planes is None:                # SelectedWire: one flat plane
            h = getattr(wire, "header", None)
            planes = () if h is None else (h,)
        for p in planes:
            if p is not None and np.asarray(p).size:
                return p
        fallback = getattr(wire, "n_outliers", None)
        if fallback is None:
            fallback = wire.eb2                       # PackedKV
        return fallback

    def _header_bitflip(self, wire):
        r = self.rng()
        leaf = self._header_plane(wire)
        a = np.asarray(leaf).copy()
        view = a.reshape(a.size).view(np.uint8)   # reshape: 0-d scalars too
        byte = int(r.integers(0, view.size))
        view[byte] ^= np.uint8(1) << np.uint8(r.integers(0, 8))
        return _swap_leaf(wire, leaf, a)

    def _length_truncate(self, wire):
        pay = np.asarray(wire.payload).copy()
        plen = np.asarray(wire.payload_len).copy()
        new = plen // 2
        rows = pay.reshape(-1, pay.shape[-1])
        lens = (new.reshape(-1) if new.size == rows.shape[0]
                else np.full(rows.shape[0], int(new.reshape(-1)[0])))
        mask = np.arange(rows.shape[-1])[None, :] < lens[:, None]
        rows *= mask.astype(rows.dtype)
        out = _swap_leaf(wire, wire.payload, pay)
        return _swap_leaf(out, out.payload_len, new)

    def _chainid_swap(self, wire):
        cid = np.asarray(wire.chain_id).copy()
        n = max(int(self.n_chains), 2)
        cid = ((cid.astype(np.int64) + 1) % n).astype(cid.dtype)
        return _swap_leaf(wire, wire.chain_id, cid)


def detection_matrix(wire, *, suite: str = "smoke", n_chains: int = 2,
                     report=None) -> dict:
    """Run every applicable wire fault against `wire` (which must carry
    a §12 checksum) and return {fault class: detected?}.  Detection is
    the checksum verdict: `verify_wire(corrupted)` must come back False.
    When an `AuditReport` from a nan-corrupted encode is given, the
    `nan_input` row is judged from it (`n_nonfinite > 0`)."""
    if not audit.has_checksum(wire):
        raise ValueError("detection_matrix needs encode(integrity=True) "
                         "wires — no checksum carried")
    assert bool(audit.verify_wire(wire)), "clean wire failed its checksum"
    out = {}
    for cls in applicable_classes(wire):
        bad = FaultPlan(suite, cls, n_chains=n_chains).corrupt_wire(wire)
        out[cls] = not bool(audit.verify_wire(bad))
    if report is not None:
        out["nan_input"] = int(report.n_nonfinite) > 0
    return out
