"""Elastic scaling: rebuild the mesh at a new size and re-shard state.

Shardings are pure functions of (logical param axes, mesh) — launch/mesh.py
rules — and checkpoints store plain host arrays, so ANY checkpoint restores
onto ANY mesh whose axes divide the dims.  Scale-down after losing a pod /
scale-up after capacity returns is: checkpoint -> resize() -> continue.
The data pipeline is stateless-in-step, so no iterator surgery is needed;
only `global_batch % new_dp == 0` is re-validated.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.launch import mesh as M


def make_mesh_for(devices=None, model_parallel: int | None = None) -> Mesh:
    """Build the largest (data, model) mesh from the devices at hand."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    mp = model_parallel or min(16, n)
    while n % mp:
        mp -= 1
    arr = np.asarray(devices).reshape(n // mp, mp)
    return Mesh(arr, ("data", "model"))


def reshard_state(state, axes_tree_fn, mesh: Mesh):
    """Place a host-restored state tree onto `mesh` with rule-derived
    shardings (params/opt) — the core of the elastic resize."""
    shardings = axes_tree_fn(mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings)


def resize(ckpt_manager, template, axes_tree_fn, model_parallel=None):
    """checkpoint -> rebuild mesh from the CURRENT device set -> restore +
    re-shard.  Returns (state, step, mesh)."""
    state, step = ckpt_manager.restore(template)
    if state is None:
        raise RuntimeError("no checkpoint to resize from")
    mesh = make_mesh_for(model_parallel=model_parallel)
    return reshard_state(state, axes_tree_fn, mesh), step, mesh
