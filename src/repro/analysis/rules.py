"""The guarantee-lesson rules, GL001-GL007 (DESIGN.md §13).

Each rule encodes ONE pitfall this repo (or the source paper) actually
hit; the docstrings name the PR that learned the lesson.  Rules are
heuristic by design — they pattern-match the shape of the bug class,
and per-file `# repro: noqa GL00x -- reason` handles the sound
exceptions.  All pure stdlib `ast`; no JAX import.
"""
from __future__ import annotations

import ast
import re

from .walker import Finding, register_rule

_FLOAT_DTYPES = {"float16", "float32", "float64", "bfloat16"}


# ------------------------------------------------------- ast utilities ---

def _funcs(tree):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _dotted(node) -> str:
    """Best-effort dotted name of a Name/Attribute chain ('jax.debug.print');
    '' for anything unresolvable."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _idents(node):
    """Every Name id and Attribute attr in a subtree."""
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            yield n.id
        elif isinstance(n, ast.Attribute):
            yield n.attr


def _has_float_dtype(node) -> bool:
    """Does this subtree mention a floating dtype (astype(f32), jnp.float32
    constructor/attribute, dtype='float32' strings)?"""
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)):
            if (n.id if isinstance(n, ast.Name) else n.attr) in _FLOAT_DTYPES:
                return True
        elif isinstance(n, ast.Constant) and n.value in _FLOAT_DTYPES:
            return True
    return False


def _calls(node, names: set):
    """Call nodes in a subtree whose (last-segment) callee name is in
    `names` — matches both `sum(...)` and `jnp.sum(...)`."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            d = _dotted(n.func)
            if d and d.split(".")[-1] in names:
                yield n


def _name_segments(name: str) -> set:
    return set(name.lower().split("_")) - {""}


# ---------------------------------------------------------------- rules ---

class GL001:
    """Float-typed accumulation in wire/bit accounting (PR 5's drift
    class): an f32 sum over word/bit counts rounds past 2^24 and the
    reported wire size silently diverges from the shipped one.  The
    contract (codec.transmitted_bits): accumulate exact int32 words,
    convert to float ONCE at the end."""
    id = "GL001"
    title = "float-typed accumulation in wire/bit accounting"
    hint = ("accumulate word counts as int32 and convert once via "
            "codec.transmitted_bits (the PR 5 fix)")
    _SCOPE = re.compile(r"wire_bits|wire_bytes|transmitted|bytes_moved"
                        r"|account")

    def check(self, tree, text, path):
        for fn in _funcs(tree):
            if not self._SCOPE.search(fn.name):
                continue
            for call in _calls(fn, {"sum", "cumsum"}):
                # the float marker must sit INSIDE the reduction — an
                # astype on the summed result is the sanctioned
                # convert-once pattern, not the drift class
                if any(_has_float_dtype(a) for a in call.args) or \
                        any(_has_float_dtype(k.value) for k in call.keywords):
                    yield Finding(
                        self.id, path, call.lineno,
                        f"`{fn.name}` accumulates in floating point "
                        f"inside accounting (f32 sums drift past 2^24 "
                        f"words)", self.hint)


class GL002:
    """Reconstruction acceptance without the contracted-overflow guard
    (PR 1's ABS bug): `|x - bin*eb2| <= eb` contracts to a finite, in-
    bound difference when `bin*eb2` overflows to inf with x finite —
    the check PASSES and the decoder ships inf.  Any acceptance test
    over a product reconstruction must also check the product (or the
    difference's operands) with isfinite."""
    id = "GL002"
    title = "reconstruction check missing the overflow guard"
    hint = ("guard the reconstruction with jnp.isfinite(recon) before "
            "accepting |x - recon| <= eb (the PR 1 fix)")

    def check(self, tree, text, path):
        for fn in _funcs(tree):
            body_ids = set(_idents(fn))
            if "isfinite" in body_ids:
                continue
            assigned = {}
            for n in ast.walk(fn):
                if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                        isinstance(n.targets[0], ast.Name):
                    assigned[n.targets[0].id] = n.value

            def has_product(node) -> bool:
                for s in ast.walk(node):
                    if isinstance(s, ast.BinOp) and isinstance(s.op, ast.Mult):
                        return True
                    if isinstance(s, ast.Name) and s.id in assigned:
                        v = assigned[s.id]
                        for t in ast.walk(v):
                            if isinstance(t, ast.BinOp) and \
                                    isinstance(t.op, ast.Mult):
                                return True
                return False

            for cmp in ast.walk(fn):
                if not (isinstance(cmp, ast.Compare)
                        and all(isinstance(op, (ast.LtE, ast.Lt))
                                for op in cmp.ops)):
                    continue
                for call in _calls(cmp.left, {"abs", "absolute"}):
                    sub = next((s for a in call.args for s in ast.walk(a)
                                if isinstance(s, ast.BinOp)
                                and isinstance(s.op, ast.Sub)), None)
                    if sub is not None and has_product(sub):
                        yield Finding(
                            self.id, path, cmp.lineno,
                            f"`{fn.name}` accepts |x - recon| against a "
                            f"bound with no isfinite guard on the "
                            f"product reconstruction", self.hint)
                        break


class GL003:
    """TIGHTEN in an audit/violation predicate (PR 9's gotcha,
    inverted): encoders must accept only `diff <= eb*TIGHTEN` (§1
    rounding-tie rule), but auditors must test the PLAIN bound — a
    tightened audit flags clean encodes at the margin as violations,
    and the margin is the whole point of tightening."""
    id = "GL003"
    title = "TIGHTEN used in an audit/violation predicate"
    hint = ("audit against the plain requested bound; only encoders "
            "tighten (core.audit.audit_report's contract)")
    _SCOPE = re.compile(r"audit|verify|violat|detect")

    def check(self, tree, text, path):
        for fn in _funcs(tree):
            if not self._SCOPE.search(fn.name):
                continue
            for n in ast.walk(fn):
                ident = (n.id if isinstance(n, ast.Name)
                         else n.attr if isinstance(n, ast.Attribute) else "")
                if "tighten" in ident.lower():
                    yield Finding(
                        self.id, path, n.lineno,
                        f"`{fn.name}` references `{ident}` — auditors "
                        f"must use the plain bound, not the encoder's "
                        f"tightened one", self.hint)


class GL004:
    """Open-loop prediction (the classic predictor bug, §9): a
    predictor that reads the ORIGINAL value plane instead of the
    reconstructed/bin plane diverges from the decoder (which only has
    reconstructions), and the §1 bound quietly becomes unbounded.
    `encode_bins`/`decode_bins` implementations may only touch the bin
    plane they are handed."""
    id = "GL004"
    title = "open-loop prediction (reads the original plane)"
    hint = ("predict from the bin/reconstructed plane only — the "
            "closed-loop contract of core.predict (DESIGN.md §9)")
    _PLANE_NAMES = {"x", "values", "orig", "original", "raw", "x_orig"}

    def check(self, tree, text, path):
        for fn in _funcs(tree):
            if fn.name not in ("encode_bins", "decode_bins"):
                continue
            args = {a.arg for a in fn.args.args} | \
                {a.arg for a in fn.args.kwonlyargs}
            leaked = args & self._PLANE_NAMES
            used = {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)}
            hit = sorted(leaked | (used & self._PLANE_NAMES))
            if hit:
                yield Finding(
                    self.id, path, fn.lineno,
                    f"`{fn.name}` touches the original value plane "
                    f"({', '.join(hit)}) — predictors must be closed-"
                    f"loop on the bin plane", self.hint)


class GL005:
    """Transmitted length consumed without validation (§12's length
    guard): slicing a payload by a wire-carried `payload_len` without
    `check_payload_len` (host) or clamping (traced) lets a corrupt
    length index garbage or silently truncate.  §6's rule: the header
    plane, not the length, is the decode authority."""
    id = "GL005"
    title = "transmitted length used without validation"
    hint = ("call audit.check_payload_len (host) or clamp via "
            "jnp.clip/minimum (traced) before consuming payload_len")
    _VALIDATORS = {"check_payload_len", "clip", "minimum", "clamp",
                   "gather_chunks", "decode_words", "decode_word_stages"}

    def check(self, tree, text, path):
        for fn in _funcs(tree):
            called = {_dotted(c.func).split(".")[-1]
                      for c in ast.walk(fn) if isinstance(c, ast.Call)}
            if called & self._VALIDATORS:
                continue
            # names bound from a `.payload_len` attribute, plus direct use
            len_names = {"payload_len"}
            for n in ast.walk(fn):
                if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                        isinstance(n.targets[0], ast.Name) and \
                        any(i == "payload_len" for i in _idents(n.value)):
                    len_names.add(n.targets[0].id)
            for n in ast.walk(fn):
                if isinstance(n, ast.Subscript) and \
                        set(_idents(n.slice)) & len_names:
                    yield Finding(
                        self.id, path, n.lineno,
                        f"`{fn.name}` indexes by a transmitted "
                        f"payload_len with no length validation in "
                        f"scope", self.hint)


class GL006:
    """Non-deterministic benchmark seeding: every committed BENCH_*
    artifact and fault plan must reproduce across processes, so seeds
    follow ONE convention — `np.random.default_rng(zlib.crc32(name))`
    (benchmarks/datasets.py).  Bare `default_rng()` is time-seeded;
    literal-int seeds fork the convention and collide; `hash()` varies
    per process under PYTHONHASHSEED."""
    id = "GL006"
    title = "benchmark seeding off the crc32 convention"
    hint = ("seed as np.random.default_rng(zlib.crc32(name.encode())) — "
            "the datasets.py/guard.py discipline")

    def check(self, tree, text, path):
        # host-side np seeding only: jax.random.PRNGKey(literal) is a
        # pure function of its int (deterministic by construction), so
        # keys are out of scope — the convention governs the np RNGs
        # that generate benchmark/fault data by suite NAME
        for call in _calls(tree, {"default_rng", "seed"}):
            d = _dotted(call.func)
            if d.split(".")[-1] == "seed" and "random" not in d:
                continue                       # some other .seed() method
            if not call.args and not call.keywords:
                yield Finding(
                    self.id, path, call.lineno,
                    "unseeded RNG construction (time-seeded, "
                    "irreproducible)", self.hint)
                continue
            ok = any("crc32" in _idents(a) for a in call.args)
            hashed = any(isinstance(c, ast.Call)
                         and _dotted(c.func) == "hash"
                         for a in call.args for c in ast.walk(a))
            if hashed:
                yield Finding(
                    self.id, path, call.lineno,
                    "RNG seeded via hash() (varies per process under "
                    "PYTHONHASHSEED)", self.hint)
            elif not ok:
                yield Finding(
                    self.id, path, call.lineno,
                    "RNG seeded off the crc32 convention "
                    "(irreproducible-by-name)", self.hint)


class GL007:
    """Host callbacks in jitted codec paths: `print`/`jax.debug.*`/
    `io_callback`/`pure_callback` inside encode/decode/quantize
    functions force host syncs (or silently trace-once), wreck the
    fused-kernel perf story, and can change semantics under vmap/jit.
    Debug output belongs in callers, never in the codec."""
    id = "GL007"
    title = "host callback inside a jitted encode/decode path"
    hint = ("move the print/debug call to the caller, or use the "
            "verify=/AuditReport plumbing for runtime observability")
    _SEGMENTS = {"encode", "decode", "pack", "unpack", "quantize",
                 "dequantize"}
    _BANNED = {"print", "breakpoint", "io_callback", "pure_callback"}

    def check(self, tree, text, path):
        if "benchmarks" in path:
            return                 # benches print by design (host-side)
        for fn in _funcs(tree):
            if not (_name_segments(fn.name) & self._SEGMENTS):
                continue
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                d = _dotted(call.func)
                if d.startswith("jax.debug") or \
                        (d and d.split(".")[-1] in self._BANNED):
                    yield Finding(
                        self.id, path, call.lineno,
                        f"`{fn.name}` calls `{d}` inside a codec path",
                        self.hint)


for _rule in (GL001, GL002, GL003, GL004, GL005, GL006, GL007):
    register_rule(_rule())
