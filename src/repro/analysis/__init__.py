"""Guarantee linter (DESIGN.md §13): static contract analysis over the
stages, registries, kernels, and accounting of the LC reproduction.

The paper's core lesson is that error-bound violations come from a
small set of recurring code-level pitfalls — overflow in the
reconstruction check, mishandled non-finite values, silent accounting
drift — that slip in as a compressor grows.  This repo re-learned
several of them the hard way (PR 1's ABS recon-overflow, PR 5's f32
accounting drift past 2^24 words, PR 9's TIGHTEN-vs-plain-bound
gotcha).  PR 9 made the guarantee observable at runtime; this package
makes it checkable *statically*, in CI, before any kernel runs.

Two layers, both gated via `python -m repro.analysis`:

  Layer 1 (`walker` + `rules`)  a stdlib-`ast` lint engine with a
      pluggable rule registry (`RULES`, mirroring the `STAGES`
      pattern).  Rules GL001-GL007 each encode one learned lesson; see
      DESIGN.md §13 for the table.  Pure stdlib — importable and
      runnable with no JAX devices.

  Layer 2 (`contracts` + `dispatch`)  a registry contract checker that
      IMPORTS the package and verifies cross-artifact invariants no
      single unit test pins as a set: stage encode/decode pairing and
      header accounting, preset/selector/KV-chain parseability, the
      DESIGN.md §7 dispatch table against `kernel_dispatch`'s actual
      routing, degradation-policy reachability, fault-class coverage
      in BENCH_audit.json, and §13 documentation of every registered
      rule.

Findings carry a rule id, file:line, and a fix hint; suppress per file
with `# repro: noqa GL00x -- reason` (the reason is mandatory — a bare
noqa is itself a finding).  The committed `analysis-baseline.json`
holds accepted findings (empty: the tree is clean); the CLI exits
nonzero on anything new.
"""
from .walker import (Finding, RULES, register_rule, lint_file,  # noqa: F401
                     lint_paths)
from . import rules as _rules  # noqa: F401  (registers GL001-GL007)
