"""CLI gate: `python -m repro.analysis [--format json]` — exits nonzero
on any finding not in the committed baseline (DESIGN.md §13).

Layer 1 (AST lint) always runs and needs no JAX; Layer 2 (registry
contracts) imports the package on the CPU backend — skip it with
--no-contracts for a pure-stdlib run.  The default lint scope is
src/repro + benchmarks relative to the repo root (resolved from this
file, so the gate works from any cwd).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import lint_paths
from . import report as R

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_PATHS = ("src/repro", "benchmarks")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS} "
                         f"under the repo root)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline",
                    default=str(REPO_ROOT / R.BASELINE_NAME),
                    help="accepted-findings file (default: committed "
                         "analysis-baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings into the baseline "
                         "and exit 0")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip Layer 2 (no repro/jax import; pure stdlib)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip Layer 1 (contracts only)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (Layer 1)")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)

    paths = args.paths or [REPO_ROOT / p for p in DEFAULT_PATHS]
    findings = []
    if not args.no_lint:
        rules = args.rules.split(",") if args.rules else None
        findings += lint_paths(paths, rules=rules)
    if not args.no_contracts:
        from . import contracts
        findings += contracts.run_contracts(REPO_ROOT)

    # repo-relative paths in output, wherever the gate ran from
    rel = []
    for f in findings:
        try:
            p = str(Path(f.path).resolve().relative_to(REPO_ROOT))
        except ValueError:
            p = f.path
        rel.append(type(f)(f.rule, p, f.line, f.message, f.hint))
    findings = rel

    if args.write_baseline:
        R.write_baseline(args.baseline, findings)
        print(f"baseline written: {len(findings)} accepted finding(s) "
              f"-> {args.baseline}")
        return 0

    new, old = R.split_new(findings, R.load_baseline(args.baseline))
    out = (R.render_json if args.format == "json" else R.render_text)(
        new, old)
    print(out)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
