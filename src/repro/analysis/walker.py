"""Layer-1 lint engine (DESIGN.md §13): parse every file once, hand the
tree to each registered rule, honor per-file suppressions.

The rule registry mirrors `core.pipeline.STAGES`: adding a rule = one
class + one `register_rule` call (+ a DESIGN.md §13 row — enforced by
the Layer-2 documentation contract).  Rules are pure stdlib `ast`
visitors so Layer 1 runs with no JAX installed at all.

Suppressions are per FILE, not per line: a comment anywhere in the file

    # repro: noqa GL001 -- kernels accumulate in f64, accounted exactly

turns the named rule(s) off for that file.  The reason after `--` is
MANDATORY — a bare `# repro: noqa GL00x` emits a GL000 finding instead
of suppressing anything, so every accepted exception is self-
documenting at the suppression site.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

# matches "repro: noqa GL001" / "repro: noqa GL001,GL005 -- reason"
# comment markers (see the module docstring for the full grammar)
_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\s+([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)"
    r"(?:\s*--\s*(\S.*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint/contract finding: rule id, location, message, fix hint."""
    rule: str
    path: str
    line: int
    message: str
    hint: str = ""

    def key(self) -> str:
        """Baseline identity: stable across line-number churn (edits
        above a finding must not make it 'new'), so the line is not
        part of the key."""
        return f"{self.rule}::{self.path}::{self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tail = f"  [fix: {self.hint}]" if self.hint else ""
        return f"{self.path}:{self.line}: {self.rule} {self.message}{tail}"


# ------------------------------------------------------- rule registry ---
#
# id -> rule object with:  .id  .title (the one-line lesson)  .hint
# (default fix guidance) and .check(tree, text, path) -> iter[Finding].
RULES: dict = {}


def register_rule(rule) -> None:
    """Register a lint rule (the `STAGES` pattern: one entry per rule).
    The Layer-2 contract checker demands a DESIGN.md §13 row per id."""
    RULES[rule.id] = rule


def parse_suppressions(text: str, path: str):
    """-> (suppressed rule-id set, [Finding for reasonless noqas])."""
    suppressed, bad = set(), []
    for ln, line in enumerate(text.splitlines(), 1):
        m = _NOQA_RE.search(line)
        if not m:
            continue
        ids = {t.strip() for t in m.group(1).split(",")}
        if m.group(2) is None:
            bad.append(Finding(
                "GL000", path, ln,
                f"suppression of {sorted(ids)} carries no reason",
                "append ` -- <why this exception is sound>` to the noqa"))
            continue
        suppressed |= ids
    return suppressed, bad


def lint_file(path, *, rules=None) -> list:
    """Run the registered rules over one file.  Returns findings with
    per-file suppressions already applied (GL000 reason-enforcement
    findings are never suppressible)."""
    path = Path(path)
    rel = str(path)
    text = path.read_text()
    try:
        tree = ast.parse(text, filename=rel)
    except SyntaxError as e:
        return [Finding("GL000", rel, e.lineno or 1,
                        f"file does not parse: {e.msg}",
                        "fix the syntax error")]
    suppressed, findings = parse_suppressions(text, rel)
    for rule in (RULES.values() if rules is None
                 else [RULES[r] for r in rules]):
        if rule.id in suppressed:
            continue
        findings.extend(rule.check(tree, text, rel))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths, *, rules=None) -> list:
    """Walk `paths` (files or directories) and lint every `*.py`."""
    out = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_file(f, rules=rules))
    return out
