"""Reporting + baseline for the guarantee linter (DESIGN.md §13).

The committed `analysis-baseline.json` holds the keys of ACCEPTED
findings; the gate fails only on findings not in it.  The tree starts
(and should stay) clean — the baseline exists so an unavoidable
finding can be accepted explicitly, reviewed in diff, instead of
rotting as a perma-red gate.  Baseline keys omit line numbers
(`Finding.key`), so edits above an accepted finding do not resurrect
it as "new".
"""
from __future__ import annotations

import json
from pathlib import Path

BASELINE_NAME = "analysis-baseline.json"


def load_baseline(path) -> set:
    p = Path(path)
    if not p.exists():
        return set()
    doc = json.loads(p.read_text())
    return set(doc.get("findings", []))


def write_baseline(path, findings) -> None:
    doc = {"findings": sorted({f.key() for f in findings})}
    Path(path).write_text(json.dumps(doc, indent=1) + "\n")


def split_new(findings, baseline: set):
    """-> (new findings, baselined findings)."""
    new = [f for f in findings if f.key() not in baseline]
    old = [f for f in findings if f.key() in baseline]
    return new, old


def render_text(new, old) -> str:
    lines = [f.render() for f in new]
    if old:
        lines.append(f"({len(old)} baselined finding"
                     f"{'s' if len(old) != 1 else ''} suppressed)")
    lines.append(f"{len(new)} new finding{'s' if len(new) != 1 else ''}")
    return "\n".join(lines)


def render_json(new, old) -> str:
    return json.dumps({
        "new": [f.as_dict() for f in new],
        "baselined": [f.as_dict() for f in old],
        "count": len(new),
    }, indent=1)
