"""Layer-2 registry contract checker (DESIGN.md §13): IMPORT the
package and verify the cross-artifact invariants no single unit test
pins as a set.

Contracts (finding ids RC001-RC008):

  RC001  every `STAGES` entry parses bare, declares the full word-stage
         contract (encode/decode pair + capacity/header accounting +
         transmits_len), and roundtrips a small word plane exactly
  RC002  every `PIPELINES` preset parses and spec-roundtrips
  RC003  every `KV_PAGE_CHAINS` chain resolves through the two-domain
         fragment grammar
  RC004  every `SELECTOR_SETS` member constructs (scoreable) or its
         rejection is documented in DESIGN.md §11
  RC005  the DESIGN.md §7 dispatch table matches `kernel_dispatch`'s
         actual routing (analysis/dispatch.py)
  RC006  every `DEGRADATION_POLICIES` name is reachable from a consumer
         outside core/audit.py
  RC007  every `FaultPlan` class appears in BENCH_audit.json's
         detection matrix
  RC008  every registered lint rule id is documented in DESIGN.md §13

This layer imports repro (and therefore jax) lazily, per check — the
CPU backend suffices and no accelerator devices are touched, so the CI
gate runs on the plain runner.
"""
from __future__ import annotations

import ast
import json
import zlib
from pathlib import Path

from .walker import Finding, RULES
from . import dispatch as D

_REG = "src/repro/configs/registry.py"


def check_stages() -> list:
    """RC001: the word-stage registry contract."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import pipeline as PL

    findings, path = [], "src/repro/core/pipeline.py"
    contract = ("encode_words", "decode_words", "capacity_words",
                "header_words", "header_content_bits", "spec")
    n = 1024
    for name, parser in sorted(PL.STAGES.items()):
        try:
            st = parser(name, [], 16)
        except Exception as e:
            findings.append(Finding(
                "RC001", path, 1,
                f"stage {name!r} does not parse bare: {e}",
                "every registered stage must build from its plain name"))
            continue
        missing = [a for a in contract if not callable(getattr(st, a, None))]
        if not hasattr(st, "transmits_len"):
            missing.append("transmits_len")
        if missing:
            findings.append(Finding(
                "RC001", path, 1,
                f"stage {name!r} is missing contract members "
                f"{missing} (exact encode/decode pair + header "
                f"accounting)", "implement the full word-stage "
                "contract (core/pipeline.py stage classes)"))
            continue
        try:
            rng = np.random.default_rng(zlib.crc32(name.encode()))
            words = jnp.asarray(
                rng.integers(0, 256, size=n).astype(np.uint32))
            hdr, payload, plen = st.encode_words(words, n)
            cap = st.capacity_words(n)
            if int(payload.size) != cap:
                findings.append(Finding(
                    "RC001", path, 1,
                    f"stage {name!r}: stored payload plane "
                    f"({int(payload.size)} words) != declared "
                    f"capacity_words ({cap})",
                    "capacity_words must describe the stored plane"))
            if int(hdr.size) != st.header_words(n):
                findings.append(Finding(
                    "RC001", path, 1,
                    f"stage {name!r}: stored header plane "
                    f"({int(hdr.size)} words) != declared header_words "
                    f"({st.header_words(n)})",
                    "header_words must describe the stored plane"))
            if st.header_content_bits(n) > 32 * max(st.header_words(n), 0) \
                    and st.header_words(n):
                findings.append(Finding(
                    "RC001", path, 1,
                    f"stage {name!r}: header_content_bits exceeds the "
                    f"stored header plane", "content bits are what a "
                    "transport moves; they cannot exceed storage"))
            back = st.decode_words(hdr, payload, n)
            if not bool(jnp.array_equal(back, words)):
                findings.append(Finding(
                    "RC001", path, 1,
                    f"stage {name!r}: decode_words is not the exact "
                    f"inverse of encode_words on a {n}-word plane",
                    "the §6 contract is bit-exact roundtrip"))
            if not st.transmits_len and int(plen) != cap:
                findings.append(Finding(
                    "RC001", path, 1,
                    f"stage {name!r}: transmits_len=False but encode "
                    f"returned len {int(plen)} != capacity {cap}",
                    "length-static stages transmit the full plane"))
        except Exception as e:
            findings.append(Finding(
                "RC001", path, 1,
                f"stage {name!r} roundtrip raised: {type(e).__name__}: "
                f"{e}", "the bare stage must encode/decode a plain "
                "word plane"))
    return findings


def check_pipelines() -> list:
    """RC002: every preset parses and spec-roundtrips."""
    from repro.configs.registry import PIPELINES, get_pipeline
    from repro.core.pipeline import parse_pipeline

    findings = []
    for name in sorted(PIPELINES):
        try:
            pipe = parse_pipeline(get_pipeline(name))
            if parse_pipeline(pipe.spec()) != pipe:
                findings.append(Finding(
                    "RC002", _REG, 1,
                    f"preset {name!r} does not spec-roundtrip",
                    "spec() and parse_pipeline must be inverses"))
        except Exception as e:
            findings.append(Finding(
                "RC002", _REG, 1,
                f"preset {name!r} does not parse: {e}",
                "every PIPELINES entry must parse_pipeline"))
    return findings


def check_kv_chains() -> list:
    """RC003: every KV page chain resolves through the fragment grammar."""
    from repro.configs.registry import KV_PAGE_CHAINS, get_kv_chain
    from repro.compression import kv

    findings = []
    for name in sorted(KV_PAGE_CHAINS):
        try:
            pred, word = kv._page_stages(get_kv_chain(name))
            _ = pred, word
        except Exception as e:
            findings.append(Finding(
                "RC003", _REG, 1,
                f"KV page chain {name!r} does not resolve: {e}",
                "every KV_PAGE_CHAINS fragment must split into "
                "pred|word stages (compression/kv.py)"))
    return findings


def check_selector_sets(design_text: str) -> list:
    """RC004: every selector-set member is scoreable (constructs) or its
    rejection is documented in DESIGN.md §11."""
    from repro.configs.registry import SELECTOR_SETS
    from repro.core import select as SEL

    sec11 = design_text.split("## §11", 1)[1].split("## §12", 1)[0] \
        if "## §11" in design_text else ""
    findings = []
    for name, entry in sorted(SELECTOR_SETS.items()):
        if len(entry["bias"]) != len(entry["chains"]):
            findings.append(Finding(
                "RC004", _REG, 1,
                f"selector set {name!r}: bias has {len(entry['bias'])} "
                f"entries for {len(entry['chains'])} chains",
                "one calibration bias per candidate chain"))
        try:
            sel = (SEL.get_kv_selector(name) if entry["base"] is None
                   else SEL.get_selector(name))
            if len(sel.chains) != len(entry["chains"]):
                findings.append(Finding(
                    "RC004", _REG, 1,
                    f"selector set {name!r}: built {len(sel.chains)} "
                    f"candidates from {len(entry['chains'])} registered "
                    f"chains", "construction must keep every member"))
        except Exception as e:
            # documented-rejected: §11 must name the offending token
            tokens = {t.split(":")[0] for c in entry["chains"]
                      for t in c.split("|") if t}
            documented = any(tok and tok in str(e) and tok in sec11
                             for tok in tokens)
            if not documented:
                findings.append(Finding(
                    "RC004", _REG, 1,
                    f"selector set {name!r} does not construct and the "
                    f"rejection is undocumented in §11: {e}",
                    "make the member scoreable or document the "
                    "rejection (the `shuffle` pattern, DESIGN.md §11)"))
    return findings


def check_policies(repo_root: Path) -> list:
    """RC006: every degradation policy is reachable from a consumer —
    its name appears as a string constant at some call site outside
    core/audit.py.  Policy names are passed IN by callers (`integrity=`
    args route through `get_policy`), so tests/examples/benchmarks are
    consumer sites too."""
    from repro.core.audit import DEGRADATION_POLICIES

    used = set()
    for root in ("src/repro", "tests", "examples", "benchmarks"):
        for py in sorted((repo_root / root).rglob("*.py")):
            if py.name == "audit.py" or "analysis" in py.parts:
                continue
            try:
                tree = ast.parse(py.read_text())
            except SyntaxError:
                continue
            for node in ast.walk(tree):
                if isinstance(node, ast.Constant) and \
                        isinstance(node.value, str):
                    used.add(node.value)
    return [Finding(
        "RC006", "src/repro/core/audit.py", 1,
        f"degradation policy {name!r} has no consumer outside "
        f"core/audit.py", "wire the policy into a receive site (or "
        "drop it from DEGRADATION_POLICIES)")
        for name in sorted(DEGRADATION_POLICIES) if name not in used]


def check_fault_classes(bench_path: Path) -> list:
    """RC007: every FaultPlan class is pinned in BENCH_audit.json's
    detection matrix."""
    from repro.runtime.guard import FAULT_CLASSES

    if not bench_path.exists():
        return [Finding(
            "RC007", str(bench_path), 1,
            "BENCH_audit.json is missing — the detection matrix is the "
            "committed proof of fault coverage",
            "run benchmarks.audit_bench to regenerate it")]
    doc = json.loads(bench_path.read_text())
    pinned = set()
    for row in doc.get("detection", []):
        pinned |= set(row.get("matrix", {}))
    return [Finding(
        "RC007", str(bench_path.name), 1,
        f"fault class {cls!r} is not pinned in BENCH_audit.json's "
        f"detection matrix", "add a detection row exercising the class "
        "(benchmarks/audit_bench.py)")
        for cls in FAULT_CLASSES if cls not in pinned]


def check_rule_docs(design_text: str) -> list:
    """RC008: every registered lint rule id is documented in §13."""
    sec13 = design_text.split("## §13", 1)[1].split("\n## §", 1)[0] \
        if "## §13" in design_text else ""
    if not sec13:
        return [Finding(
            "RC008", "DESIGN.md", 1,
            "DESIGN.md has no §13 (the guarantee-linter contract)",
            "add §13 with the rule table (one row per registered id)")]
    return [Finding(
        "RC008", "DESIGN.md", 1,
        f"lint rule {rid} is registered but undocumented in §13",
        "add the rule's row (lesson + PR) to the §13 table")
        for rid in sorted(RULES) if rid not in sec13]


def run_contracts(repo_root) -> list:
    """Run every Layer-2 contract; returns the combined findings."""
    root = Path(repo_root)
    design = (root / "DESIGN.md").read_text() \
        if (root / "DESIGN.md").exists() else ""
    findings = []
    findings += check_stages()
    findings += check_pipelines()
    findings += check_kv_chains()
    findings += check_selector_sets(design)
    findings += D.check_dispatch(D.parse_dispatch_table(design))
    findings += check_policies(root)
    findings += check_fault_classes(root / "BENCH_audit.json")
    findings += check_rule_docs(design)
    return findings
