"""DESIGN.md §7 dispatch-table checker: the doc's kernel-routing table
vs `Pipeline.kernel_dispatch`'s ACTUAL routing.

Until this module, only prose kept the §7 table and the dispatch code in
sync — a fused kernel could land (or an open slot close) without the
table moving, and the docs would quietly lie about which chains hit
Pallas.  The checker parses the markdown table, maps each row to
representative probe chains, and asserts the row's claimed kernel (a
`kernels/x.py::fn` path, or "open slot"/"jit reference" meaning None)
equals what `parse_pipeline(probe).kernel_dispatch()` returns.

`parse_dispatch_table` + `check_dispatch` are separable so tests can
feed a deliberately desynced table and assert detection (the seeded-
desync test in tests/test_analysis.py).
"""
from __future__ import annotations

import dataclasses
import re

from .walker import Finding

_TABLE_ANCHOR = "**Kernel dispatch.**"


@dataclasses.dataclass(frozen=True)
class Row:
    """One §7 table row: the chain-pattern cell and the kernel cell,
    markdown unescaped (`\\|` -> `|`, backticks stripped)."""
    chain: str
    kernel: str


def _clean(cell: str) -> str:
    return cell.replace("\\|", "|").replace("`", "").strip()


def parse_dispatch_table(text: str) -> list:
    """Extract the kernel-dispatch rows from DESIGN.md §7 (or any text
    holding the anchored markdown table)."""
    if _TABLE_ANCHOR not in text:
        return []
    body = text.split(_TABLE_ANCHOR, 1)[1]
    rows = []
    for line in body.splitlines():
        line = line.strip()
        if rows and not line.startswith("|"):
            break                              # table ended
        if not line.startswith("|"):
            continue
        # split on unescaped pipes only (`\|` is a literal in-cell pipe)
        cells = [_clean(c) for c in re.split(r"(?<!\\)\|", line)[1:-1]]
        if len(cells) != 2 or not cells[0] or \
                set(cells[0]) <= {"-", " "} or cells[0].lower() == "chain":
            continue                           # header / separator
        rows.append(Row(cells[0], cells[1]))
    return rows


# Row-pattern -> representative probe chains.  Classification keys off
# the chain cell's CONTENT so wording tweaks don't break the parser;
# an unclassifiable row is itself a finding (the probe map must grow
# with the table).
def _probes_for(chain: str):
    c = chain.lower()
    if "anything else" in c:
        return ("rel:0.001|pack:8|zero|narrow",
                "abs:0.001|pack:32|shuffle|narrow")
    if c.startswith("pred"):
        return ("delta|abs:0.001|pack:16",)
    if "narrow|ent" in c:
        return ("abs:0.001|pack:16|narrow|ent",)
    if "zero" in c or "narrow" in c:
        return ("abs:0.001|pack:16|zero", "abs:0.001|pack:16|narrow")
    if c.replace(" ", "") == "quant|pack":
        return ("abs:0.001|pack:16",)
    return None


def _expected_from(kernel: str):
    """The kernel cell's claim: None for open slots / jit reference,
    else `kernels/x.py::fn` as the dotted `kernel_dispatch` name."""
    k = kernel.lower()
    if "open slot" in k or "jit reference" in k:
        return None
    m = re.search(r"kernels/(\w+)\.py::(\w+)", kernel)
    if not m:
        return f"<unparseable: {kernel}>"
    return f"repro.kernels.{m.group(1)}.{m.group(2)}"


def check_dispatch(rows, *, path: str = "DESIGN.md") -> list:
    """Probe each table row against the real `kernel_dispatch`.  Pure
    parse + dataclass dispatch — no devices touched."""
    from repro.core.pipeline import parse_pipeline

    findings = []
    if not rows:
        return [Finding(
            "RC005", path, 1,
            "the §7 kernel-dispatch table is missing (or lost its "
            "anchor)", "restore the '**Kernel dispatch.**' table")]
    seen = set()
    for row in rows:
        probes = _probes_for(row.chain)
        if probes is None:
            findings.append(Finding(
                "RC005", path, 1,
                f"dispatch-table row {row.chain!r} has no probe "
                f"mapping", "extend analysis/dispatch.py's probe "
                "classifier with the new row's representative chains"))
            continue
        seen.add(probes)
        expected = _expected_from(row.kernel)
        if isinstance(expected, str) and expected.startswith("<"):
            findings.append(Finding(
                "RC005", path, 1,
                f"dispatch-table row {row.chain!r} claims an "
                f"unparseable kernel {row.kernel!r}",
                "use kernels/<file>.py::<fn>, 'open slot', or "
                "'jit reference'"))
            continue
        for spec in probes:
            actual = parse_pipeline(spec).kernel_dispatch()
            if actual != expected:
                findings.append(Finding(
                    "RC005", path, 1,
                    f"§7 dispatch table desync: row {row.chain!r} "
                    f"claims {expected or 'jit reference'} but "
                    f"kernel_dispatch({spec!r}) routes to "
                    f"{actual or 'jit reference'}",
                    "update the table row (or kernel_dispatch) so doc "
                    "and code agree"))
    if len(seen) < 5:
        findings.append(Finding(
            "RC005", path, 1,
            f"§7 dispatch table covers only {len(seen)} of the 5 "
            f"routing classes (pack / lossless / ent slot / pred slot "
            f"/ reference)", "restore the missing rows"))
    return findings
