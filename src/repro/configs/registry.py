"""The 10 assigned architectures (exact public configs) and the named
compression-pipeline presets.

Sources are cited per entry ([arXiv/hf; verification tier] from the
assignment).  `get(name)` is the single lookup used by launchers, smoke
tests, dry-run, and benchmarks (--arch <id>); `get_pipeline(name)` is
the same single lookup for pipeline specs (DESIGN.md §7) — benchmarks'
`--pipeline` accepts either a preset name or a raw spec string.
"""
from __future__ import annotations

from .base import ArchConfig

ARCHS = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


internlm2_20b = _reg(ArchConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92544,
    source="arXiv:2403.17297; hf"))

stablelm_3b = _reg(ArchConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912, vocab=50304,
    source="hf:stabilityai/stablelm-2-1_6b; unverified"))

chatglm3_6b = _reg(ArchConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024, rope="partial",
    source="arXiv:2406.12793; hf (2d-RoPE -> rotary on half the head dim)"))

deepseek_67b = _reg(ArchConfig(
    name="deepseek-67b", family="dense", n_layers=95, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=102400,
    source="arXiv:2401.02954; hf (llama-arch)"))

chameleon_34b = _reg(ArchConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536,
    source="arXiv:2405.09818; unverified (early fusion: VQ image tokens "
           "share the text vocab; frontend stub = token ids)"))

whisper_base = _reg(ArchConfig(
    name="whisper-base", family="encdec", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865, enc_layers=6,
    enc_context=1500, act="gelu", rope="none",
    source="arXiv:2212.04356; unverified (conv frontend stubbed: "
           "input_specs() provides precomputed frame embeddings)"))

olmoe_1b_7b = _reg(ArchConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    moe_experts=64, moe_top_k=8,
    source="arXiv:2409.02060; hf"))

qwen3_moe_235b = _reg(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936,
    moe_experts=128, moe_top_k=8, head_dim=128,
    source="hf:Qwen/Qwen3-30B-A3B; hf"))

jamba_1_5_large = _reg(ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
    moe_experts=16, moe_top_k=2, moe_every=2, attn_period=8,
    source="arXiv:2403.19887; hf (Mamba+attn 1:7, MoE every 2nd layer)"))

xlstm_350m = _reg(ArchConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    source="arXiv:2405.04517; unverified (alternating mLSTM/sLSTM blocks)"))


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def all_archs():
    return dict(ARCHS)


# --------------------------------------------------- pipeline presets -----
#
# Named specs for the common chains (DESIGN.md §7).  The gradient-wire
# presets use eb=1 as a placeholder — compression/grads.py overrides it
# with the traced per-tensor bound eb_rel * rms(g) at encode time.

PIPELINES = {
    # gradient all-reduce wires (cap = 1/64, GradCompressionConfig default)
    "grad-wire-8": "abs:1.0:cap=0.015625|pack:8",
    "grad-wire-8-narrow": "abs:1.0:cap=0.015625|pack:8|narrow",
    "grad-wire-16-zero": "abs:1.0:cap=0.015625|pack:16|zero",
    "grad-wire-16-narrow": "abs:1.0:cap=0.015625|pack:16|narrow",
    # entropy-coded gradient wire (§7 `ent`: canonical codebook over the
    # bytes of the chunks that survive narrow)
    "grad-wire-16-ent": "abs:1.0:cap=0.015625|pack:16|narrow|ent",
    # closed-loop predictor gradient wire (§9 `delta` residuals ahead of
    # the quantizer; never ring-reduces — the §8 gather path moves it)
    "grad-wire-pred": "delta|abs:1.0:cap=0.015625|pack:16|narrow|ent",
    # scientific-data archival-grade device chains (paper eval bound 1e-3)
    "sci-abs-narrow": "abs:0.001|pack:32|narrow",
    "sci-rel-narrow": "rel:0.001|pack:32|narrow",
    "sci-rel-shuffle": "rel:0.001|pack:32|shuffle|narrow",
    "sci-rel-ent": "rel:0.001|pack:32|shuffle|narrow|ent",
    # 2-D Lorenzo predictor chain for plane-structured suites (§9; pass
    # pred_shape / a 2-D tensor so the plane structure reaches the stage)
    "sci-lorenzo-ent": "lorenzo|abs:0.001|pack:32|narrow|ent",
    # KV-page migration chain (§9 `kvdelta`): the per-page stage fragment
    # is everything after the quantizer spec — pack_kv re-quantizes with
    # its own per-page bound, so the eb here is a placeholder
    "kv-delta": "kvdelta|abs:1.0|pack:8|zero|narrow",
    # the full chain exercised by CI's smoke step
    "smoke-chain": "rel:0.001|pack:8|zero|narrow",
}


def get_pipeline(name: str) -> str:
    """Resolve a preset name OR pass through a raw spec ('|' present)."""
    if name in PIPELINES:
        return PIPELINES[name]
    if "|" in name:
        return name
    raise KeyError(f"unknown pipeline preset {name!r}; have "
                   f"{sorted(PIPELINES)} (or pass a '|'-spec)")


# Per-page KV wire chains for the decode engine and cache migration
# (DESIGN.md §10): fragments of the two-domain grammar applied per page —
# optional §9 pred stages, then word stages.  These are NOT full pipeline
# specs (the quantizer lives in kv_quantizer_config, per page); they feed
# `pack_cache(..., stages=)` / `DecodeEngine(stages=)`.
KV_PAGE_CHAINS = {
    # default engine hand-off: drop the unwritten tail of mid-decode
    # caches (zero chunks), nothing else on the latency path
    "kv-page": "zero",
    # narrow the surviving chunks too — smaller eviction/migration wires
    "kv-page-narrow": "zero|narrow",
    # §9 kvdelta residuals ahead of the per-page coder: correlated KV
    # rows ship near-zero planes (the PR 6 transfer-proof chain)
    "kv-page-pred": "kvdelta|zero|narrow",
}


def get_kv_chain(name: str) -> str:
    """Resolve a KV page-chain preset OR pass through a raw fragment.
    'auto' / 'auto:SET' specs (DESIGN.md §11) pass through verbatim —
    `compression/kv.py` resolves them to a per-page `KVSelector`."""
    if name in KV_PAGE_CHAINS:
        return KV_PAGE_CHAINS[name]
    if name == "auto" or name.startswith("auto:"):
        return name
    if "|" in name or name in ("", "zero", "narrow"):
        return name
    raise KeyError(f"unknown KV page chain {name!r}; have "
                   f"{sorted(KV_PAGE_CHAINS)} (or pass a stage fragment)")


# ------------------------------------------------- selector preset sets ---
#
# Candidate sets for the adaptive chain selector (DESIGN.md §11).  Each
# entry names a BASE quantizer+pack spec shared by every candidate and
# the candidate stage fragments (optional §9 pred prefix + word stages);
# `base: None` marks a KV page-fragment set (the quantizer lives in the
# per-page KV bound — resolved by `core.select.get_kv_selector`).
# `bias` is the autotuner's measured-vs-estimated calibration in bits
# per 1024 words, one entry per candidate.
#
# Between the AUTOTUNED markers, the `bias` tuples are REWRITTEN by
# `benchmarks/autotune.py --write` (measured-vs-estimated calibration);
# edit chain membership freely, but bias values come from measurement.

# --- AUTOTUNED BEGIN (benchmarks/autotune.py rewrites the bias values) ---
SELECTOR_SETS = {
    # gradient all-reduce wires: plain through pred+entropy — the eb is
    # a placeholder like the grad-wire presets (grads.py overrides it
    # with the traced per-tensor bound at encode time)
    "grad-wire": {
        "base": "abs:0.001:cap=0.015625|pack:16",
        "chains": ("", "zero", "narrow", "narrow|ent",
                   "delta|narrow|ent"),
        "bias": (0, 0, 0, 24.119, 30.48),
    },
    # plane-structured scientific fields (the NYX-like plane bound the
    # lossless bench uses); lorenzo needs a 2-D pred_shape to fire
    "sci-plane": {
        "base": "abs:64.0:cap=0.015625|pack:32",
        "chains": ("", "narrow", "narrow|ent", "lorenzo|narrow|ent"),
        "bias": (0, 0, 4.297, 8.176),
    },
    # per-page KV cache fragments (engine eviction / migration wires)
    "kv-page": {
        "base": None,
        "chains": ("zero", "zero|narrow", "kvdelta|zero|narrow"),
        "bias": (0, 0, 0),
    },
}
# --- AUTOTUNED END ---
