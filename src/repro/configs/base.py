"""Architecture + run-shape configuration.

One ArchConfig per assigned architecture (exact public numbers, see the
per-arch files) plus `reduced()` for CPU smoke tests.  ShapeConfig carries
the four assigned input shapes; `runnable()` encodes the skip rules
(long_500k only for sub-quadratic families — see ShapeConfig.runnable).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

FAMILIES = ("dense", "moe", "hybrid", "ssm", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # see FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1            # MoE FFN every k-th layer (jamba: 2)
    # hybrid (jamba): one attention layer per `attn_period` layers
    attn_period: int = 0
    ssm_state: int = 16           # mamba d_state
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_context: int = 1500       # stubbed frame-embedding length
    # rotary style: 'full' | 'partial' (chatglm 2d-rope: half the head dim)
    rope: str = "full"
    norm_eps: float = 1e-5
    act: str = "swiglu"           # 'swiglu' | 'gelu' (whisper)
    source: str = ""              # provenance note [paper/hf; tier]

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("hybrid", "ssm")

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 256 multiple so the embedding/logits can
        shard over the 16-way model axis (whisper's 51865 is odd)."""
        return (self.vocab + 255) // 256 * 256

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (one real step)."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.attn_period == 0
                         else self.attn_period),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads,
                                  4 // max(1, self.group_size))),
            head_dim=32,
            d_ff=256,
            vocab=512,
            moe_experts=min(self.moe_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            enc_layers=min(self.enc_layers, 2),
            enc_context=64,
            ssm_state=8,
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once; used for the
        roofline MODEL_FLOPS = 6*N*D term)."""
        d, hd, f = self.d_model, self.head_dim, self.d_ff
        attn = d * (self.n_heads * hd) * 2 + d * (2 * self.n_kv_heads * hd)
        dense_ffn = (3 if self.act == 'swiglu' else 2) * d * f
        if self.family == "moe":
            moe_ffn = 3 * d * f * self.moe_experts
            per_layer = attn + moe_ffn + d * self.moe_experts + 2 * d
            n = self.n_layers * per_layer
        elif self.family == "hybrid":
            n = 0
            for i in range(self.n_layers):
                is_attn = (i % self.attn_period) == self.attn_period - 1
                block = attn if is_attn else self._mamba_params()
                ffn = (3 * d * f * self.moe_experts + d * self.moe_experts
                       if (i % self.moe_every) == self.moe_every - 1
                       else dense_ffn)
                n += block + ffn + 2 * d
        elif self.family == "ssm":
            n = self.n_layers * self._xlstm_params()
        elif self.family == "encdec":
            dec = self.n_layers * (2 * attn + dense_ffn + 3 * d)
            enc = self.enc_layers * (attn + dense_ffn + 2 * d)
            n = dec + enc + (self.enc_context + 32_768) * d  # pos embeddings
        else:  # dense / vlm
            n = self.n_layers * (attn + dense_ffn + 2 * d)
        return n + self.vocab * d

    def active_param_count(self) -> int:
        """MoE: only top-k experts count toward step FLOPs."""
        if self.moe_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        if self.family == "moe":
            inactive = (self.n_layers * 3 * d * f
                        * (self.moe_experts - self.moe_top_k))
        else:  # hybrid
            n_moe = sum(1 for i in range(self.n_layers)
                        if (i % self.moe_every) == self.moe_every - 1)
            inactive = n_moe * 3 * d * f * (self.moe_experts - self.moe_top_k)
        return full - inactive

    def _mamba_params(self) -> int:
        # mirrors models/mamba.py::mamba_params_shape
        d = self.d_model
        n = self.ssm_state
        di = 2 * d
        return (d * 2 * di            # in_proj
                + 4 * di              # conv
                + di * n + di         # a_log, d_skip
                + di * 2 * n          # bc_proj
                + di * di + di        # dt_proj, dt_bias
                + di * d)             # out_proj

    def _xlstm_params(self) -> int:
        # mirrors models/xlstm.py param shapes: one mLSTM + one sLSTM pair
        d, h = self.d_model, self.n_heads
        di = 2 * d
        dh = di // h
        mlstm = d * 2 * di + di * 3 * di + di * 3 * h + di * d
        slstm = d * 2 * di + di * 4 * di + h * dh * 4 * dh + di * d
        return (mlstm + slstm + 2 * d) // 2   # per layer (pairs counted /2)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def runnable(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """Assignment skip rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False
    return True
