"""repro.configs"""
