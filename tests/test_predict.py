"""Closed-loop predictor stages (DESIGN.md §9): the value-domain half of
the two-domain pipeline grammar.

The §1 guarantee proof strategy: the pred stages are exact integer
bijections on the quantized bin plane, so a pred chain's decode must be
BIT-IDENTICAL to its pred-free twin — every bound/special-value property
already proven for the twin is inherited, and any single differing bit
is a regression.  On top of that:

  * the vectorized stages are pinned bit-identical to `scan_reference`,
    the literal per-element reconstruction-feedback loop the paper
    describes (predict from the decoder's view, feed the decoded
    residual back) — recon == bins IS closed-loop exactness;
  * an OPEN-loop delta (predict from the raw input) demonstrably breaks
    the bound on a drifting ramp — the regression the paper's lesson
    warns about;
  * a hypothesis property runs every predictor x ABS/REL x f32/f64 over
    awkward shapes (n=1, single-row/column planes, batched 3-D);
  * wire accounting: pred stages ship zero header bits, and
    `wire_bits`/`stage_report`/KV `wire_bytes` agree exactly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import GRAMMAR, QuantizerConfig, codec, oracle_np as onp
from repro.core import predict as P
from repro.core.pipeline import parse_pipeline
from repro.core.quantizer import (dequantize_abs, dequantize_rel,
                                  quantize_abs, quantize_rel)

RNG = np.random.default_rng(97)

PRED_SPECS = ["delta", "lorenzo", "kvdelta"]


def _mix(n):
    x = (RNG.standard_normal(n) * 3e-3).astype(np.float32)
    x[RNG.random(n) < 0.5] = 0.0
    if n >= 8:
        x[:8] = [np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-42,
                 np.finfo(np.float32).max, 5e-4]
    return x


def _smooth_plane(rows, cols, scale=1.0):
    y, x = np.mgrid[0:rows, 0:cols]
    f = np.sin(x / 9.0) * np.cos(y / 7.0) + 0.03 * RNG.standard_normal(
        (rows, cols))
    return (scale * f).astype(np.float32)


# ----------------------------------------- bit-identity to pred-free twin --

@pytest.mark.parametrize("pred", PRED_SPECS)
@pytest.mark.parametrize("tail", ["", "|zero", "|narrow", "|narrow|ent"])
def test_pred_decode_bit_identical_to_pred_free_twin(pred, tail):
    """The §9 invariant: inserting any pred stage changes the wire, never
    the decoded bits — so every §1 property of the twin is inherited."""
    n = 40_000
    x = jnp.asarray(_mix(n))
    base = f"abs:0.001|pack:16{tail}"
    twin = parse_pipeline(base)
    pipe = parse_pipeline(f"{pred}|{base}")
    y0 = np.asarray(twin.decode(twin.encode(x, kernels=False), n=n,
                                kernels=False))
    y1 = np.asarray(pipe.decode(pipe.encode(x, kernels=False), n=n,
                                kernels=False))
    np.testing.assert_array_equal(y0.view(np.uint32), y1.view(np.uint32))


def test_pred_stages_compose_and_roundtrip():
    """Two pred stages chain in spec order and invert in reverse order."""
    n = 12_000
    x = jnp.asarray(_mix(n))
    pipe = parse_pipeline("delta|kvdelta|abs:0.001|pack:16|narrow")
    assert [p.spec() for p in pipe.pred] == ["delta", "kvdelta"]
    assert parse_pipeline(pipe.spec()) == pipe
    twin = parse_pipeline("abs:0.001|pack:16|narrow")
    y0 = np.asarray(twin.decode(twin.encode(x, kernels=False), n=n,
                                kernels=False))
    y1 = np.asarray(pipe.decode(pipe.encode(x, kernels=False), n=n,
                                kernels=False))
    np.testing.assert_array_equal(y0.view(np.uint32), y1.view(np.uint32))


def test_pred_chain_matches_numpy_oracle():
    """§1 proof via the host oracle: the decoded stream of a pred chain
    equals the numpy quantizer's reconstruction on non-outliers and the
    bound holds on every finite element."""
    n = 30_000
    x = _mix(n)
    pipe = parse_pipeline("delta|abs:0.001|pack:16|narrow")
    bins, outlier, recon = onp.quantize_abs(x, pipe.qcfg())
    y = np.asarray(pipe.roundtrip(jnp.asarray(x), kernels=False))
    fin = np.isfinite(x)
    keep = fin & ~outlier
    np.testing.assert_array_equal(
        y[keep].view(np.uint32),
        recon[keep].astype(np.float32).view(np.uint32))
    assert np.abs(x[fin].astype(np.float64) - y[fin]).max() <= 1e-3
    np.testing.assert_array_equal(x[~fin].view(np.uint32),
                                  y[~fin].view(np.uint32))


# --------------------------------------------- scan-reference bit parity ---

@pytest.mark.parametrize("shape", [(31,), (1,), (7, 9), (1, 13), (13, 1),
                                   (3, 5, 8)])
@pytest.mark.parametrize("bits", [8, 16, 32])
@pytest.mark.parametrize("pred", PRED_SPECS)
def test_vectorized_stage_matches_reconstruction_feedback_scan(
        pred, bits, shape):
    """The vectorized bin-domain stages must be bit-identical to the
    literal per-element closed-loop scan, and the scan's running
    reconstruction must equal the true bins (closed-loop exactness)."""
    (stage,) = P.parse_pred_stages(pred)
    n = int(np.prod(shape))
    maxbin = (1 << (bits - 1)) - 1 if bits < 32 else (1 << 23)
    bins = jnp.asarray(RNG.integers(-maxbin, maxbin + 1, n, dtype=np.int64),
                       jnp.int32)
    codes = np.asarray(stage.encode_bins(bins, shape, bits))
    ref_codes, ref_recon = P.scan_reference(stage, np.asarray(bins), shape,
                                            bits)
    np.testing.assert_array_equal(codes, ref_codes)
    np.testing.assert_array_equal(ref_recon, np.asarray(bins))
    back = np.asarray(stage.decode_bins(jnp.asarray(codes), shape, bits))
    np.testing.assert_array_equal(back, np.asarray(bins))


def test_fold_unfold_is_a_bijection_at_every_width():
    for bits in (8, 16, 32):
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        span = np.arange(lo, hi + 1, dtype=np.int64) if bits == 8 else \
            RNG.integers(lo, hi + 1, 4096, dtype=np.int64)
        d = jnp.asarray(span, jnp.int32)
        z = P._fold(d, bits)
        np.testing.assert_array_equal(np.asarray(P._unfold(z, bits)),
                                      np.asarray(d))


# ------------------------------------------------- open-loop regression ----

def test_open_loop_delta_violates_bound_on_drifting_ramp():
    """The paper's central lesson, as a failing construction: predict
    from the RAW previous value and each per-step residual quantizes to
    zero on a slow ramp — the reconstruction never moves while the input
    drifts without bound.  The closed-loop chain on the same input holds
    the bound exactly."""
    eb = 1e-3
    n = 4096
    x = (np.arange(n, dtype=np.float64) * 0.9 * eb).astype(np.float32)

    # open loop: residual vs the raw neighbour, quantized independently
    d = np.diff(x.astype(np.float64), prepend=0.0)
    bins = np.rint(d / (2 * eb))
    y_open = np.cumsum(bins * 2 * eb)
    assert np.abs(x.astype(np.float64) - y_open).max() > 100 * eb

    # closed loop (§9): the same data through the delta chain holds §1
    pipe = parse_pipeline(f"delta|abs:{eb!r}|pack:16")
    y = np.asarray(pipe.roundtrip(jnp.asarray(x), kernels=False))
    assert np.abs(x.astype(np.float64) - y).max() <= eb


# ------------------------------------------------------ hypothesis sweep ---

SHAPES = [(1,), (7,), (97,), (1, 9), (9, 1), (8, 16), (2, 5, 6)]


def _roundtrip_holds(pred, mode, dtype, eb, x):
    """One closed-loop roundtrip check, shared by the hypothesis property
    and the deterministic sweep.  float32 runs the full packed pipeline;
    float64 runs the value-domain path (quantize -> pred bijection ->
    pack/unpack words -> inverse -> dequantize) because the packed wire's
    exact-outlier payload is a uint32 plane (f32-only) — the pred stages
    themselves are dtype-blind bin bijections either way."""
    shape, n = x.shape, x.size
    xf = x.astype(np.float64).reshape(-1)
    if dtype == "float32":
        spec = f"{pred}|{mode}:{eb!r}|pack:16"
        pipe = parse_pipeline(spec)
        y = np.asarray(pipe.roundtrip(jnp.asarray(x), kernels=False))
        twin = parse_pipeline(f"{mode}:{eb!r}|pack:16")
        y0 = np.asarray(twin.roundtrip(jnp.asarray(x), kernels=False))
        np.testing.assert_array_equal(y.view(np.uint32), y0.view(np.uint32))
        yf = y.astype(np.float64).reshape(-1)
        fin = np.isfinite(xf)
    else:
        cfg = QuantizerConfig(mode=mode, error_bound=eb, bin_bits=16,
                              dtype=dtype)
        q = (quantize_abs if mode == "abs" else quantize_rel)(
            jnp.asarray(x.reshape(-1)), cfg)
        stages = P.parse_pred_stages(pred)
        codes = P.encode_pred_stages(stages, q.bins, shape, 16)
        words = codec.pack_words(codes, 16)
        back = P.decode_pred_stages(stages,
                                    codec.unpack_words(words, n, 16),
                                    shape, 16)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q.bins))
        if mode == "abs":
            y = dequantize_abs(back, cfg)
        else:
            y = dequantize_rel(back, q.sign, cfg)
        yf = np.asarray(y, np.float64).reshape(-1)
        fin = ~np.asarray(q.outlier).reshape(-1)   # outliers ride separately
    if mode == "abs":
        assert np.abs(xf[fin] - yf[fin]).max() <= eb
    else:
        assert np.abs((xf[fin] - yf[fin]) / xf[fin]).max() <= eb


def test_closed_loop_roundtrip_property():
    pytest.importorskip("hypothesis")   # optional dev dep
    from hypothesis import given, settings, strategies as st

    x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        @settings(max_examples=120, deadline=None)
        @given(st.data())
        def run(data):
            pred = data.draw(st.sampled_from(PRED_SPECS))
            mode = data.draw(st.sampled_from(["abs", "rel"]))
            dtype = data.draw(st.sampled_from(["float32", "float64"]))
            shape = data.draw(st.sampled_from(SHAPES))
            eb = data.draw(st.sampled_from([1e-3, 1e-2]))
            n = int(np.prod(shape))
            vals = data.draw(st.lists(
                st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False,
                          width=32), min_size=n, max_size=n))
            x = np.asarray(vals, dtype).reshape(shape)
            if mode == "rel":            # REL bound is undefined at 0
                x = np.where(np.abs(x) < 1e-6, 1e-6, x).astype(dtype)
            _roundtrip_holds(pred, mode, dtype, eb, x)

        run()
    finally:
        jax.config.update("jax_enable_x64", x64)


@pytest.mark.parametrize("pred", PRED_SPECS)
@pytest.mark.parametrize("mode", ["abs", "rel"])
@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_closed_loop_roundtrip_deterministic_sweep(pred, mode, dtype):
    """Deterministic twin of the hypothesis property (hypothesis is an
    optional dev dep): every predictor x ABS/REL x f32/f64 over the
    awkward shapes, bound + bit-identity to the pred-free twin."""
    x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        eb = 1e-3
        for shape in SHAPES:
            n = int(np.prod(shape))
            x = (RNG.standard_normal(n) * 2.0).astype(dtype).reshape(shape)
            if mode == "rel":            # REL bound is undefined at 0
                x = np.where(np.abs(x) < 1e-6, 1e-6, x).astype(dtype)
            _roundtrip_holds(pred, mode, dtype, eb, x)
    finally:
        jax.config.update("jax_enable_x64", x64)


@pytest.mark.parametrize("pred", PRED_SPECS)
def test_f64_value_domain_route_holds_sub_f32_bound(pred):
    """Pin the f64 predictor route explicitly (the PR 6 gotcha: the packed
    wire's exact-outlier payload is a uint32 plane, so full packed-Pipeline
    roundtrips are f32-only — f64 streams take the value-domain path
    quantize -> pred bijection -> pack_words -> inverse -> dequantize).
    The bound here, 2**-30 on O(1) values, is STRICTLY below f32 spacing
    at 1.0 (2**-23): only a genuinely 64-bit route can pass."""
    eb = 2.0 ** -30
    assert eb < np.spacing(np.float32(1.0))    # sub-f32-resolution bound
    x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        n = 4096
        x = (1.0 + RNG.random(n)).astype(np.float64)      # O(1), in [1, 2)
        cfg = QuantizerConfig(mode="abs", error_bound=eb, bin_bits=32,
                              dtype="float64")
        q = quantize_abs(jnp.asarray(x), cfg)
        assert not bool(np.asarray(q.outlier).any())
        stages = P.parse_pred_stages(pred)
        codes = P.encode_pred_stages(stages, q.bins, (n,), 32)
        words = codec.pack_words(codes, 32)
        back = P.decode_pred_stages(stages,
                                    codec.unpack_words(words, n, 32),
                                    (n,), 32)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q.bins))
        y = np.asarray(dequantize_abs(back, cfg))
        assert y.dtype == np.float64
        assert np.abs(x - y).max() <= eb
        # the same data through f32 cannot meet this bound — the route
        # being tested is doing real 64-bit work, not riding f32 luck
        assert np.abs(x - x.astype(np.float32).astype(np.float64)
                      ).max() > eb
    finally:
        jax.config.update("jax_enable_x64", x64)


# --------------------------------------------------- dispatch + jit/shmap --

def test_pred_chain_dispatches_to_jit_reference():
    """kernel_dispatch must return None for pred chains (the §7 table's
    open slot) and the kernels=True path must fall back bit-identically."""
    pipe = parse_pipeline("delta|abs:0.01|pack:16|narrow")
    assert pipe.kernel_dispatch() is None
    x = jnp.asarray(_mix(30_000))
    a = pipe.encode(x, kernels=False)
    b = pipe.encode(x, kernels=True, interpret=True)   # falls back
    np.testing.assert_array_equal(np.asarray(a.payload),
                                  np.asarray(b.payload))
    ya = pipe.decode(a, n=x.size, kernels=False)
    yb = pipe.decode(b, n=x.size, kernels=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ya).view(np.uint32),
                                  np.asarray(yb).view(np.uint32))


def test_pred_chain_under_jit_holds_bound():
    n = 1 << 14
    x = _smooth_plane(128, 128, scale=0.5).reshape(-1)[:n]
    pipe = parse_pipeline("delta|abs:0.001|pack:16|narrow")
    f = jax.jit(lambda v: pipe.decode(pipe.encode(v, kernels=False), n=n,
                                      kernels=False))
    y = np.asarray(f(jnp.asarray(x)))
    assert np.abs(x.astype(np.float64) - y).max() <= 1e-3


def test_lorenzo_pred_shape_threads_through_encode_decode():
    """A 2-D tensor's plane structure must reach the stage whether the
    stream arrives 2-D (shape default) or flat (explicit pred_shape) —
    and both must decode to the same bits."""
    x2 = _smooth_plane(64, 96)
    pipe = parse_pipeline("lorenzo|abs:0.001|pack:16|narrow")
    enc2 = pipe.encode(jnp.asarray(x2), kernels=False)
    encf = pipe.encode(jnp.asarray(x2.reshape(-1)), kernels=False,
                       pred_shape=x2.shape)
    np.testing.assert_array_equal(np.asarray(enc2.payload),
                                  np.asarray(encf.payload))
    y = np.asarray(pipe.decode(enc2, shape=x2.shape, kernels=False))
    assert np.abs(x2.astype(np.float64) - y).max() <= 1e-3
    # a mismatched pred_shape must fail loudly, not mis-predict silently
    with pytest.raises(ValueError, match="pred_shape"):
        pipe.encode(jnp.asarray(x2), pred_shape=(7, 5), kernels=False)


def test_lorenzo_beats_plain_chain_on_smooth_plane():
    """The stage's reason to exist: on a smooth 2-D plane the folded
    Lorenzo residuals are far narrower than the raw bins."""
    x2 = jnp.asarray(_smooth_plane(256, 256))
    plain = parse_pipeline("abs:0.0001|pack:32|narrow|ent")
    lor = parse_pipeline("lorenzo|abs:0.0001|pack:32|narrow|ent")
    b0 = float(plain.wire_bits(plain.encode(x2, kernels=False), x2.size))
    b1 = float(lor.wire_bits(lor.encode(x2, kernels=False), x2.size))
    assert b1 < 0.75 * b0, (b0, b1)


# ----------------------------------------------------------- error paths ---

@pytest.mark.parametrize("bad", ["abs:0.001|pack:8|wavelet",
                                 "wavelet|abs:0.001|pack:8"])
def test_unknown_stage_error_names_both_domains_and_grammar(bad):
    """The parse error must teach the grammar: sorted registered names
    from BOTH domains plus the two-domain grammar string."""
    with pytest.raises(ValueError) as ei:
        parse_pipeline(bad)
    msg = str(ei.value)
    for name in ("delta", "kvdelta", "lorenzo",        # value domain
                 "ent", "narrow", "shuffle", "zero",   # word domain
                 "abs", "noa", "rel"):                 # quantizers
        assert name in msg, (name, msg)
    assert GRAMMAR in msg


def test_pred_stage_rejects_parameters():
    with pytest.raises(ValueError, match="takes no parameters"):
        parse_pipeline("delta:3|abs:0.001|pack:8")


def test_pred_stage_after_quantizer_is_rejected():
    with pytest.raises(ValueError):
        parse_pipeline("abs:0.001|delta|pack:8")


def test_register_pred_stage_extends_the_grammar():
    name = "_testpred"
    assert name not in P.PRED_STAGES
    P.register_pred_stage(name,
                          lambda nm, toks: P._parse_plain(nm, toks,
                                                          P.DeltaStage))
    try:
        pipe = parse_pipeline(f"{name}|abs:0.001|pack:16")
        assert pipe.pred == (P.DeltaStage(),)
    finally:
        del P.PRED_STAGES[name]


# ------------------------------------------------------- wire accounting ---

def test_pred_wire_accounting_is_bit_exact():
    """Pred stages ship ZERO header bits: wire_bits must equal the
    manual payload+header+table sum (the §9 accounting slot contributes
    its explicit 0), and stage_report's base row carries the pred specs."""
    n = 1 << 16
    x = np.zeros(n, np.float32)
    x[: n // 16] = RNG.standard_normal(n // 16).astype(np.float32) * 3e-3
    pipe = parse_pipeline("delta|abs:0.001|pack:16|narrow|ent")
    enc = pipe.encode(jnp.asarray(x), kernels=False)
    sizes = pipe.stage_sizes(n)
    hdr = sum(st.header_content_bits(sz)
              for st, sz in zip(pipe.stages, sizes[:-1]))
    hdr += sum(st.header_content_bits() for st in pipe.pred)   # == +0
    base = 64 + enc.out_idx.shape[0] * 64
    want = 32 * int(enc.payload_len) + hdr + 32 + base
    assert float(pipe.wire_bits(enc, n)) == want

    rows = pipe.stage_report(jnp.asarray(x))
    assert rows[1][0] == "delta|abs:0.001|pack:16"
    assert float(rows[-1][1]) == want

    # a static pred chain accounts identically to its pred-free twin
    twin = parse_pipeline("abs:0.001|pack:16")
    ppipe = parse_pipeline("delta|abs:0.001|pack:16")
    e0 = twin.encode(jnp.asarray(x), kernels=False)
    e1 = ppipe.encode(jnp.asarray(x), kernels=False)
    assert float(twin.wire_bits(e0, n)) == float(ppipe.wire_bits(e1, n))


def test_compression_ratio_threads_pred_shape():
    from repro.core import QuantizerConfig, compression_ratio
    x2 = _smooth_plane(128, 128)
    cfg = QuantizerConfig(mode="abs", error_bound=1e-4, bin_bits=32)
    plain = compression_ratio(x2, cfg, wire="device",
                              pipeline="abs:0.0001|pack:32|narrow|ent")
    lor = compression_ratio(x2, cfg, wire="device",
                            pipeline="lorenzo|abs:0.0001|pack:32|narrow|ent")
    assert lor > plain
    rows = compression_ratio(
        x2, cfg, wire="device",
        pipeline="lorenzo|abs:0.0001|pack:32|narrow", per_stage=True)
    assert rows[0][0] == "lorenzo|abs:0.0001|pack:32"
