"""Guarantee-audit plane (DESIGN.md §12): the acceptance pins.

  * Clean-path bit identity: `integrity=True` may not move one bit of
    any transmitted plane — the checksum rides as aux only.
  * Detection coverage: every `runtime.guard` fault class flips the
    checksum verdict on every wire shape (Encoded / SelectedWire /
    PackedKV, static and `auto`-selected), with zero false positives
    on clean wires.
  * `verify=` audit reports: clean encodes audit to zero violations
    (with TIGHTEN margin); non-finite inputs surface in n_nonfinite and
    never as violations.
  * Decode-side length validation: transmitted payload_len beyond the
    wire's capacity raises a structured `WireIntegrityError` host-side;
    truncated-but-consistent wires decode without crashing.
  * Degradation policies: 'raise' raises, `compressed_mean`'s 'drop'
    renormalizes a corrupted shard out of the mean (2-device
    subprocess), the engine's 'rerequest' refuses the insert and counts
    per-slot audit failures.
  * Special-value hardening (the §1 taxonomy): ABS/REL/NOA agree with
    the numpy oracle bit-for-bit on the full special-value sweep, the
    Pallas kernel wire is identical on it, and NaN payloads survive
    the roundtrip.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig, audit, oracle_np as onp
from repro.core.pipeline import parse_pipeline
from repro.core.quantizer import quantize_abs, quantize_noa, quantize_rel
from repro.core.select import get_kv_selector, get_selector, parse_selector
from repro.compression.kv import (kv_quantizer_config, pack_kv, quantize_kv,
                                  unpack_kv)
from repro.configs.registry import PIPELINES, SELECTOR_SETS, get_pipeline
from repro.runtime import guard

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import datasets  # noqa: E402

RNG = np.random.default_rng(41)


def _grad(n=1 << 16):
    return jnp.asarray(datasets.GRAD_SUITES["gradsmooth"]()[:n])


def _swap(wire, leaf, arr):
    flat, treedef = jax.tree_util.tree_flatten(wire)
    flat = [jnp.asarray(arr) if f is leaf else f for f in flat]
    return jax.tree_util.tree_unflatten(treedef, flat)


# ------------------------------------------------ clean-path bit identity --

def test_integrity_wire_is_bit_identical_to_plain_encode():
    """The checksum is aux: every transmitted plane of an
    integrity=True encode equals the checksum-free encode bit-for-bit,
    on a pipeline, a selector, and a KV pack."""
    x = _grad()
    pipe = parse_pipeline(get_pipeline("grad-wire-16-ent"))
    eb = float(jnp.sqrt(jnp.mean(x * x))) * 2.0 ** -8
    e0 = pipe.encode(x, eb=eb)
    e1 = pipe.encode(x, eb=eb, integrity=True)
    assert e0.checksum is None and e1.checksum is not None
    for a, b in zip(e0[:-1], e1[:-1]):          # all fields but checksum
        if a is None:
            assert b is None
            continue
        jax.tree.map(lambda p, q: np.testing.assert_array_equal(
            np.asarray(p), np.asarray(q)), a, b)

    sel = parse_selector("auto:grad-wire")
    w0 = sel.encode(x, eb=eb)
    w1 = sel.encode(x, eb=eb, integrity=True)
    assert w0.checksum is None and w1.checksum is not None
    np.testing.assert_array_equal(np.asarray(w0.payload),
                                  np.asarray(w1.payload))
    assert int(w0.chain_id) == int(w1.chain_id)

    q = quantize_kv(jnp.asarray(
        RNG.standard_normal((2, 2, 256, 64)).astype(np.float32)),
        kv_quantizer_config())
    p0 = pack_kv(q, stages="narrow")
    p1 = pack_kv(q, stages="narrow", integrity=True)
    np.testing.assert_array_equal(np.asarray(p0.payload),
                                  np.asarray(p1.payload))
    np.testing.assert_array_equal(np.asarray(p0.payload_len),
                                  np.asarray(p1.payload_len))


def test_checksum_survives_pytree_roundtrip_and_accounts_4_bytes():
    x = _grad()
    pipe = parse_pipeline("abs:0.001:cap=0.015625|pack:16|narrow")
    e0, e1 = pipe.encode(x), pipe.encode(x, integrity=True)
    leaves, treedef = jax.tree_util.tree_flatten(e1)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.checksum is not None and bool(audit.verify_wire(back))
    assert pipe.capacity_bytes(e1) == pipe.capacity_bytes(e0) + 4


# ---------------------------------------------------- detection coverage --

@pytest.mark.parametrize("preset", sorted(PIPELINES))
def test_fault_detection_on_every_pipeline_preset(preset):
    """Every applicable guard fault class must flip the checksum, and
    the clean wire must pass (detection_matrix asserts it)."""
    pipe = parse_pipeline(get_pipeline(preset))
    x = (jnp.asarray(datasets.rel_mixed()[:1 << 16])
         if pipe.quant.mode == "rel" else _grad())
    eb = (float(jnp.sqrt(jnp.mean(x * x))) * 2.0 ** -8
          if pipe.quant.eb == 1.0 else None)
    enc = pipe.encode(x, eb=eb, integrity=True)
    matrix = guard.detection_matrix(enc, suite=preset)
    assert set(matrix) == {"payload_bitflip", "header_bitflip",
                           "length_truncate"}
    assert all(matrix.values()), matrix
    plan = guard.FaultPlan(preset, "nan_input")
    _, rep = pipe.encode(plan.corrupt_input(x), eb=eb, verify=True)
    assert int(rep.n_nonfinite) > 0
    assert int(rep.violations) == 0      # non-finites route to outliers


def test_fault_detection_on_auto_selector_and_kv_wires():
    x = _grad()
    eb = float(jnp.sqrt(jnp.mean(x * x))) * 2.0 ** -8
    sel = get_selector("grad-wire")
    wire = sel.encode(x, eb=eb, integrity=True)
    m = guard.detection_matrix(
        wire, suite="grad-wire",
        n_chains=len(SELECTOR_SETS["grad-wire"]["chains"]))
    assert set(m) == {"payload_bitflip", "header_bitflip",
                      "length_truncate", "chainid_swap"}
    assert all(m.values()), m

    cache = RNG.standard_normal((2, 2, 512, 64)).astype(np.float32)
    cache[:, :, 300:, :] = 0.0
    q = quantize_kv(jnp.asarray(cache), kv_quantizer_config())
    p = pack_kv(q, stages=get_kv_selector("kv-page"), integrity=True)
    m = guard.detection_matrix(p, suite="kv-page", n_chains=3)
    assert "chainid_swap" in m and all(m.values()), m
    m = guard.detection_matrix(pack_kv(q, stages="narrow", integrity=True),
                               suite="kv-page")
    assert "chainid_swap" not in m and all(m.values()), m


def test_even_multiplicity_corruption_is_detected():
    """The fold avalanches (word, position) pairs: the same value change
    at an even number of positions must NOT cancel (a plain xor fold
    would pass it — e.g. every page's chain id bumping together)."""
    cache = RNG.standard_normal((2, 2, 512, 64)).astype(np.float32)
    q = quantize_kv(jnp.asarray(cache), kv_quantizer_config())
    p = pack_kv(q, stages=get_kv_selector("kv-page"), integrity=True)
    cid = np.asarray(p.chain_id)
    assert cid.size % 2 == 0
    bad = _swap(p, p.chain_id, (cid + 1) % 3)
    assert not bool(audit.verify_wire(bad))


def test_detection_matrix_requires_a_checksum():
    pipe = parse_pipeline("abs:0.001|pack:16")
    with pytest.raises(ValueError, match="integrity=True"):
        guard.detection_matrix(pipe.encode(_grad()))


# ------------------------------------------------------- verify= reports --

def test_audit_report_clean_encode_zero_violations():
    x = _grad()
    for spec in ("abs:0.001:cap=0.015625|pack:16|narrow",
                 "rel:0.001|pack:32|shuffle|narrow"):
        pipe = parse_pipeline(spec)
        data = (jnp.asarray(datasets.rel_mixed()[:1 << 16])
                if pipe.quant.mode == "rel" else x)
        enc, rep = pipe.encode(data, verify=True)
        assert int(rep.violations) == 0
        assert int(rep.n) == data.size
        bound = pipe.qcfg().error_bound
        assert float(rep.max_err) <= bound
        assert bool(rep.ok()) == (not bool(enc.overflow))


def test_audit_report_flags_nonfinite_never_violations():
    x = jnp.asarray(datasets.special_values())
    pipe = parse_pipeline("abs:0.001:cap=1.0|pack:16")
    _, rep = pipe.encode(x, verify=True)
    assert int(rep.n_nonfinite) > 0
    assert int(rep.violations) == 0
    assert int(rep.n_outliers) >= int(rep.n_nonfinite)


def test_audit_report_composes_with_jit_and_return_quantized():
    x = _grad()
    pipe = parse_pipeline("abs:0.001:cap=0.015625|pack:16|narrow")
    f = jax.jit(lambda v: pipe.encode(v, verify=True))
    enc, rep = f(x)
    assert int(rep.violations) == 0
    enc2, qt, rep2 = pipe.encode(x, verify=True, return_quantized=True)
    assert int(rep2.violations) == 0
    np.testing.assert_array_equal(np.asarray(enc.payload),
                                  np.asarray(enc2.payload))


def test_selector_encode_verify_and_kernels_warning():
    x = _grad()
    sel = parse_selector("auto:grad-wire")
    eb = float(jnp.sqrt(jnp.mean(x * x))) * 2.0 ** -8
    wire, rep = sel.encode(x, eb=eb, verify=True)
    assert int(rep.violations) == 0
    with pytest.warns(UserWarning, match="fused selector kernel"):
        sel.encode(x, eb=eb, kernels=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")    # no warning on the default path
        sel.encode(x, eb=eb)


# --------------------------------------------------- length validation ----

def test_overlong_payload_len_raises_structured_error():
    x = _grad()
    pipe = parse_pipeline("abs:0.001:cap=0.015625|pack:16|narrow")
    enc = pipe.encode(x)
    cap = enc.payload.shape[0]
    bad = _swap(enc, enc.payload_len,
                np.asarray(enc.payload_len) * 0 + cap + 7)
    with pytest.raises(audit.WireIntegrityError, match="payload_len"):
        pipe.decode(bad, n=x.size)

    cache = RNG.standard_normal((2, 2, 256, 64)).astype(np.float32)
    q = quantize_kv(jnp.asarray(cache), kv_quantizer_config())
    p = pack_kv(q, stages="narrow")
    plen = np.asarray(p.payload_len).copy()
    plen.flat[0] = p.payload.shape[-1] + 1
    with pytest.raises(audit.WireIntegrityError, match="PackedKV"):
        unpack_kv(_swap(p, p.payload_len, plen))


def test_truncated_wire_decodes_without_crash():
    """A truncated-but-consistent wire (half the words, zeroed tail) is
    in-capacity: decode must not crash or read out of bounds — the
    CHECKSUM is what flags the loss, not the decoder."""
    x = _grad()
    pipe = parse_pipeline("abs:0.001:cap=0.015625|pack:16|narrow")
    enc = pipe.encode(x, integrity=True)
    bad = guard.FaultPlan("t", "length_truncate").corrupt_wire(enc)
    y = pipe.decode(bad, n=x.size)               # no verify: must not raise
    assert np.asarray(y).shape == (x.size,)
    assert not bool(audit.verify_wire(bad))
    with pytest.raises(audit.WireIntegrityError, match="checksum"):
        pipe.decode(bad, n=x.size, verify=True)


def test_traced_decode_skips_host_length_check():
    x = _grad()
    pipe = parse_pipeline("abs:0.001:cap=0.015625|pack:16|narrow")
    enc = pipe.encode(x)
    y = jax.jit(lambda e: pipe.decode(e, n=x.size))(enc)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(pipe.decode(enc, n=x.size)))


# ------------------------------------------------- degradation policies ---

def test_policy_registry_raise_drop_rerequest():
    with pytest.raises(audit.WireIntegrityError, match="engine.insert"):
        audit.get_policy("raise")(dict(site="engine.insert"))
    assert audit.get_policy("drop")(dict()) == "drop"
    assert audit.get_policy("rerequest")(dict()) == "rerequest"
    with pytest.raises(KeyError):
        audit.get_policy("no-such-policy")
    audit.register_policy("test-noop", lambda ctx: "noop")
    try:
        assert audit.get_policy("test-noop")({}) == "noop"
    finally:
        del audit.DEGRADATION_POLICIES["test-noop"]


DROP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys
    sys.path.insert(0, ".")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compression.grads import (GradCompressionConfig,
                                         compress_shard, compressed_mean)
    from repro.core.transport import Transport
    from tests.conftest import shard_map_compat as smap

    mesh = jax.make_mesh((2,), ("pod",))
    cfg = GradCompressionConfig(eb_rel=2.0 ** -6, bin_bits=16)
    n = 8192
    rng = np.random.default_rng(9)
    g = jnp.asarray((rng.standard_normal((2, n)) * 3e-3)
                    .astype(np.float32))

    def corrupt_shard1(gathered):
        pay = gathered.payload
        return gathered._replace(
            payload=pay.at[1, 0].set(pay[1, 0] ^ jnp.uint32(1 << 9)))

    tp_clean = Transport()
    tp_bad = Transport(fault=corrupt_shard1)

    def run(tp):
        def body(gs):
            m, r = compressed_mean(gs.reshape(-1), cfg, "pod",
                                   transport=tp, integrity="drop")
            return m, r
        return jax.jit(smap(body, mesh, P("pod"), (P(), P("pod"))))(g)

    mean_clean, _ = run(tp_clean)

    # clean: integrity-drop mean == both-shard mean (no false drop)
    shard0, q0 = compress_shard(g[0], cfg)
    shard1, q1 = compress_shard(g[1], cfg)
    d0 = shard0.pipe.decode(shard0.enc, n=n, kernels=False)
    d1 = shard1.pipe.decode(shard1.enc, n=n, kernels=False)
    ref_both = (d0 + d1) / 2.0
    assert np.array_equal(np.asarray(mean_clean),
                          np.asarray(ref_both)), "clean drop-mean moved"
    print("CLEAN_OK")

    # corrupt shard 1 on the wire: mean renormalizes to shard 0 alone
    mean_bad, _ = run(tp_bad)
    assert np.array_equal(np.asarray(mean_bad), np.asarray(d0)), (
        "corrupt shard not dropped/renormalized")
    print("DROP_OK")
""")


@pytest.mark.slow
def test_compressed_mean_drop_renormalizes_corrupt_shard():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", DROP_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("CLEAN_OK", "DROP_OK"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr)


def test_engine_insert_rerequest_policy_and_stats():
    from repro.configs.base import ArchConfig
    from repro.models import build
    from repro.models import engine as E

    tiny = ArchConfig(name="tiny-audit", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab=512, head_dim=16)
    params = build(tiny).init(jax.random.PRNGKey(0))
    prompt = RNG.integers(0, tiny.vocab, size=150).astype(np.int32)

    eng = E.DecodeEngine(tiny, params, n_slots=2, seq=256,
                         integrity="rerequest")
    pre = eng.prefill(prompt)
    assert pre.pages.k.checksum is not None
    assert eng.insert(0, pre) is True
    st = eng.stats()
    assert st["audit_checks"] == 2 and st["audit_failures"] == 0
    assert st["slot_audit"][0] == dict(checks=2, failures=0)

    out = eng.evict(0)
    pay = np.asarray(out.pages.k.payload).copy()
    pay.flat[0] ^= 1
    bad = out._replace(pages=out.pages._replace(
        k=_swap(out.pages.k, out.pages.k.payload, pay)))
    assert eng.insert(0, bad) is False           # refused, slot stays free
    assert eng.requests[0] is None
    st = eng.stats()
    assert st["audit_failures"] == 1
    assert st["slot_audit"][0]["failures"] == 1

    with pytest.raises(KeyError):
        E.DecodeEngine(tiny, params, n_slots=1, seq=256, integrity="bogus")

    eng2 = E.DecodeEngine(tiny, params, n_slots=1, seq=256,
                          integrity="raise")
    bad2 = pre._replace(pages=pre.pages._replace(
        k=_swap(pre.pages.k, pre.pages.k.payload, pay)))
    with pytest.raises(audit.WireIntegrityError):
        eng2.insert(0, bad2)

    eng3 = E.DecodeEngine(tiny, params, n_slots=1, seq=256)
    pre3 = eng3.prefill(prompt)
    assert pre3.pages.k.checksum is None         # integrity off: unchanged
    assert eng3.insert(0, pre3) is True
    assert eng3.stats()["audit_checks"] == 0


# ------------------------------------------- special-value hardening (§1) --

def test_special_values_quantizer_oracle_agreement():
    """ABS / REL / NOA vs the numpy oracle, bit-for-bit, on the paper's
    special-value sweep (±Inf, NaN payloads, denormals, ±0.0)."""
    x = datasets.special_values()
    xj = jnp.asarray(x)

    cfg = QuantizerConfig(mode="abs", error_bound=1e-3)
    ja = quantize_abs(xj, cfg)
    ab, ao, ar = onp.quantize_abs(x, cfg)
    np.testing.assert_array_equal(np.asarray(ja.bins), ab)
    np.testing.assert_array_equal(np.asarray(ja.outlier), ao)
    np.testing.assert_array_equal(np.asarray(ja.recon).view(np.uint32),
                                  ar.view(np.uint32))

    cfgr = QuantizerConfig(mode="rel", error_bound=1e-3)
    jr = quantize_rel(xj, cfgr)
    rb, ro, rr, rsgn = onp.quantize_rel(x, cfgr)
    np.testing.assert_array_equal(np.asarray(jr.bins), rb)
    np.testing.assert_array_equal(np.asarray(jr.outlier), ro)
    np.testing.assert_array_equal(np.asarray(jr.sign), rsgn)

    # NOA: the sweep's finite range overflows f32 -> derived eb inf ->
    # EVERYTHING routes to the lossless outlier path, identically
    cfgn = QuantizerConfig(mode="noa", error_bound=1e-3)
    qn, ebn = quantize_noa(xj, cfgn)
    with np.errstate(over="ignore", invalid="ignore"):
        ob, oo, orr, oeb = onp.quantize_noa(x, cfgn)
    np.testing.assert_array_equal(np.asarray(qn.bins), ob)
    np.testing.assert_array_equal(np.asarray(qn.outlier), oo)
    assert float(ebn) == oeb
    assert bool(np.asarray(qn.outlier).all())


def test_special_values_pinned_classes():
    x = datasets.special_values()
    xj = jnp.asarray(x)
    neg0 = np.where(x.view(np.uint32) == np.uint32(0x80000000))[0]
    assert neg0.size > 0

    # ABS: -0.0 is bin 0, NOT an outlier (|x| <= eb trivially)
    ja = quantize_abs(xj, QuantizerConfig(mode="abs", error_bound=1e-3))
    assert (np.asarray(ja.bins)[neg0] == 0).all()
    assert not np.asarray(ja.outlier)[neg0].any()

    # REL: -0.0 is below the screen threshold -> outlier, and its
    # bit-pattern sign is NEGATIVE (parity with the oracle's int view)
    jr = quantize_rel(xj, QuantizerConfig(mode="rel", error_bound=1e-3))
    assert np.asarray(jr.outlier)[neg0].all()
    assert np.asarray(jr.sign)[neg0].all()


def test_special_values_roundtrip_preserves_nan_payloads_and_kernel_wire():
    x = datasets.special_values()
    xj = jnp.asarray(x)
    pipe = parse_pipeline("abs:0.001:cap=1.0|pack:16|narrow")
    ref = pipe.encode(xj, kernels=False)
    ker = pipe.encode(xj, kernels=True, interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(ker)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    y = np.asarray(pipe.decode(ref, n=x.size))
    nf = ~np.isfinite(x)
    np.testing.assert_array_equal(y[nf].view(np.uint32),
                                  x[nf].view(np.uint32))
    payload = np.where(x.view(np.uint32) == np.uint32(0x7FC00123))[0]
    assert payload.size > 0          # the sweep plants payload NaNs
    np.testing.assert_array_equal(y[payload].view(np.uint32),
                                  x[payload].view(np.uint32))


# ------------------------------------------ §12 in-flight hop integrity ---

def test_hop_bitflip_plan_is_not_a_stored_wire_fault():
    plan = guard.FaultPlan("ring", "hop_bitflip")
    enc = parse_pipeline("abs:0.001|pack:16").encode(_grad(1 << 12),
                                                     integrity=True)
    assert "hop_bitflip" in guard.FAULT_CLASSES
    assert "hop_bitflip" not in guard.applicable_classes(enc)
    with pytest.raises(AssertionError):
        plan.corrupt_wire(enc)
    # the in-graph hook is deterministic and hashable (Transport needs
    # a hashable fault for its frozen-dataclass identity)
    hash(plan.corrupt_hop)
    pay = jnp.zeros(64, jnp.uint32)
    a = np.asarray(plan.corrupt_hop((pay, jnp.uint32(0)))[0])
    b = np.asarray(plan.corrupt_hop((pay, jnp.uint32(0)))[0])
    np.testing.assert_array_equal(a, b)
    assert int(np.count_nonzero(a)) == 1     # exactly one flipped bit


def test_reduce_integrity_arg_validation():
    from repro.core.transport import TRANSPORT

    pipe = parse_pipeline("abs:0.001|pack:16")
    enc_plain = pipe.encode(_grad(1 << 12))
    enc_ck = pipe.encode(_grad(1 << 12), integrity=True)
    with pytest.raises(KeyError):
        TRANSPORT.reduce_mean(enc_ck, pipe, 1 << 12, "pod",
                              integrity="no-such-policy")
    with pytest.raises(ValueError, match="drop"):
        TRANSPORT.reduce_mean(enc_ck, pipe, 1 << 12, "pod",
                              integrity="raise")
    with pytest.raises(ValueError, match="integrity=True"):
        TRANSPORT.reduce_mean(enc_plain, pipe, 1 << 12, "pod",
                              integrity="drop")


RING_INTEGRITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compression.grads import GradCompressionConfig, compress_shard
    from repro.core.transport import TRANSPORT, Transport
    from repro.runtime.guard import FaultPlan

    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((2,), ("pod",))
    if hasattr(jax, "shard_map"):
        def smap(f):
            return jax.shard_map(f, mesh=mesh, in_specs=P("pod", None),
                                 out_specs=(P("pod", None), P("pod")),
                                 axis_names={"pod"}, check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        def smap(f):
            return _shard_map(f, mesh=mesh, in_specs=P("pod", None),
                              out_specs=(P("pod", None), P("pod")),
                              check_rep=False)

    # bin_bits=16 keeps the data outlier-free (range ~ +-5 >> the 1e-2
    # values) so the §8 ring genuinely fires — with outliers the compat
    # gate would fall back to gather and never exercise the hop digests
    cfg = GradCompressionConfig(eb_rel=2.0 ** -6, bin_bits=16,
                                outlier_cap_frac=1 / 16)
    pipe, n = cfg.pipe(), 4096

    def run(tp, g):
        def f(v):
            shard, _ = compress_shard(v, cfg, integrity=True)
            mean, nv = tp.reduce_mean(shard.enc, pipe, n, "pod",
                                      integrity="drop", return_valid=True)
            return mean, nv[None]
        gd = jax.device_put(jnp.asarray(g),
                            NamedSharding(mesh, P("pod", None)))
        mean, nv = jax.jit(smap(f))(gd)
        # the global mean comes back flat (p * n); fold to per-rank rows
        return np.asarray(mean).reshape(2, n), np.asarray(nv).tolist()

    r = np.random.default_rng(__import__("zlib").crc32(b"ring-hop-test"))
    g = np.broadcast_to((r.standard_normal(n) * 1e-2).astype(np.float32),
                        (2, n)).copy()

    # clean verified ring: every hop passes and the mean matches the
    # unchecked reduce bit-for-bit (identical shards -> the ring fires)
    mean_c, valid_c = run(TRANSPORT, g)
    assert valid_c == [2, 2], valid_c
    def ref(v):
        shard, _ = compress_shard(v, cfg, integrity=True)
        m = TRANSPORT.reduce_mean(shard.enc, pipe, n, "pod")
        nv = jax.lax.psum(jnp.int32(1), "pod")
        return m, nv[None]
    gd = jax.device_put(jnp.asarray(g), NamedSharding(mesh, P("pod", None)))
    mean_ref, _ = jax.jit(smap(ref))(gd)
    assert np.array_equal(mean_c.reshape(-1).view(np.uint32),
                          np.asarray(mean_ref).reshape(-1).view(np.uint32)), (
        "verified clean ring moved a bit vs the unchecked reduce")
    print("CLEAN_OK")

    # hop corruption: every received hop fails its owner digest, each
    # rank renormalizes down to its own contribution
    plan = FaultPlan("ring", "hop_bitflip")
    mean_f, valid_f = run(Transport(fault=plan.corrupt_hop), g)
    assert valid_f == [1, 1], valid_f
    shard0, _ = compress_shard(jnp.asarray(g[0]), cfg)
    assert int(shard0.enc.n_outliers) == 0, (
        "ring precondition broken: data has outliers, gather would fire")
    own = np.asarray(shard0.pipe.decode(shard0.enc, n=n, kernels=False))
    assert np.array_equal(mean_f[0].view(np.uint32), own.view(np.uint32)), (
        "rank 0's degraded mean is not its own decode")
    print("HOP_DROP_OK")
""")


@pytest.mark.slow
def test_ring_reduce_drops_corrupt_hops():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", RING_INTEGRITY_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("CLEAN_OK", "HOP_DROP_OK"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr)
