"""Cross-implementation parity: JAX (XLA:CPU) vs pure numpy — two
independent compiler stacks must produce bit-identical compressed output.
This is the testable analogue of the paper's CPU/GPU parity requirement
(see core/oracle_np.py docstring)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (QuantizerConfig, log2approx, pow2approx, quantize_abs,
                        quantize_rel)
from repro.core import oracle_np as onp

RNG = np.random.default_rng(7)


def bit_pattern_samples(n=1 << 16):
    """Uniform over the full uint32 bit space: hits every exponent class,
    denormals, NaN payloads, infinities."""
    return RNG.integers(0, 1 << 32, n, dtype=np.uint32).view(np.float32)


@pytest.mark.parametrize("eb", [1e-1, 1e-3, 1e-6])
def test_abs_parity_bit_patterns(eb):
    cfg = QuantizerConfig(mode="abs", error_bound=eb)
    x = bit_pattern_samples()
    jb = quantize_abs(jnp.asarray(x), cfg)
    nb, no, nr = onp.quantize_abs(x, cfg)
    np.testing.assert_array_equal(np.asarray(jb.bins), nb)
    np.testing.assert_array_equal(np.asarray(jb.outlier), no)
    np.testing.assert_array_equal(
        np.asarray(jb.recon).view(np.uint32), nr.view(np.uint32))


@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_rel_parity_bit_patterns(eb):
    cfg = QuantizerConfig(mode="rel", error_bound=eb, bin_bits=32)
    x = bit_pattern_samples()
    jb = quantize_rel(jnp.asarray(x), cfg)
    nb, no, nr, ns = onp.quantize_rel(x, cfg)
    np.testing.assert_array_equal(np.asarray(jb.bins), nb)
    np.testing.assert_array_equal(np.asarray(jb.outlier), no)
    np.testing.assert_array_equal(
        np.asarray(jb.recon).view(np.uint32), nr.view(np.uint32))
    np.testing.assert_array_equal(np.asarray(jb.sign), ns)


def test_log2_pow2_parity_exhaustive_exponents():
    """All 254 normal exponent classes x dense mantissa sample x both signs
    (for pow2: the full log range), bit-for-bit."""
    mant = RNG.integers(0, 1 << 23, 512, dtype=np.uint32)
    expo = np.arange(1, 255, dtype=np.uint32)  # normals
    bits = (expo[:, None] << 23 | mant[None, :]).ravel()
    x = bits.view(np.float32)
    np.testing.assert_array_equal(
        np.asarray(log2approx(jnp.asarray(x))).view(np.uint32),
        onp.log2approx(x).view(np.uint32))
    lg = onp.log2approx(x)
    np.testing.assert_array_equal(
        np.asarray(pow2approx(jnp.asarray(lg))).view(np.uint32),
        onp.pow2approx(lg).view(np.uint32))


def test_ftz_semantics_documented():
    """Pin the hazard the screens defend against: XLA:CPU flushes denormal
    results (FTZ) under jit while numpy keeps gradual underflow.  If this
    test ever fails (XLA stops flushing), the screens are merely
    conservative — the guarantee is unaffected."""
    import jax

    prod = jax.jit(lambda a, b: a * b)(jnp.float32(1e-20), jnp.float32(1e-20))
    assert float(prod) == 0.0            # XLA flushed 1e-40 to zero
    assert np.float32(1e-20) * np.float32(1e-20) != 0.0  # numpy kept it


@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_rel_parity_denormal_zone(eb):
    """The zone that originally broke parity: denormals and near-denormal
    normals must get identical outlier decisions on both stacks."""
    cfg = QuantizerConfig(mode="rel", error_bound=eb, bin_bits=32)
    mant = RNG.integers(0, 1 << 23, 2048, dtype=np.uint32)
    expo = RNG.integers(0, 24, 2048, dtype=np.uint32)  # denormal..2^-104
    sign = RNG.integers(0, 2, 2048, dtype=np.uint32) << 31
    x = (sign | (expo << 23) | mant).view(np.float32)
    jb = quantize_rel(jnp.asarray(x), cfg)
    nb, no, nr, _ = onp.quantize_rel(x, cfg)
    np.testing.assert_array_equal(np.asarray(jb.bins), nb)
    np.testing.assert_array_equal(np.asarray(jb.outlier), no)


def test_fma_contraction_documented():
    """Pin the second hazard class: LLVM contracts mul+add beneath XLA (jit)
    while eager per-op execution rounds twice — and lax.optimization_barrier
    does NOT prevent it.  This is why quantization steps are powers of two
    (bitops module note).  If this test fails, XLA stopped contracting and
    the pow2 restriction is merely conservative."""
    import jax
    from jax import lax

    def chain(b):
        l = lax.optimization_barrier(b.astype(jnp.float32) *
                                     jnp.float32(0.014355292543768883))
        return l + 127.0

    b = jnp.int32(286)
    eager = np.asarray(chain(b))
    jitted = np.asarray(jax.jit(chain)(b))
    assert eager.view(np.uint32) != jitted.view(np.uint32), (
        "XLA:CPU no longer FMA-contracts through barriers; pow2 steps could "
        "be relaxed")


def test_pow2_step_products_are_exact():
    """The exactness property the whole no-FMA story rests on: bin * step
    with a pow2 step is error-free, so jit and eager agree bit-for-bit."""
    import jax

    cfg = QuantizerConfig(mode="rel", error_bound=1e-2, bin_bits=32)
    _, log_step, _ = cfg.rel_constants()
    assert np.float32(log_step).view(np.uint32) & 0x007FFFFF == 0  # pow2
    bins = jnp.asarray(RNG.integers(-30000, 30000, 4096, dtype=np.int32))
    f = lambda b: b.astype(jnp.float32) * jnp.float32(log_step) + 127.0
    np.testing.assert_array_equal(
        np.asarray(f(bins)).view(np.uint32),
        np.asarray(jax.jit(f)(bins)).view(np.uint32))


def test_library_log_breaks_parity_argument():
    """Sanity check on the premise: the bit-trick log differs from the
    library log (so depending on the library WOULD be a parity hazard),
    while still being within its documented ~0.086 max error."""
    x = np.abs(bit_pattern_samples())
    x = x[np.isfinite(x) & (x >= np.finfo(np.float32).tiny)].astype(np.float32)  # normals only: the bit trick reads a wrong exponent on denormals
    approx = onp.log2approx(x).astype(np.float64)
    exact = np.log2(x.astype(np.float64))
    err = np.abs(approx - exact)
    assert err.max() <= 0.0861
    assert err.max() > 0.01  # it IS an approximation, not the library fn
