"""Packed wire codec: bit-exact roundtrips, kernel/reference parity, and
measured wire sizes.

The PACKED layout is the format the collectives actually move, so every
test here is a bit-equality test: pack/unpack must be lossless over the
full bin range, decode_packed must agree with decode_compact elementwise
(including outlier restoration of NaN payloads / inf / -0.0), and the
fused Pallas pipeline (interpret mode) must reproduce the jit reference
word-for-word."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.compression.grads import (GradCompressionConfig, compress_shard,
                                     wire_bytes)
from repro.compression.kv import (kv_quantizer_config, kv_wire_bytes, pack_kv,
                                  quantize_kv, unpack_kv)
from repro.core import (QuantizerConfig, decode_compact, decode_packed,
                        encode_compact, encode_packed, pack_flags, pack_words,
                        packed_word_count, unpack_flags, unpack_words)
from repro.kernels import pack as kpack

RNG = np.random.default_rng(23)

# non-multiples of the 128-lane tile and of values-per-word, plus exact
# tile multiples and a single element
SIZES = [1, 12, 511, 4096, 32768, 65537]


def _mix(n):
    x = (RNG.standard_normal(n) * 10).astype(np.float32)
    if n >= 8:
        x[:8] = [np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-42,
                 np.finfo(np.float32).max, 5e-4]
    return x


# ------------------------------------------------------- pack primitives --

@pytest.mark.parametrize("bin_bits", [8, 16, 32])
@pytest.mark.parametrize("n", SIZES)
def test_pack_unpack_words_lossless(bin_bits, n):
    mx = (1 << (bin_bits - 1)) - 1
    bins = RNG.integers(-mx + 1, mx, size=n).astype(np.int32)
    words = pack_words(jnp.asarray(bins), bin_bits)
    assert words.dtype == jnp.uint32
    assert words.shape[0] == packed_word_count(n, bin_bits)
    back = np.asarray(unpack_words(words, n, bin_bits))
    np.testing.assert_array_equal(back, bins)


@pytest.mark.parametrize("n", SIZES)
def test_pack_unpack_flags_lossless(n):
    flags = RNG.integers(0, 2, size=n).astype(bool)
    words = pack_flags(jnp.asarray(flags))
    assert words.shape[0] == packed_word_count(n, 1)
    np.testing.assert_array_equal(np.asarray(unpack_flags(words, n)), flags)


# ------------------------------------------------ codec-level roundtrips --

@pytest.mark.parametrize("bin_bits", [8, 16])
@pytest.mark.parametrize("mode", ["abs", "rel", "noa"])
@pytest.mark.parametrize("n", SIZES)
def test_packed_matches_compact_bitexact(bin_bits, mode, n):
    """Acceptance: unpack(pack(x)) == decode_compact(encode_compact(x))
    elementwise at the bit level, outlier restoration included."""
    cfg = QuantizerConfig(mode=mode, error_bound=1e-2, bin_bits=bin_bits)
    x = jnp.asarray(_mix(n))
    via_compact = decode_compact(encode_compact(x, cfg), cfg)
    via_packed = decode_packed(encode_packed(x, cfg), cfg, n=n)
    np.testing.assert_array_equal(np.asarray(via_compact).view(np.uint32),
                                  np.asarray(via_packed).view(np.uint32))


def test_packed_all_outlier_tensor():
    """Every value an outlier (NaN/inf mix): bins are all zero on the wire
    and the table alone reconstructs the tensor bit-for-bit."""
    n = 300
    x = np.where(RNG.integers(0, 2, size=n).astype(bool),
                 np.float32(np.nan), np.float32(np.inf)).astype(np.float32)
    x[::3] = np.uint32(0x7FC00001).view(np.float32)   # NaN with payload
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3, bin_bits=8,
                          outlier_cap_frac=1.0)
    enc = encode_packed(jnp.asarray(x), cfg)
    assert int(enc.n_outliers) == n
    assert not bool(enc.overflow)
    assert int(jnp.sum(enc.words)) == 0               # nothing but zeros
    y = np.asarray(decode_packed(enc, cfg, n=n))
    np.testing.assert_array_equal(x.view(np.uint32), y.view(np.uint32))


def test_packed_overflow_flag():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3, bin_bits=8,
                          outlier_cap_frac=1 / 256)
    x = jnp.asarray(np.full(1024, np.inf, np.float32))
    enc = encode_packed(x, cfg)
    assert bool(enc.overflow)


def test_packed_wire_bits_smaller_than_compact():
    n = 1 << 16
    cfg = QuantizerConfig(mode="abs", error_bound=1e-2, bin_bits=8,
                          outlier_cap_frac=1 / 64)
    x = jnp.asarray((RNG.standard_normal(n) * 0.1).astype(np.float32))
    c = encode_compact(x, cfg)
    p = encode_packed(x, cfg)
    # compact's wire_bits already assumes host narrowing; packed must not
    # exceed it by more than tile padding, and both are ~4x under f32
    assert p.wire_bits() <= c.wire_bits(cfg) + 32 * 128
    assert p.wire_bits() < n * 32 / 3


# ------------------------------------------------- fused kernel parity ----

@pytest.mark.parametrize("bin_bits", [8, 16])
@pytest.mark.parametrize("mode", ["abs", "rel"])
@pytest.mark.parametrize("n", SIZES)
def test_kernel_encode_matches_reference(bin_bits, mode, n):
    cfg = QuantizerConfig(mode=mode, error_bound=1e-2, bin_bits=bin_bits)
    x = jnp.asarray(_mix(n))
    ref = encode_packed(x, cfg)
    ker = kpack.encode_packed(x, cfg, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref.words), np.asarray(ker.words))
    np.testing.assert_array_equal(np.asarray(ref.out_idx),
                                  np.asarray(ker.out_idx))
    np.testing.assert_array_equal(np.asarray(ref.out_payload),
                                  np.asarray(ker.out_payload))
    assert int(ref.n_outliers) == int(ker.n_outliers)
    if mode == "rel":
        np.testing.assert_array_equal(np.asarray(ref.sign_words),
                                      np.asarray(ker.sign_words))


@pytest.mark.parametrize("bin_bits", [8, 16])
@pytest.mark.parametrize("mode", ["abs", "rel"])
@pytest.mark.parametrize("n", [511, 4096, 65537])
def test_kernel_decode_matches_reference(bin_bits, mode, n):
    cfg = QuantizerConfig(mode=mode, error_bound=1e-2, bin_bits=bin_bits)
    x = jnp.asarray(_mix(n))
    enc = encode_packed(x, cfg)
    ref = decode_packed(enc, cfg, n=n)
    ker = kpack.decode_packed(enc, cfg, n=n, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref).view(np.uint32),
                                  np.asarray(ker).view(np.uint32))


def test_kernel_traced_eb_and_tiling_invariance():
    cfg = QuantizerConfig(mode="abs", error_bound=1.0, bin_bits=8)
    x = jnp.asarray(_mix(100_000))
    eb = jnp.float32(3.7e-3)
    ref = encode_packed(x, cfg, eb=eb)
    base = None
    for rows in (32, 256, 512):
        ker = kpack.encode_packed(x, cfg, eb=eb, rows=rows, interpret=True)
        np.testing.assert_array_equal(np.asarray(ref.words),
                                      np.asarray(ker.words))
        if base is None:
            base = np.asarray(ker.words)
        else:
            np.testing.assert_array_equal(base, np.asarray(ker.words))


# ----------------------------------------------------- wire accounting ----

@pytest.mark.parametrize("n", [1000, 1 << 16, (1 << 20) + 17])
def test_grad_shard_wire_matches_wire_bytes(n):
    """Acceptance: what compressed_mean all-gathers is packed uint32 words
    and the measured size equals wire_bytes exactly."""
    cfg = GradCompressionConfig()
    g = jnp.asarray((RNG.standard_normal(n) * 0.01).astype(np.float32))
    shard, _ = compress_shard(g, cfg)
    assert shard.words.dtype == jnp.uint32
    assert shard.out_payload.dtype == jnp.uint32
    assert shard.nbytes() == wire_bytes(n, cfg)
    # packed words alone are 4x under f32; full wire under the cap's bound
    assert shard.words.size * 4 <= n + 4 * 128 * 4
    assert wire_bytes(n, cfg) < n * 4 / 3


def test_grad_shard_roundtrip_bound():
    """Decoding the shard's own wire arrays honors the per-tensor bound."""
    from repro.core import codec as C
    from repro.core.quantizer import dequantize_abs
    n = 8192
    cfg = GradCompressionConfig(eb_rel=2.0 ** -6, outlier_cap_frac=1 / 4)
    g = np.asarray((RNG.standard_normal(n) * 0.01).astype(np.float32))
    shard, q = compress_shard(jnp.asarray(g), cfg)
    bins = C.unpack_words(shard.words, n, cfg.bin_bits)
    recon = dequantize_abs(bins, cfg.qcfg(), eb=shard.eb, dtype=jnp.float32)
    vals = jnp.asarray(shard.out_payload.astype(jnp.int32)).view(jnp.float32)
    recon = np.asarray(recon.at[shard.out_idx].set(vals, mode="drop"))
    eb = float(shard.eb)
    out_mask = np.asarray(q.outlier)
    assert np.all(np.abs(g[~out_mask] - recon[~out_mask]) <= eb)
    np.testing.assert_array_equal(g[out_mask], recon[out_mask])


def test_compressed_mean_outlier_at_last_index():
    """Regression: an outlier at flat index n-1 with spare table slots must
    ship exactly.  The empty slots' fill index is n; a clamped duplicate
    scatter (min(ii, n-1)) would overwrite the exact payload with the
    zeroed-bin reconstruction and decode 0 — silently violating the
    bound."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.compression.grads import compressed_mean

    n = 4096
    g = np.zeros(n, np.float32)
    g[:64] = 0.01
    g[-1] = 50.0                 # far outside the int8 bin range -> outlier
    cfg = GradCompressionConfig(eb_rel=2.0 ** -6, bin_bits=8,
                                outlier_cap_frac=1 / 64)   # cap 64 >> 1
    mesh = jax.make_mesh((1,), ("pod",))
    from conftest import shard_map_compat
    mapped = shard_map_compat(lambda x: compressed_mean(x, cfg, "pod"),
                              mesh, P(), (P(), P()))
    mean, resid = jax.jit(mapped)(jnp.asarray(g))
    mean = np.asarray(mean)
    assert mean[-1] == g[-1], (mean[-1], "outlier at last index not exact")
    eb = cfg.eb_rel * float(np.sqrt(np.mean(g ** 2)))
    assert np.abs(mean - g).max() <= eb * 1.01


def test_kv_pack_roundtrip_bitexact():
    cfg = kv_quantizer_config()
    x = jnp.asarray(RNG.standard_normal((2, 3, 256, 64)).astype(np.float32))
    q = quantize_kv(x, cfg)
    p = pack_kv(q)
    assert p.words.dtype == jnp.uint32
    assert p.nbytes() == kv_wire_bytes(x.shape)
    back = unpack_kv(p)
    np.testing.assert_array_equal(np.asarray(q.bins), np.asarray(back.bins))
    for a, b in zip(q[1:], back[1:]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
