"""Documentation consistency: source docstrings cite design sections as
`DESIGN.md §N`, and those anchors rot silently when sections are added or
renumbered.  This test walks every docstring/comment under src/ and
benchmarks/ and checks each cited §N actually exists in DESIGN.md, plus a
few structural invariants of the top-level docs."""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _design_sections():
    text = (REPO / "DESIGN.md").read_text()
    return {int(m) for m in re.findall(r"^## §(\d+)\b", text, re.M)}, text


def test_design_sections_are_contiguous():
    sections, _ = _design_sections()
    assert sections == set(range(1, max(sections) + 1)), sections


def test_all_design_refs_resolve():
    sections, _ = _design_sections()
    bad = []
    for root in ("src", "benchmarks", "examples", "tests"):
        for py in sorted((REPO / root).rglob("*.py")):
            for ln, line in enumerate(py.read_text().splitlines(), 1):
                for m in re.finditer(r"DESIGN\.md §(\d+)", line):
                    if int(m.group(1)) not in sections:
                        bad.append(f"{py.relative_to(REPO)}:{ln} §{m.group(1)}")
    assert not bad, f"dangling DESIGN.md § references: {bad}"


def test_readme_links_resolve():
    text = (REPO / "README.md").read_text()
    missing = []
    for target in re.findall(r"\]\(([^)]+)\)", text):
        if target.startswith(("http://", "https://")):
            continue
        path = target.split("#")[0]
        if path and not (REPO / path).exists():
            missing.append(target)
    assert not missing, f"README links to missing files: {missing}"


def test_readme_covers_the_essentials():
    text = (REPO / "README.md").read_text()
    for needle in ("DESIGN.md", "examples/quickstart.py", "pytest",
                   "PYTHONPATH=src", "parse_pipeline"):
        assert needle in text, f"README.md is missing {needle!r}"


def test_design_documents_the_pipeline_api():
    """§7 is the pipeline contract: every registered stage name must
    appear in DESIGN.md (the registry row is part of adding a stage), and
    the spec grammar example must be present."""
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.pipeline import STAGES

    _, text = _design_sections()
    assert "## §7" in text
    sec7 = text.split("## §7", 1)[1]
    for name in STAGES:
        assert f"`{name}`" in sec7 or f"`{name}[" in sec7, (
            f"registered stage {name!r} is undocumented in DESIGN.md §7")
    assert "rel:1e-3|pack:8|zero|narrow" in sec7


def test_design_documents_the_value_stage_contract():
    """§9 is the value-domain (predictor) contract: every registered pred
    stage must appear in DESIGN.md §9 (the registry row is part of adding
    a predictor), along with the closed-loop invariant and the two-domain
    grammar example, and §4/§6/§7 must cross-link to it — the bin-plane
    bijection is what keeps the §1 bound intact ahead of the quantizer."""
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.predict import PRED_STAGES

    _, text = _design_sections()
    assert "## §9" in text
    sec9 = text.split("## §9", 1)[1]
    for name in PRED_STAGES:
        assert f"`{name}`" in sec9, (
            f"registered value stage {name!r} is undocumented in DESIGN.md §9")
    assert "closed-loop" in sec9 or "closed loop" in sec9
    assert "delta|abs:1e-3|pack:8|zero|narrow|ent" in sec9
    # §4/§6/§7 each cross-link the value-domain section
    for n in (4, 6, 7):
        body = text.split(f"## §{n}", 1)[1].split(f"## §{n + 1}", 1)[0]
        assert "§9" in body, f"DESIGN.md §{n} does not cross-link §9"


def test_design_documents_the_transport_api():
    """§8 is the transport contract: every public Transport method must
    appear in DESIGN.md §8 (plus the module-level wire_bytes accessor and
    the packed-domain compatibility rule), and §4/§6/§7 must cross-link
    to it — the transport is the transmit leg of the guarantee and must
    not drift out of the wire-format docs."""
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.transport import Transport

    _, text = _design_sections()
    assert "## §8" in text
    sec8 = text.split("## §8", 1)[1]
    methods = [m for m in vars(Transport)
               if not m.startswith("_") and callable(getattr(Transport, m))]
    assert set(methods) >= {"all_gather", "reduce_sum", "reduce_mean",
                            "send_pages", "bytes_moved"}
    for name in methods:
        assert f"`{name}" in sec8, (
            f"Transport.{name} is undocumented in DESIGN.md §8")
    assert "`wire_bytes" in sec8 or "wire_bytes(" in sec8
    assert "compatibility rule" in sec8
    # §4/§6/§7 each cross-link the transport section
    for n in (4, 6, 7):
        body = text.split(f"## §{n}", 1)[1].split(f"## §{n + 1}", 1)[0]
        assert "§8" in body, f"DESIGN.md §{n} does not cross-link §8"


def test_design_documents_the_engine():
    """§10 is the decode-engine contract: the public slot-lifecycle API
    must appear in DESIGN.md §10, along with the lifecycle verbs, the
    streaming-migration overlap claim, and the bit-identity claim — and
    §8/§9 must cross-link to it (the engine is the §8 transport's and the
    §9 page chains' request-rate consumer), plus the README architecture
    map must carry its row."""
    _, text = _design_sections()
    assert "## §10" in text
    sec10 = text.split("## §10", 1)[1]
    for name in ("allocate", "prefill", "insert", "generate_step",
                 "evict", "stream_prefill", "PageWire", "PackedKV",
                 "KV_PAGE_CHAINS"):
        assert f"`{name}" in sec10, (
            f"{name!r} is undocumented in DESIGN.md §10")
    for verb in ("allocate", "fill", "close", "evict"):    # the lifecycle
        assert verb in sec10, verb
    assert "bit-identical" in sec10
    assert "overlap" in sec10
    assert "BENCH_decode.json" in sec10
    # §8/§9 each cross-link the engine section
    for n in (8, 9):
        body = text.split(f"## §{n}", 1)[1].split(f"## §{n + 1}", 1)[0]
        assert "§10" in body, f"DESIGN.md §{n} does not cross-link §10"
    readme = (REPO / "README.md").read_text()
    assert "models/engine.py" in readme
    assert "§10" in readme


def test_design_documents_the_selector():
    """§11 is the adaptive-selector contract: the runtime surface
    (`Selector`/`KVSelector`/`SelectedWire`), the registry
    (`SELECTOR_SETS`), the chain-id header, the bit-transparency claim,
    and the autotuner flow must all appear in DESIGN.md §11 — and
    §7/§8/§9/§10 must cross-link to it (the selector sits on top of the
    pipeline grammar, inside the transport accounting, across the pred
    stages, and under the engine's page chains), plus the README
    architecture map must carry its row."""
    _, text = _design_sections()
    assert "## §11" in text
    sec11 = text.split("## §11", 1)[1]
    for name in ("Selector", "KVSelector", "SelectedWire",
                 "SELECTOR_SETS", "plane_stats", "CHAIN_ID_BITS",
                 "autotune", "BENCH_select.json", "wire_bytes"):
        assert name in sec11, (
            f"{name!r} is undocumented in DESIGN.md §11")
    assert "chain id" in sec11 or "chain-id" in sec11
    assert "argmin" in sec11                       # the scoring rule
    assert "self-describing" in sec11
    assert "bit-identical" in sec11
    assert "shuffle" in sec11                      # the scoreability rule
    # §7/§8/§9/§10 each cross-link the selector section
    for n in (7, 8, 9, 10):
        body = text.split(f"## §{n}", 1)[1].split(f"## §{n + 1}", 1)[0]
        assert "§11" in body, f"DESIGN.md §{n} does not cross-link §11"
    readme = (REPO / "README.md").read_text()
    assert "core/select.py" in readme
    assert "§11" in readme


def test_design_documents_the_audit_plane():
    """§12 is the guarantee-audit contract: the runtime surface
    (`AuditReport`/`wire_checksum`/`verify_wire`/`attach_checksum`), the
    degradation-policy registry and its three built-ins, the length
    guard, and the fault-plan grammar (every `guard.FAULT_CLASSES` name)
    must all appear in DESIGN.md §12 — and §4/§7/§8/§11 must cross-link
    to it (the checksum covers the §4 planes, rides the §7/§11 encode
    opt-ins, and is enforced on the §8 receive leg), plus the README
    architecture map must carry its row."""
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.core import audit
    from repro.runtime import guard

    _, text = _design_sections()
    assert "## §12" in text
    sec12 = text.split("## §12", 1)[1]
    for name in ("AuditReport", "audit_report", "wire_checksum",
                 "attach_checksum", "verify_wire", "verify_gathered",
                 "check_payload_len", "WireIntegrityError",
                 "DEGRADATION_POLICIES", "register_policy", "FaultPlan",
                 "detection_matrix", "BENCH_audit.json"):
        assert name in sec12, (
            f"{name!r} is undocumented in DESIGN.md §12")
    for cls in guard.FAULT_CLASSES:          # the fault-plan grammar
        assert f"`{cls}`" in sec12, (
            f"fault class {cls!r} is undocumented in DESIGN.md §12")
    for policy in audit.DEGRADATION_POLICIES:
        assert f"`{policy}`" in sec12, (
            f"degradation policy {policy!r} is undocumented in §12")
    assert "verify=True" in sec12 and "integrity=True" in sec12
    assert "bit-identical" in sec12          # checksum-as-aux placement
    assert "false positives" in sec12
    # §4/§7/§8/§11 each cross-link the audit section
    for n in (4, 7, 8, 11):
        body = text.split(f"## §{n}", 1)[1].split(f"## §{n + 1}", 1)[0]
        assert "§12" in body, f"DESIGN.md §{n} does not cross-link §12"
    readme = (REPO / "README.md").read_text()
    assert "core/audit.py" in readme
    assert "runtime/guard.py" in readme
    assert "§12" in readme


def test_design_documents_the_guarantee_linter():
    """§13 is the linter contract: every registered GL rule id (plus
    GL000, the suppression enforcer) and every RC contract id must have
    its row, the suppression grammar and gate command must be stated,
    and §7/§12 must cross-link to §13 (the dispatch table and the audit
    conventions are what the linter enforces statically)."""
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis import RULES

    _, text = _design_sections()
    assert "## §13" in text
    sec13 = text.split("## §13", 1)[1].split("\n## §", 1)[0]
    for rid in sorted(RULES) + ["GL000"]:
        assert f"`{rid}`" in sec13, (
            f"lint rule {rid!r} is undocumented in DESIGN.md §13 "
            f"(the RC008 contract also fails CI on this)")
    for rc in [f"RC00{i}" for i in range(1, 9)]:
        assert rc in sec13, f"contract {rc!r} is undocumented in §13"
    assert "repro: noqa" in sec13            # suppression grammar
    assert "-- reason" in sec13 or "MANDATORY" in sec13
    assert "python -m repro.analysis" in sec13
    assert "analysis-baseline.json" in sec13
    for n in (7, 12):
        body = text.split(f"## §{n}", 1)[1].split(f"## §{n + 1}", 1)[0]
        assert "§13" in body, f"DESIGN.md §{n} does not cross-link §13"
    readme = (REPO / "README.md").read_text()
    assert "analysis" in readme and "repro.analysis" in readme


def test_registry_selector_sets_resolve():
    """Every SELECTOR_SETS entry must build: full-pipeline sets through
    `get_selector`, page-fragment sets (base None) through
    `get_kv_selector` — construction validates the shared base, the
    candidate count, and the scoreability rule."""
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.configs.registry import SELECTOR_SETS
    from repro.core import select as SEL

    for name, entry in SELECTOR_SETS.items():
        assert len(entry["bias"]) == len(entry["chains"]), name
        if entry["base"] is None:
            sel = SEL.get_kv_selector(name)
            assert len(sel.chains) == len(entry["chains"])
        else:
            sel = SEL.get_selector(name)
            assert sel.spec() == f"auto:{name}"
            assert len(sel.chains) == len(entry["chains"])


def test_registry_pipeline_presets_parse():
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.configs.registry import PIPELINES, get_pipeline
    from repro.core.pipeline import parse_pipeline

    for name, spec in PIPELINES.items():
        pipe = parse_pipeline(get_pipeline(name))
        assert parse_pipeline(pipe.spec()) == pipe, name
