"""Adaptive chain selector (DESIGN.md §11): the runtime choice must be
invisible in the bits and honest in the accounting.

  * Bit-transparency: a selected wire decodes bit-identically to
    encoding directly with the chosen chain (the `lax.switch` branch IS
    that chain's own encode) — pinned pointwise and as a hypothesis
    property over adversarial inputs.
  * The §1 guarantee survives selection verbatim: every decoded value is
    within the bound or bit-identical.
  * Acceptance: on the gradient suites + iid + the NYX-like plane, the
    statistics pick the true per-suite best candidate on most suites and
    the auto wire is never more than 2% above the per-suite best.
  * Accounting: `Selector.wire_bits` = the chosen chain's own
    `Pipeline.wire_bits` + the 8-bit chain id; the KV per-page wire adds
    exactly one id byte per page over the same pages packed statically.
  * The selector grad wire rides `compressed_mean` unchanged
    (shard_map), bit-identical to the decode-then-sum reference.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compression.grads import GradCompressionConfig, compress_shard
from repro.compression.kv import (kv_error_bound_holds, kv_quantizer_config,
                                  pack_kv, quantize_kv, unpack_kv)
from repro.core import select as SEL
from repro.core.pipeline import parse_pipeline

from conftest import shard_map_compat as _smap

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks import datasets  # noqa: E402

RNG = np.random.default_rng(17)
N = 1 << 14
EB = 1e-3


def _u32(a):
    return np.asarray(a).view(np.uint32)


def _suite_cut(gen, cut=1 << 16):
    return np.asarray(gen())[:cut]


@pytest.fixture(scope="module")
def sel():
    return SEL.get_selector("grad-wire")


# ------------------------------------------------------ bit-transparency --

def test_selected_wire_is_the_chosen_chains_wire(sel):
    """The switch branch is the candidate's own encode: every field of
    the re-split view must be byte-equal to the direct encoding."""
    x = jnp.asarray((RNG.standard_normal(N) * 3e-3).astype(np.float32))
    wire = sel.encode(x, EB)
    cid = int(wire.chain_id)
    pipe = sel.chains[cid]
    direct = pipe.encode(x, EB, kernels=False)
    view = sel._view(wire, cid, N)
    assert np.array_equal(_u32(view.payload), _u32(direct.payload))
    assert int(view.payload_len) == int(direct.payload_len)
    for hv, hd in zip(view.headers, direct.headers):
        assert np.array_equal(_u32(hv), _u32(hd.reshape(-1)))
    # and the decode is bit-identical both ways
    y_auto = sel.decode(wire, shape=x.shape)
    y_direct = pipe.decode(direct, shape=x.shape, kernels=False)
    assert np.array_equal(_u32(y_auto), _u32(y_direct))


def test_auto_roundtrip_property():
    """Hypothesis twin: adversarial float32 inputs (zeros, huge values,
    specials) through every registered full-pipeline set — selection
    never moves a bit vs the chosen chain, and the §1 bound holds."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st
    del hyp

    sel = SEL.get_selector("grad-wire")

    @settings(max_examples=20, deadline=None)
    @given(st.lists(
        st.floats(width=32, allow_nan=True, allow_infinity=True),
        min_size=1, max_size=600), st.integers(0, 2 ** 31))
    def prop(vals, seed):
        r = np.random.default_rng(seed)
        n = 1024
        x = np.zeros(n, np.float32)
        x[: len(vals)] = np.asarray(vals, np.float32)
        r.shuffle(x)
        xj = jnp.asarray(x)
        wire = sel.encode(xj, EB)
        cid = int(wire.chain_id)
        y = np.asarray(sel.decode(wire, shape=(n,)))
        y_direct = np.asarray(sel.chains[cid].decode(
            sel.chains[cid].encode(xj, EB, kernels=False), shape=(n,),
            kernels=False))
        assert np.array_equal(_u32(y), _u32(y_direct))
        ok = (np.abs(y - x) <= EB) | (_u32(y) == _u32(x))
        assert bool(np.all(ok)) or bool(wire.overflow)

    prop()


def test_error_bound_holds_through_auto(sel):
    for gen in (datasets.grad_smooth, datasets.grad_sparse, datasets.iid):
        x = _suite_cut(gen, 1 << 15)
        y = np.asarray(sel.roundtrip(jnp.asarray(x), EB))
        ok = (np.abs(y - x) <= EB) | (_u32(y) == _u32(x))
        assert bool(np.all(ok)), gen.__name__


# ----------------------------------------------------------- acceptance --

def test_auto_tracks_the_best_static_chain(sel):
    """The §11 acceptance bar: auto never pays more than 2% over the
    per-suite best candidate, and the statistics pick the true argmin
    candidate on most suites (the iid suite additionally pins that the
    choice is never the pred chain — predictors cannot win on iid)."""
    suites = dict(datasets.GRAD_SUITES, iid=datasets.iid)
    hits, rows = 0, []
    for name, gen in suites.items():
        x = jnp.asarray(_suite_cut(gen))
        eb = jnp.float32(2.0 ** -8) * jnp.sqrt(jnp.mean(x * x))
        n = x.size
        actual = [float(p.wire_bits(p.encode(x, eb, kernels=False), n))
                  for p in sel.chains]
        wire = sel.encode(x, eb)
        auto_bits = float(sel.wire_bits(wire, n))
        cid, best = int(wire.chain_id), int(np.argmin(actual))
        assert auto_bits <= 1.02 * actual[best], (name, auto_bits, actual)
        hits += cid == best
        rows.append((name, sel.chains[cid].spec(),
                     sel.chains[best].spec()))
        if name == "iid":
            assert not sel.chains[cid].pred, rows[-1]
    assert hits >= len(suites) - 1, rows


def test_sci_plane_auto(sel):
    """The 2-D set: lorenzo must fire on the NYX-like plane (via
    pred_shape threading) and auto must track the best chain."""
    ssel = SEL.get_selector("sci-plane")
    x = jnp.asarray(datasets.nyx_plane(256))
    n = x.size
    actual = [float(p.wire_bits(p.encode(x, kernels=False), n))
              for p in ssel.chains]
    wire = ssel.encode(x)
    cid, best = int(wire.chain_id), int(np.argmin(actual))
    assert float(ssel.wire_bits(wire, n)) <= 1.02 * actual[best]
    assert cid == best
    y = np.asarray(ssel.decode(wire, shape=x.shape))
    xs = np.asarray(x)
    ok = (np.abs(y - xs) <= ssel.quant.eb) | (_u32(y) == _u32(xs))
    assert bool(np.all(ok))


# ----------------------------------------------------------- accounting --

def test_wire_bits_is_chosen_chain_plus_id_byte(sel):
    x = jnp.asarray(_suite_cut(datasets.grad_smooth, 1 << 15))
    wire = sel.encode(x, EB)
    cid = int(wire.chain_id)
    direct = sel.chains[cid].encode(x, EB, kernels=False)
    assert float(sel.wire_bits(wire, x.size)) == pytest.approx(
        float(sel.chains[cid].wire_bits(direct, x.size))
        + SEL.CHAIN_ID_BITS)


def test_selector_rejects_unscoreable_and_mixed_sets():
    base = parse_pipeline("abs:1e-3|pack:16|shuffle|narrow")
    with pytest.raises(ValueError, match="scoreab"):
        SEL.Selector("bad", (base,))
    a = parse_pipeline("abs:1e-3|pack:16|narrow")
    b = parse_pipeline("abs:1e-3|pack:8|narrow")
    with pytest.raises(ValueError, match="share"):
        SEL.Selector("mixed", (a, b))
    with pytest.raises(ValueError, match="bias"):
        SEL.Selector("nobias", (a,), bias=(0.0, 1.0))


# --------------------------------------------------------- grad wire ------

def test_selector_grad_wire_through_compressed_mean():
    """pipeline='auto' rides the §8 gather path unchanged: the
    shard_map `compressed_mean` result is bit-identical to decoding the
    selector wire and averaging by hand."""
    from repro.compression.grads import compressed_mean

    cfg = GradCompressionConfig(pipeline="auto")
    pipe = cfg.pipe()
    assert isinstance(pipe, SEL.Selector)
    g = jnp.asarray((RNG.standard_normal(N) * 3e-3).astype(np.float32))

    shard, _ = compress_shard(g, cfg)
    ref = pipe.decode(shard.enc, n=N)

    mesh = jax.make_mesh((1,), ("pod",))
    m, resid = _smap(
        lambda gg: compressed_mean(gg[0], cfg, "pod"),
        mesh, in_specs=P("pod"), out_specs=(P(), P()))(g[None])
    assert np.array_equal(_u32(m), _u32(ref))
    assert np.all(np.abs(np.asarray(resid))
                  <= float(shard.enc.eb) * 1.0000001)


# ------------------------------------------------------------- KV pages ---

def test_kv_auto_pages_roundtrip_and_account():
    cache = RNG.standard_normal((2, 2, 512, 64)).astype(np.float32)
    cache[:, :, 300:, :] = 0.0                     # unwritten decode tail
    cfg = kv_quantizer_config()
    q = quantize_kv(jnp.asarray(cache), cfg, page=128)

    sel = SEL.get_kv_selector("kv-page")
    packed = pack_kv(q, page=128, stages=sel)
    assert packed.select is sel
    n_pages = packed.chain_id.size

    # bit-exact per-page roundtrip + the §1 bound on the cache
    q2 = unpack_kv(packed, page=128)
    assert np.array_equal(np.asarray(q2.bins), np.asarray(q.bins))
    assert bool(kv_error_bound_holds(jnp.asarray(cache), q2, cfg))

    # accounting: where every page picks fragment i, the auto wire costs
    # exactly the static fragment wire + one id byte per page
    ids = np.unique(np.asarray(packed.chain_id))
    if ids.size == 1:
        from repro.configs.registry import SELECTOR_SETS
        frag = SELECTOR_SETS["kv-page"]["chains"][int(ids[0])]
        static = pack_kv(q, page=128, stages=frag)
        assert float(packed.wire_nbytes()) == pytest.approx(
            float(static.wire_nbytes()) + n_pages)

    # pytree roundtrip keeps the selection (device_put runs flatten)
    leaves, treedef = jax.tree.flatten(packed)
    packed2 = jax.tree.unflatten(treedef, leaves)
    assert packed2.select is sel
    assert np.array_equal(np.asarray(packed2.chain_id),
                          np.asarray(packed.chain_id))


def test_kv_auto_correlated_picks_kvdelta():
    """Token-correlated KV rows are kvdelta's case (§9): when rows
    repeat along the token axis the raw bins are dense (nothing for
    `zero`/`narrow` to drop) but the previous-token residuals vanish —
    the per-page statistics must route those pages to the kvdelta
    fragment."""
    row = RNG.standard_normal((1, 2, 1, 64)).astype(np.float32)
    corr = np.broadcast_to(row, (1, 2, 512, 64)).copy()
    q = quantize_kv(jnp.asarray(corr), kv_quantizer_config(), page=128)
    sel = SEL.get_kv_selector("kv-page")
    packed = pack_kv(q, page=128, stages=sel)
    from repro.configs.registry import SELECTOR_SETS
    frags = SELECTOR_SETS["kv-page"]["chains"]
    chosen = [frags[i] for i in np.asarray(packed.chain_id).ravel()]
    assert any("kvdelta" in c for c in chosen), chosen
    assert np.array_equal(np.asarray(unpack_kv(packed, page=128).bins),
                          np.asarray(q.bins))


# ------------------------------------------------------------ plumbing ----

def test_parse_chain_grammar():
    assert isinstance(SEL.parse_chain("auto"), SEL.Selector)
    assert SEL.parse_chain("auto:sci-plane").name == "sci-plane"
    assert isinstance(SEL.parse_chain("abs:1e-3|pack:8|zero"),
                      type(parse_pipeline("abs:1e-3|pack:8|zero")))
    with pytest.raises(KeyError):
        SEL.get_selector("kv-page")        # page set via the wrong getter
    with pytest.raises(KeyError):
        SEL.get_kv_selector("grad-wire")


def test_grads_config_cap_semantics():
    """Same rule as plain specs: an explicit cap= in the set's base spec
    wins over the config (the registry grad-wire base pins 1/64), and a
    REL base is rejected — the per-tensor eb override is an ABS bound."""
    cfg = GradCompressionConfig(pipeline="auto", outlier_cap_frac=1 / 32)
    pipe = cfg.pipe()
    assert isinstance(pipe, SEL.Selector)
    for p in pipe.chains:
        assert p.quant.cap == pytest.approx(1 / 64)
        assert p.quant.mode == "abs"
