"""Chunkwise-parallel mLSTM must match the exact sequential recurrence."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models.xlstm import _mlstm_chunkwise, _mlstm_step


def test_chunkwise_matches_sequential():
    rng = np.random.default_rng(0)
    b, t, h, dh = 2, 128, 2, 16
    q = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32) / 4
    v = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
    ig = jnp.asarray(rng.standard_normal((b, t, h)), jnp.float32)
    fg = jax.nn.log_sigmoid(
        jnp.asarray(rng.standard_normal((b, t, h)) + 2.0, jnp.float32))

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)

    (cs, ns, ms), hs_seq = jax.lax.scan(
        _mlstm_step, (c0, n0, m0),
        (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
         fg.transpose(1, 0, 2)))
    h_seq = np.asarray(hs_seq.transpose(1, 0, 2, 3))

    for chunk in (16, 32, 128):
        h_ch, (cc, nc_, mc) = _mlstm_chunkwise(q, k, v, ig, fg,
                                               (c0, n0, m0), chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_ch), h_seq, rtol=2e-4,
                                   atol=2e-4, err_msg=f"chunk={chunk}")
        # boundary state matches too (up to the stabilizer decomposition)
        c_seq = np.asarray(cs) * np.exp(np.asarray(ms))[..., None, None]
        c_chk = np.asarray(cc) * np.exp(np.asarray(mc))[..., None, None]
        np.testing.assert_allclose(c_chk, c_seq, rtol=2e-3, atol=1e-4)


def test_chunkwise_grad_finite():
    rng = np.random.default_rng(1)
    b, t, h, dh = 1, 64, 2, 8
    args = [jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
            for _ in range(3)]
    ig = jnp.asarray(rng.standard_normal((b, t, h)), jnp.float32)
    fg = jax.nn.log_sigmoid(jnp.asarray(
        rng.standard_normal((b, t, h)) + 2, jnp.float32))
    state = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
             jnp.full((b, h), -1e30))

    def loss(q):
        hh, _ = _mlstm_chunkwise(q, args[1], args[2], ig, fg, state,
                                 chunk=16)
        return jnp.sum(hh ** 2)

    g = jax.grad(loss)(args[0])
    assert np.isfinite(np.asarray(g)).all()
