"""Core quantizer tests: the error-bound GUARANTEE, special values, edge
cases, and codec roundtrips.  The verification oracle always computes the
true error in float64 (exact for f32 data)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (QuantizerConfig, decode_compact, decode_dense,
                        encode_compact, encode_dense, log2approx, pow2approx,
                        quantize_abs, quantize_rel, roundtrip_dense)

RNG = np.random.default_rng(0)


def random_floats(n, scale=1.0):
    return (RNG.standard_normal(n) * scale).astype(np.float32)


def assert_bound_abs(x, y, eb):
    x64 = x.astype(np.float64)
    y64 = y.astype(np.float64)
    mask = np.isfinite(x)
    assert np.all(np.abs(x64[mask] - y64[mask]) <= eb), \
        f"ABS bound violated: max err {np.max(np.abs(x64[mask]-y64[mask]))}"
    # non-finite must be bit-exact
    nf = ~mask
    assert np.array_equal(x[nf].view(np.uint32), y[nf].view(np.uint32))


def assert_bound_rel(x, y, eb):
    x64 = x.astype(np.float64)
    y64 = y.astype(np.float64)
    mask = np.isfinite(x) & (x != 0)
    err = np.abs(x64[mask] - y64[mask]) / np.abs(x64[mask])
    assert np.all(err <= eb), f"REL bound violated: max rel err {err.max()}"
    assert np.all(np.sign(y64[mask]) == np.sign(x64[mask])), "sign flipped"
    rest = ~mask
    assert np.array_equal(x[rest].view(np.uint32), y[rest].view(np.uint32))


@pytest.mark.parametrize("eb", [1e-1, 1e-3, 1e-6])
@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e4])
def test_abs_roundtrip_guarantee(eb, scale):
    cfg = QuantizerConfig(mode="abs", error_bound=eb)
    x = random_floats(4096, scale)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    assert_bound_abs(x, y, eb)


@pytest.mark.parametrize("eb", [1e-1, 1e-3, 1e-5])
@pytest.mark.parametrize("scale", [1e-20, 1.0, 1e20])
def test_rel_roundtrip_guarantee(eb, scale):
    cfg = QuantizerConfig(mode="rel", error_bound=eb, bin_bits=32)
    x = random_floats(4096, scale)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    assert_bound_rel(x, y, eb)


def test_noa_roundtrip_guarantee():
    cfg = QuantizerConfig(mode="noa", error_bound=1e-4)
    x = random_floats(4096, 50.0) + 17.0
    enc = encode_dense(jnp.asarray(x), cfg)
    y = np.asarray(decode_dense(enc, cfg))
    r = x.max() - x.min()
    assert_bound_abs(x, y, 1e-4 * np.float64(r) * (1 + 1e-6))


SPECIALS = np.array(
    [np.inf, -np.inf, np.nan, -np.nan, 0.0, -0.0, np.finfo(np.float32).tiny,
     -np.finfo(np.float32).tiny, 1e-45, -1e-45,  # denormals
     np.finfo(np.float32).max, np.finfo(np.float32).min,
     np.float32(1.0), np.float32(-1.0)], dtype=np.float32)


@pytest.mark.parametrize("mode", ["abs", "rel"])
def test_special_values_preserved(mode):
    """Paper Table 3 row for LC: INF/NaN/denormal all handled, bit-exact
    where not quantizable."""
    cfg = QuantizerConfig(mode=mode, error_bound=1e-3)
    x = np.tile(SPECIALS, 8)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    if mode == "abs":
        assert_bound_abs(x, y, 1e-3)
    else:
        assert_bound_rel(x, y, 1e-3)
    # NaN payloads and -0.0 sign: bit-for-bit
    nf = ~np.isfinite(x)
    assert np.array_equal(x[nf].view(np.uint32), y[nf].view(np.uint32))


def test_nan_payload_bits_survive():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-2)
    payloads = np.array([0x7FC00001, 0x7F800123, 0xFFC0ABCD], np.uint32)
    x = payloads.view(np.float32)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    assert np.array_equal(y.view(np.uint32), payloads)


def test_binning_near_bin_borders():
    """Values maximally close to bin borders — the paper's §2.2 failure
    scenario.  The double-check must keep every one inside the bound."""
    eb = 1e-3
    cfg = QuantizerConfig(mode="abs", error_bound=eb)
    eb2 = np.float32(2 * eb)
    k = np.arange(-2000, 2000, dtype=np.float32)
    borders = (k + np.float32(0.5)) * eb2
    x = np.concatenate([
        borders, np.nextafter(borders, np.float32(np.inf)),
        np.nextafter(borders, np.float32(-np.inf))]).astype(np.float32)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    assert_bound_abs(x, y, eb)


def test_int_min_edge_case_form():
    """Paper §2.4: huge values map to bins beyond int32; the two-comparison
    range check must flag them (abs(INT_MIN) would wrap)."""
    cfg = QuantizerConfig(mode="abs", error_bound=1e-30, bin_bits=32)
    x = np.array([-3e8, 3e8, -1e30, 1e30, np.float32(-2147483648.0) * 2e-30],
                 np.float32)
    q = quantize_abs(jnp.asarray(x), cfg)
    assert bool(jnp.all(q.outlier))
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    assert np.array_equal(x.view(np.uint32), y.view(np.uint32))


def test_bins_within_storage_range():
    for bits in (8, 16, 32):
        cfg = QuantizerConfig(mode="abs", error_bound=1e-3, bin_bits=bits)
        x = random_floats(8192, 10.0)
        q = quantize_abs(jnp.asarray(x), cfg)
        b = np.asarray(q.bins)
        assert b.max() < cfg.maxbin and b.min() > -cfg.maxbin


def test_compact_codec_matches_dense():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3, outlier_cap_frac=0.5)
    x = random_floats(2048, 1.0)
    x[::97] = np.nan
    x[::101] = np.inf
    d = np.asarray(decode_dense(encode_dense(jnp.asarray(x), cfg), cfg))
    enc = encode_compact(jnp.asarray(x), cfg)
    assert not bool(enc.overflow)
    c = np.asarray(decode_compact(enc, cfg))
    assert np.array_equal(d.view(np.uint32), c.view(np.uint32))


def test_compact_codec_overflow_detected():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3,
                          outlier_cap_frac=0.001)
    x = np.full(1000, np.nan, np.float32)
    enc = encode_compact(jnp.asarray(x), cfg)
    assert bool(enc.overflow)


def test_rel_sign_preserved_small_magnitudes():
    # |x| < 1 gives negative REL bins; signs must still decode correctly.
    cfg = QuantizerConfig(mode="rel", error_bound=1e-2)
    x = np.array([0.25, -0.25, 0.03125, -0.03125, 3.0, -3.0], np.float32)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    assert_bound_rel(x, y, 1e-2)


def test_log2_pow2_inverse_on_powers_of_two():
    e = np.arange(-100, 101, dtype=np.float32)
    x = np.exp2(e).astype(np.float32)
    lg = np.asarray(log2approx(jnp.asarray(x)))
    np.testing.assert_array_equal(lg, e)        # exact on powers of two
    back = np.asarray(pow2approx(jnp.asarray(lg)))
    np.testing.assert_array_equal(back, x)


def test_log2approx_monotone():
    x = np.sort(np.abs(random_floats(4096, 1e3))) + np.float32(1e-30)
    lg = np.asarray(log2approx(jnp.asarray(x)))
    assert np.all(np.diff(lg) >= 0)


def test_jit_and_shape_polymorphism():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3)
    f = jax.jit(lambda v: roundtrip_dense(v, cfg))
    for shape in [(16,), (8, 8), (2, 3, 4)]:
        x = RNG.standard_normal(shape).astype(np.float32)
        y = np.asarray(f(jnp.asarray(x)))
        assert y.shape == shape
        assert_bound_abs(x.ravel(), y.ravel(), 1e-3)


def test_float64_roundtrip():
    jax.config.update("jax_enable_x64", True)
    try:
        cfg = QuantizerConfig(mode="abs", error_bound=1e-9, dtype="float64")
        x = RNG.standard_normal(2048)
        y = np.asarray(roundtrip_dense(jnp.asarray(x, jnp.float64), cfg))
        mask = np.isfinite(x)
        assert np.all(np.abs(x[mask] - y[mask]) <= 1e-9)
        cfgr = QuantizerConfig(mode="rel", error_bound=1e-6, dtype="float64",
                               bin_bits=32)
        yr = np.asarray(roundtrip_dense(jnp.asarray(x, jnp.float64), cfgr))
        err = np.abs(x[mask & (x != 0)] - yr[mask & (x != 0)]) / np.abs(
            x[mask & (x != 0)])
        assert np.all(err <= 1e-6)
    finally:
        jax.config.update("jax_enable_x64", False)
