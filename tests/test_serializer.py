"""Host byte-stream serializer: roundtrip exactness vs the jit codec,
inline-outlier escape handling, and compression-ratio sanity."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (QuantizerConfig, compression_ratio, decode_dense,
                        deserialize, encode_dense, serialize)

RNG = np.random.default_rng(3)


def smooth_field(n=1 << 14, scale=1.0):
    """Synthetic scientific-like 1D field: smooth + small noise (compresses
    like SDRBench climate slices)."""
    t = np.linspace(0, 8 * np.pi, n)
    x = np.sin(t) * np.cos(3 * t) + 0.1 * RNG.standard_normal(n)
    return (x * scale).astype(np.float32)


@pytest.mark.parametrize("mode,eb", [("abs", 1e-3), ("rel", 1e-3),
                                     ("noa", 1e-4)])
def test_serialize_roundtrip_matches_device_decode(mode, eb):
    cfg = QuantizerConfig(mode=mode, error_bound=eb)
    x = smooth_field()
    x[::911] = np.nan
    x[::713] = np.inf
    stream = serialize(x, cfg)
    y, cfg2 = deserialize(stream)
    assert cfg2.mode == mode and cfg2.bin_bits == cfg.bin_bits
    if mode != "noa":
        # Host decode must equal device decode bit-for-bit (parity).
        dev = np.asarray(decode_dense(encode_dense(jnp.asarray(x), cfg), cfg))
        np.testing.assert_array_equal(y.view(np.uint32), dev.view(np.uint32))
    # And the guarantee holds either way.
    mask = np.isfinite(x)
    if mode == "abs":
        assert np.all(np.abs(x[mask].astype(np.float64) - y[mask]) <= eb)
    elif mode == "rel":
        m = mask & (x != 0)
        err = np.abs((x[m].astype(np.float64) - y[m]) / x[m].astype(np.float64))
        assert np.all(err <= eb)
    else:
        r = np.float64(x[mask].max()) - np.float64(x[mask].min())
        assert np.all(np.abs(x[mask].astype(np.float64) - y[mask]) <= eb * r)
    nf = ~mask
    assert np.array_equal(x[nf].view(np.uint32), y[nf].view(np.uint32))


def test_compression_ratio_beats_raw_for_smooth_data():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3)
    r = compression_ratio(smooth_field(), cfg)
    assert r > 1.5, f"expected >1.5x on smooth data, got {r:.2f}"


def test_ratio_decreases_with_tighter_bound():
    x = smooth_field()
    ratios = [compression_ratio(x, QuantizerConfig(mode="abs", error_bound=e))
              for e in (1e-2, 1e-4, 1e-6)]
    assert ratios[0] > ratios[1] > ratios[2]


def test_all_outlier_stream_roundtrips():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3)
    x = np.full(512, np.nan, np.float32)
    y, _ = deserialize(serialize(x, cfg))
    assert np.array_equal(x.view(np.uint32), y.view(np.uint32))


def test_escape_code_never_collides_with_valid_bin():
    # A value that would bin exactly at +maxbin must be an outlier, so the
    # escape code is unambiguous.
    cfg = QuantizerConfig(mode="abs", error_bound=0.5, bin_bits=8)
    x = (np.arange(-300, 300).astype(np.float32))  # bins = x, maxbin = 127
    stream = serialize(x, cfg)
    y, _ = deserialize(stream)
    assert np.all(np.abs(x.astype(np.float64) - y) <= 0.5)


@pytest.mark.parametrize("bits", [8, 16, 32])
def test_bin_widths(bits):
    cfg = QuantizerConfig(mode="abs", error_bound=1e-2, bin_bits=bits)
    x = smooth_field(4096)
    y, _ = deserialize(serialize(x, cfg))
    assert np.all(np.abs(x.astype(np.float64) - y) <= 1e-2)
