"""Property-based tests (hypothesis) on the system's invariants.

The invariant under test is the paper's headline guarantee: for ANY float32
input and ANY positive error bound, every decoded value is within the bound
or bit-identical.  Inputs are drawn from raw bit patterns so every special
class (denormal/NaN payload/inf/-0) is reachable."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import QuantizerConfig, roundtrip_dense
from repro.core import oracle_np as onp
from repro.core.quantizer import quantize_abs, quantize_rel

bit_arrays = st.lists(st.integers(0, (1 << 32) - 1), min_size=1, max_size=256)
bounds = st.floats(min_value=1e-12, max_value=1e6, allow_nan=False,
                   allow_infinity=False)


def _to_f32(bits):
    return np.array(bits, dtype=np.uint32).view(np.float32)


@settings(max_examples=200, deadline=None)
@given(bit_arrays, bounds)
def test_abs_guarantee_holds_for_any_input(bits, eb):
    cfg = QuantizerConfig(mode="abs", error_bound=eb)
    x = _to_f32(bits)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    fin = np.isfinite(x)
    assert np.all(np.abs(x[fin].astype(np.float64) - y[fin].astype(np.float64))
                  <= eb)
    assert np.array_equal(x[~fin].view(np.uint32), y[~fin].view(np.uint32))


@settings(max_examples=200, deadline=None)
@given(bit_arrays, st.floats(min_value=1e-7, max_value=0.5))
def test_rel_guarantee_holds_for_any_input(bits, eb):
    cfg = QuantizerConfig(mode="rel", error_bound=eb, bin_bits=32)
    x = _to_f32(bits)
    y = np.asarray(roundtrip_dense(jnp.asarray(x), cfg))
    m = np.isfinite(x) & (x != 0)
    err = np.abs(x[m].astype(np.float64) - y[m].astype(np.float64)) / np.abs(
        x[m].astype(np.float64))
    assert np.all(err <= eb)
    assert np.all(np.signbit(y[m]) == np.signbit(x[m]))
    assert np.array_equal(x[~m].view(np.uint32), y[~m].view(np.uint32))


@settings(max_examples=100, deadline=None)
@given(bit_arrays, bounds)
def test_jax_numpy_parity_property(bits, eb):
    cfg = QuantizerConfig(mode="abs", error_bound=eb)
    x = _to_f32(bits)
    jq = quantize_abs(jnp.asarray(x), cfg)
    nb, no, _ = onp.quantize_abs(x, cfg)
    assert np.array_equal(np.asarray(jq.bins), nb)
    assert np.array_equal(np.asarray(jq.outlier), no)


@settings(max_examples=100, deadline=None)
@given(bit_arrays, st.floats(min_value=1e-6, max_value=0.5))
def test_rel_jax_numpy_parity_property(bits, eb):
    cfg = QuantizerConfig(mode="rel", error_bound=eb, bin_bits=32)
    x = _to_f32(bits)
    jq = quantize_rel(jnp.asarray(x), cfg)
    nb, no, _, ns = onp.quantize_rel(x, cfg)
    assert np.array_equal(np.asarray(jq.bins), nb)
    assert np.array_equal(np.asarray(jq.outlier), no)
