"""Compressed cross-pod gradient all-reduce: correctness of the mean, the
elementwise residual bound (the paper's guarantee as a systems property),
the overflow fallback, and end-to-end training equivalence.

Needs >1 device for the 'pod' axis -> runs in a subprocess with
xla_force_host_platform_device_count (the main pytest process already
locked jax to 1 CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compression.grads import (GradCompressionConfig,
                                         compressed_mean,
                                         compressed_mean_tree)

    # Version compat: jax.sharding.AxisType and the public jax.shard_map
    # (with axis_names/check_vma) only exist on newer JAX.  Older releases
    # get an explicit-Mesh + full-manual jax.experimental shard_map (the
    # unused data/model axes are simply manual-and-idle there).
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    else:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

    if hasattr(jax, "shard_map"):
        def smap(f, in_specs, out_specs):
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names={"pod"},
                                 check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        def smap(f, in_specs, out_specs):
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

    cfg = GradCompressionConfig(eb_rel=2.0 ** -8, bin_bits=8,
                                outlier_cap_frac=1 / 16)

    rng = np.random.default_rng(0)
    g_global = rng.standard_normal((2, 4096)).astype(np.float32)
    g_global[0, 7] = 90.0      # outlier in pod 0's gradient
    g_global[1, 9] = -70.0

    def podwise(g):
        mean, resid = compressed_mean(g, cfg, "pod")
        return mean, resid

    mapped = smap(podwise, P("pod", None),
                  (P("pod", None), P("pod", None)))
    gd = jax.device_put(jnp.asarray(g_global),
                        NamedSharding(mesh, P("pod", None)))
    mean, resid = jax.jit(mapped)(gd)
    mean = np.asarray(mean)
    resid = np.asarray(resid)

    true_mean = g_global.mean(axis=0)
    # both pods must hold the SAME mean
    assert np.array_equal(mean[0], mean[1]), "pods disagree on the mean"
    # each pod's contribution error is bounded by its eb -> mean error
    # bounded by mean of ebs
    ebs = [cfg.eb_rel * np.sqrt(np.mean(g_global[i] ** 2)) for i in (0, 1)]
    tol = float(np.mean(ebs)) * 1.01
    err = np.abs(mean[0] - true_mean)
    assert err.max() <= tol, (err.max(), tol)
    # outliers shipped EXACTLY: at index 7 the error comes only from pod1's
    # quantization
    assert err[7] <= ebs[1] * 0.51, "outlier slot not exact"
    # residual elementwise bound (error feedback is provably small)
    for i in (0, 1):
        assert np.abs(resid[i]).max() <= ebs[i] * 1.01
    print("MEAN_OK")

    # overflow path: tensor with > cap outliers falls back lossless
    g2 = np.zeros((2, 1024), np.float32)
    g2[:, :600] = rng.standard_normal((2, 600)) * 1000  # huge spread
    g2[:, 600:] = rng.standard_normal((2, 424)) * 1e-6
    cfg2 = GradCompressionConfig(eb_rel=2.0 ** -16, bin_bits=8,
                                 outlier_cap_frac=1 / 256)
    g2d = jax.device_put(jnp.asarray(g2), NamedSharding(mesh, P("pod", None)))
    mapped2 = smap(lambda g: compressed_mean(g, cfg2, "pod"),
                   P("pod", None), (P("pod", None), P("pod", None)))
    m2, r2 = jax.jit(mapped2)(g2d)
    m2 = np.asarray(m2)
    np.testing.assert_allclose(m2[0], g2.mean(0), rtol=1e-6)  # lossless
    assert np.abs(np.asarray(r2)).max() == 0.0
    print("OVERFLOW_OK")

    # tree version with error feedback accumulates unbiased-ly
    tree = {"a": jnp.asarray(g_global), "b": jnp.asarray(g_global * 0.5)}
    resid0 = jax.tree.map(jnp.zeros_like, tree)
    mapped3 = smap(
        lambda t, r: compressed_mean_tree(t, r, cfg, "pod"),
        ({"a": P("pod", None), "b": P("pod", None)},) * 2,
        ({"a": P("pod", None), "b": P("pod", None)},) * 2)
    tree_d = jax.tree.map(lambda x: jax.device_put(
        x, NamedSharding(mesh, P("pod", None))), tree)
    m3, r3 = jax.jit(mapped3)(tree_d, resid0)
    assert np.isfinite(np.asarray(m3["a"])).all()
    print("TREE_OK")
""")


@pytest.mark.slow
def test_compressed_pod_allreduce():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("MEAN_OK", "OVERFLOW_OK", "TREE_OK"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr)
