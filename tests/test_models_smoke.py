"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family, run one forward/train step (and a decode step) on CPU, assert
output shapes and no NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import build

ALL = sorted(registry.all_archs())
B, S = 2, 64


def make_batch(cfg, key):
    kt, kf = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kf, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            kf, (B, cfg.enc_context, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ALL)
def test_train_step_smoke(name):
    cfg = registry.get(name).reduced()
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    batch = make_batch(cfg, key)

    def step(p, b):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(p, b)
        p = jax.tree.map(
            lambda w, g: (w.astype(jnp.float32)
                          - 1e-3 * g.astype(jnp.float32)).astype(w.dtype),
            p, grads)
        return p, loss, ce

    params2, loss, ce = jax.jit(step)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss={float(loss)}"
    assert np.isfinite(float(ce))
    # params actually changed (bit-level: tiny lr deltas are sub-allclose)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes(name):
    cfg = registry.get(name).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    logits = bundle.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", ALL)
def test_decode_step_smoke(name):
    cfg = registry.get(name).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(3))
    seq = 128
    cache = bundle.make_cache(B, seq)
    step = jax.jit(lambda p, c, t, pos: bundle.serve_step(p, c, t, pos))
    logits, cache = step(params, cache, jnp.full((B, 1), 7, jnp.int32),
                         jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    logits2, cache = step(params, cache, jnp.full((B, 1), 311, jnp.int32),
                          jnp.int32(1))
    assert np.all(np.isfinite(np.asarray(logits2)))
    # cache state must influence later steps (it's actually being written)
    assert not np.allclose(np.asarray(logits), np.asarray(logits2))


def test_quantized_kv_decode_matches_raw_closely():
    """The paper technique in the serve loop: decode with the guaranteed-
    error-bounded quantized cache stays within the analytic output bound
    of the raw-cache decode."""
    from repro.compression.kv import kv_quantizer_config

    cfg = registry.get("deepseek-67b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(4))
    seq = 256
    kv_cfg = kv_quantizer_config()   # eb_rel = 2^-6

    raw = bundle.make_cache(B, seq)
    quant = bundle.make_cache(B, seq, quantized=True)
    step_raw = jax.jit(lambda p, c, t, i: bundle.serve_step(p, c, t, i))
    step_q = jax.jit(lambda p, c, t, i: bundle.serve_step(
        p, c, t, i, kv_cfg=kv_cfg))

    key = jax.random.PRNGKey(5)
    lr, lq = None, None
    for pos in range(200):           # crosses a page boundary (PAGE=128)
        tok = jax.random.randint(jax.random.fold_in(key, pos), (B, 1), 0,
                                 cfg.vocab)
        lr, raw = step_raw(params, raw, tok, jnp.int32(pos))
        lq, quant = step_q(params, quant, tok, jnp.int32(pos))
    lr, lq = np.asarray(lr), np.asarray(lq)
    assert np.all(np.isfinite(lq))
    # bounded perturbation, not bit-equality: eb_rel=2^-6 per page max
    assert np.max(np.abs(lr - lq)) / (np.max(np.abs(lr)) + 1e-9) < 0.15
    # quantized pages were actually written
    assert np.asarray(jnp.any(quant.k.bins != 0))


def test_param_counts_match_analytic():
    for name in ALL:
        cfg = registry.get(name)
        bundle = build(cfg)
        got = bundle.n_params()
        want = cfg.param_count()
        # analytic formula tracks the spec tree within 5% (norms, biases)
        assert abs(got - want) / want < 0.05, (name, got, want)
