"""Shared test helpers.

`shard_map_compat` is the one copy of the JAX shard_map version shim the
in-process collective tests share (the subprocess scripts in
test_grad_compression.py / test_transport.py keep inline copies — they
must be self-contained source strings).  The API has already shifted
once (check_rep -> check_vma, axis_names added); keeping the guard in
one place means the next shift is one edit.
"""
import jax


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names=("pod",)):
    """Version-compat shard_map: the public jax.shard_map
    (axis_names/check_vma) when this JAX has it, else the
    jax.experimental full-manual one (check_rep=False)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(axis_names), check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
