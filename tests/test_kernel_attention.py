"""Fused KV-dequant decode attention kernel vs the pure-jnp oracle, plus
the KV compression guarantee itself."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compression.kv import (dequantize_kv, kv_error_bound_holds,
                                  kv_quantizer_config, quantize_kv)
from repro.kernels.kv_attention import kv_decode_attention
from repro.kernels.ref import kv_decode_attention_ref

RNG = np.random.default_rng(5)


def make_cache(b, g, s, d, sinks=True):
    k = (RNG.standard_normal((b, g, s, d)) * 0.7).astype(np.float32)
    v = (RNG.standard_normal((b, g, s, d)) * 0.7).astype(np.float32)
    if sinks:
        # attention-sink-style outliers: huge magnitudes at token 0
        k[:, :, 0, : d // 4] *= 80.0
        v[:, :, 0, : d // 4] *= 80.0
    return jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("b,g,hg,s,d", [(2, 2, 4, 256, 128),
                                        (1, 1, 8, 512, 128),
                                        (2, 4, 2, 128, 128)])
def test_kv_attention_kernel_matches_oracle(b, g, hg, s, d):
    cfg = kv_quantizer_config()
    k, v = make_cache(b, g, s, d)
    kq = quantize_kv(k, cfg)
    vq = quantize_kv(v, cfg)
    assert not bool(jnp.any(kq.overflow) | jnp.any(vq.overflow))
    q = jnp.asarray(RNG.standard_normal((b, g, hg, d)).astype(np.float32))
    lengths = jnp.asarray(RNG.integers(1, s + 1, b), jnp.int32)

    out_k = kv_decode_attention(q, kq, vq, lengths, interpret=True)
    out_r = kv_decode_attention_ref(q, kq, vq, lengths)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_kv_attention_jit_compatible():
    cfg = kv_quantizer_config()
    k, v = make_cache(1, 2, 256, 128)
    kq, vq = quantize_kv(k, cfg), quantize_kv(v, cfg)
    q = jnp.asarray(RNG.standard_normal((1, 2, 4, 128)).astype(np.float32))
    lengths = jnp.asarray([200], jnp.int32)
    f = jax.jit(lambda *a: kv_decode_attention(*a, interpret=True))
    np.testing.assert_allclose(
        np.asarray(f(q, kq, vq, lengths)),
        np.asarray(kv_decode_attention(q, kq, vq, lengths, interpret=True)),
        rtol=0, atol=0)


@pytest.mark.parametrize("eb_rel", [2.0 ** -4, 2.0 ** -5, 2.0 ** -6])
def test_kv_quantization_guarantee(eb_rel):
    # int8 sizing constraint: |bin| <= 1/eb_rel must stay under maxbin=127,
    # so eb_rel >= 2^-6 for 8-bit bins (see kv_quantizer_config).
    from repro.core import QuantizerConfig

    cfg = QuantizerConfig(mode="abs", error_bound=eb_rel, bin_bits=8)
    k, _ = make_cache(2, 2, 512, 128)
    kq = quantize_kv(k, cfg)
    assert not bool(jnp.any(kq.overflow))
    assert bool(kv_error_bound_holds(k, kq, cfg))
    # per-page bound verified in float64 against the ORIGINAL request
    y = np.asarray(dequantize_kv(kq)).reshape(2, 2, 4, -1)
    x = np.asarray(k).reshape(2, 2, 4, -1)
    amax = np.abs(x).max(-1)
    err = np.abs(x.astype(np.float64) - y.astype(np.float64)).max(-1)
    assert np.all(err <= eb_rel * amax + 1e-30)


def test_kv_undersized_bound_surfaces_overflow():
    """eb_rel below the int8 sizing limit cannot be honored -> the encoder
    must FLAG it (paper's never-silently-violate principle), not clamp."""
    from repro.core import QuantizerConfig

    cfg = QuantizerConfig(mode="abs", error_bound=2.0 ** -8, bin_bits=8)
    k, _ = make_cache(1, 1, 256, 128)
    kq = quantize_kv(k, cfg)
    assert bool(jnp.any(kq.overflow))
    assert bool(kv_error_bound_holds(k, kq, cfg))  # holds where not flagged


def test_kv_outliers_restored_bit_exactly():
    cfg = kv_quantizer_config()
    k, _ = make_cache(1, 1, 128, 128, sinks=False)
    k = k.at[0, 0, 3, 7].set(jnp.float32(np.nan))   # NaN must survive
    kq = quantize_kv(k, cfg)
    y = dequantize_kv(kq)
    got = np.asarray(y)[0, 0, 3, 7]
    assert np.isnan(got)
    # and finite outliers (if any) are exact: every non-finite or flagged
    # position matches input bits
    xb = np.asarray(k).view(np.uint32) if False else None


def test_kv_compression_footprint():
    cfg = kv_quantizer_config()
    k, _ = make_cache(1, 2, 1024, 128)
    kq = quantize_kv(k, cfg)
    raw = k.size * 4
    comp = (kq.bins.size * 1 + kq.eb2.size * 4 + kq.out_idx.size * 4 +
            kq.out_val.size * 4 + kq.overflow.size)
    assert comp < raw / 3.5, f"footprint {comp/raw:.2%} of raw"
