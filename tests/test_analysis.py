"""Guarantee linter (DESIGN.md §13): every rule fires on its golden bad
snippet, the real tree is clean, suppressions demand reasons, and the
contract checker catches a seeded §7 dispatch-table desync.

Layer 1 is pure stdlib — these tests import no JAX except for the
clean-tree gate (which runs Layer 2's registry contracts on the CPU
backend exactly as CI does).
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import RULES, lint_file, lint_paths
from repro.analysis.walker import parse_suppressions
from repro.analysis import dispatch as D
from repro.analysis.__main__ import main as analysis_main

REPO = Path(__file__).resolve().parents[1]


def _lint(tmp_path, src, rules=None, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return lint_file(p, rules=rules)


# --------------------------------------------- golden snippets per rule ---

GOLDEN = {
    "GL001": """
        import jax.numpy as jnp

        def wire_bits(stages, lens):
            return jnp.sum(lens.astype(jnp.float32)) * 32.0
        """,
    "GL002": """
        import jax.numpy as jnp

        def apply_feedback(x, bins, eb2, eb):
            recon = bins * eb2
            ok = jnp.abs(x - recon) <= eb
            return ok
        """,
    "GL003": """
        import jax.numpy as jnp

        def audit_violations(diff, eb, TIGHTEN):
            return jnp.sum(diff > eb * TIGHTEN)
        """,
    "GL004": """
        def encode_bins(bins, x):
            return bins - x
        """,
    "GL005": """
        def read_payload(payload, payload_len):
            return payload[:payload_len]
        """,
    "GL006": """
        import numpy as np

        rng = np.random.default_rng(42)
        """,
    "GL007": """
        def encode_packed(x):
            print("encoding", x.shape)
            return x
        """,
}


@pytest.mark.parametrize("rule", sorted(GOLDEN))
def test_golden_snippet_fires(tmp_path, rule):
    findings = _lint(tmp_path, GOLDEN[rule], rules=[rule])
    assert findings, f"{rule} missed its golden snippet"
    assert all(f.rule == rule for f in findings)
    assert all(f.hint for f in findings), "findings must carry a fix hint"


@pytest.mark.parametrize("rule", sorted(GOLDEN))
def test_clean_twin_does_not_fire(tmp_path, rule):
    """The sanctioned version of each pattern stays clean."""
    clean = {
        # convert-ONCE: float lives on the sum result, not inside it
        "GL001": """
            import jax.numpy as jnp

            def wire_bits(stages, lens):
                return 32.0 * jnp.sum(lens).astype(jnp.float32)
            """,
        "GL002": """
            import jax.numpy as jnp

            def apply_feedback(x, bins, eb2, eb):
                recon = bins * eb2
                ok = jnp.isfinite(recon) & (jnp.abs(x - recon) <= eb)
                return ok
            """,
        "GL003": """
            import jax.numpy as jnp

            def audit_violations(diff, eb):
                return jnp.sum(diff > eb)
            """,
        "GL004": """
            def encode_bins(bins, prev_bins):
                return bins - prev_bins
            """,
        "GL005": """
            import jax.numpy as jnp

            def read_payload(payload, payload_len):
                k = jnp.minimum(payload_len, payload.shape[-1])
                return payload[:k]
            """,
        "GL006": """
            import numpy as np
            import zlib

            rng = np.random.default_rng(zlib.crc32(b"suite-name"))
            """,
        "GL007": """
            def encode_packed(x):
                return x

            def report(x):
                print("host-side caller", x.shape)
            """,
    }
    assert _lint(tmp_path, clean[rule], rules=[rule]) == []


def test_gl006_flags_unseeded_and_hash(tmp_path):
    src = """
        import numpy as np

        a = np.random.default_rng()
        b = np.random.default_rng(hash("suite"))
        """
    msgs = [f.message for f in _lint(tmp_path, src, rules=["GL006"])]
    assert any("unseeded" in m for m in msgs)
    assert any("hash()" in m for m in msgs)


# ------------------------------------------------------- suppressions ---

def test_suppression_with_reason_suppresses(tmp_path):
    src = """\
        import numpy as np

        # repro: noqa GL006 -- golden-snippet fixture, not a benchmark
        rng = np.random.default_rng(42)
        """
    assert _lint(tmp_path, src) == []


def test_suppression_without_reason_is_gl000(tmp_path):
    src = """\
        import numpy as np

        # repro: noqa GL006
        rng = np.random.default_rng(42)
        """
    findings = _lint(tmp_path, src)
    rules = {f.rule for f in findings}
    assert "GL000" in rules, "reasonless noqa must be flagged"
    # a reasonless noqa suppresses NOTHING (walker docstring): the
    # underlying finding fires too, so the gate stays red until the
    # exception is justified
    assert "GL006" in rules


def test_parse_suppressions_multi_rule():
    sup, bad = parse_suppressions(
        "# repro: noqa GL001, GL005 -- fixture file\n", "f.py")
    assert sup == {"GL001", "GL005"} and bad == []


# ------------------------------------------------- registry + clean tree ---

def test_every_registered_rule_has_a_golden_snippet():
    assert set(GOLDEN) == set(RULES) - {"GL000"}, (
        "add a golden snippet (and §13 row) for every new rule")


def test_lint_paths_walks_directories(tmp_path):
    (tmp_path / "pkg").mkdir()
    (tmp_path / "pkg" / "m.py").write_text(
        "def encode_packed(x):\n    print(x)\n    return x\n")
    findings = lint_paths([tmp_path])
    assert [f.rule for f in findings] == ["GL007"]


def test_clean_tree_gate_exits_zero(capsys):
    """The CI gate on the real tree: Layer 1 + Layer 2, zero new
    findings.  This is the same invocation CI runs."""
    rc = analysis_main([])
    out = capsys.readouterr().out
    assert rc == 0, f"analysis gate failed:\n{out}"


# -------------------------------------------------- dispatch-table sync ---

def _table(rows):
    body = "\n".join(f"| `{c}` | {k} |" for c, k in rows)
    return ("**Kernel dispatch.**\n\n"
            "| chain | fused kernel |\n|---|---|\n" + body + "\n")


def test_dispatch_checker_accepts_real_table():
    rows = D.parse_dispatch_table((REPO / "DESIGN.md").read_text())
    assert len(rows) >= 5
    assert D.check_dispatch(rows) == []


def test_dispatch_checker_catches_seeded_desync():
    """Swap the §7 table's pack-only row to the wrong kernel: the
    checker must notice the routing mismatch."""
    text = _table([
        ("quant\\|pack", "`kernels/lossless.py::encode_packed_lc`"),
        ("quant\\|pack\\|zero` or `\\|narrow",
         "`kernels/lossless.py::encode_packed_lc`"),
        ("...\\|narrow\\|ent", "open slot: jit reference until then"),
        ("pred\\|... (any §9 chain)", "open slot: jit reference until then"),
        ("anything else", "jit reference (`core/codec.py`)"),
    ])
    rows = D.parse_dispatch_table(text)
    assert len(rows) == 5
    findings = D.check_dispatch(rows)
    assert any(f.rule == "RC005" and "quant|pack" in f.message
               for f in findings), findings


def test_dispatch_checker_flags_missing_table():
    findings = D.check_dispatch(D.parse_dispatch_table("no table here"))
    assert findings and findings[0].rule == "RC005"
