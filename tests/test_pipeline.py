"""Pipeline API (DESIGN.md §7): spec parse/print roundtrip, pipeline-vs-
legacy bit-identity on every chain the pre-pipeline surfaces could
express, fused-kernel vs jit-fallback dispatch parity, the shuffle stage,
and shard_map transparency of the unified CompressedShard.

Everything wire-shaped here is a bit-equality test: the pipeline replaced
the forked *_lc surfaces, so ANY discrepancy against them — one word, one
header code, one accounted byte — is a regression, not a quality delta."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import QuantizerConfig, codec
from repro.core.pipeline import (Encoded, PackStage, Pipeline,
                                 QuantStage, ShuffleStage, STAGES,
                                 parse_pipeline)

RNG = np.random.default_rng(71)


def _mix(n):
    x = (RNG.standard_normal(n) * 3e-3).astype(np.float32)
    x[RNG.random(n) < 0.6] = 0.0
    if n >= 8:
        x[:8] = [np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-42,
                 np.finfo(np.float32).max, 5e-4]
    return x


def _mixed_sign_rel(n):
    """|x| straddles 1 with both signs -> mixed-sign log-domain bins."""
    mag = np.exp(RNG.standard_normal(n) * 1.5)
    sgn = np.where(RNG.random(n) < 0.5, -1.0, 1.0)
    return (mag * sgn).astype(np.float32)


# ------------------------------------------------------- spec roundtrip ---

@pytest.mark.parametrize("spec", [
    "abs:0.001|pack:16",
    "rel:0.001|pack:8|zero|narrow",
    "noa:0.0001|pack:32|narrow",
    "abs:0.0001:cap=0.015625|pack:16|narrow",
    "rel:0.001|pack:32|shuffle:32|narrow",
    "abs:0.001:cap=0.25:dtype=float64|pack:16|zero",
    "abs:0.001|pack:8|zero|narrow|ent",
    "delta|abs:0.001|pack:16|narrow",
    "lorenzo|abs:0.001|pack:32|narrow|ent",
    "kvdelta|abs:0.001|pack:8|zero|narrow",
    "delta|kvdelta|abs:0.001|pack:16",
])
def test_spec_parse_print_roundtrip(spec):
    pipe = parse_pipeline(spec)
    assert parse_pipeline(pipe.spec()) == pipe
    # idempotent canonical form
    assert parse_pipeline(pipe.spec()).spec() == pipe.spec()


def test_bare_shuffle_inherits_pack_width():
    assert parse_pipeline("rel:0.001|pack:32|shuffle|narrow").stages[0] \
        == ShuffleStage(32)
    assert parse_pipeline("abs:0.001|pack:8|shuffle|zero").stages[0] \
        == ShuffleStage(8)


@pytest.mark.parametrize("bad", [
    "", "abs:0.001", "pack:8|abs:0.001", "abs:0.001|pack:12",
    "abs:0.001|pack:8|wavelet", "abs|pack:8", "abs:0.001:k=2|pack:8",
    "zero|abs:0.001|pack:8", "abs:0.001|pack:8|shuffle:9",
    "abs:0.001|pack:8|zero:5", "abs:0.001|pack:8|ent:5",
    "abs:0.001|pack:8|ent:k=2",
    "abs:0.001|delta|pack:8", "delta:3|abs:0.001|pack:8",
    "delta|lorenzo",
])
def test_spec_parse_rejects_malformed(bad):
    with pytest.raises((ValueError, KeyError)):
        parse_pipeline(bad)


def test_spec_roundtrip_property():
    pytest.importorskip("hypothesis")   # optional dev dep
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=80, deadline=None)
    @given(st.data())
    def run(data):
        mode = data.draw(st.sampled_from(["abs", "rel", "noa"]))
        eb = data.draw(st.floats(1e-30, 1e3, allow_nan=False,
                                 allow_infinity=False))
        cap = data.draw(st.sampled_from([0.125, 0.25, 1 / 64, 0.5]))
        bits = data.draw(st.sampled_from([8, 16, 32]))
        names = data.draw(st.lists(
            st.sampled_from(sorted(STAGES)), max_size=3))
        stages = tuple(STAGES[n](n, [], bits) for n in names)
        pipe = Pipeline(QuantStage(mode, float(eb), cap),
                        PackStage(bits), stages)
        assert parse_pipeline(pipe.spec()) == pipe

    run()


# ---------------------------------------- two-domain grammar fuzzer -------
#
# Random LEGAL chains from the full grammar {pred}|quant|pack|{word-stage}
# must (1) parse<->print roundtrip, (2) decode bit-transparent vs the
# stage-free quant|pack reference — every stage in BOTH domains is an
# exact inverse — and (3) hold the §1 bound.  Word stages are drawn as
# subsequences of the canonical order; every subset is legal (verified
# exhaustively by the deterministic twin's superset sweep).

PRED_NAMES = ["delta", "lorenzo", "kvdelta"]
WORD_ORDER = ["shuffle", "zero", "narrow", "ent"]


def _grammar_chain_is_transparent(preds, mode, eb, bits, words, x):
    """One fuzzer case, shared with the deterministic twin."""
    n = x.size
    base = f"{mode}:{eb!r}|pack:{bits}"
    spec = "".join(p + "|" for p in preds) + base \
        + "".join("|" + w for w in words)
    pipe = parse_pipeline(spec)
    assert parse_pipeline(pipe.spec()) == pipe
    assert parse_pipeline(pipe.spec()).spec() == pipe.spec()
    ref = parse_pipeline(base)
    xj = jnp.asarray(x)
    y0 = np.asarray(ref.decode(ref.encode(xj, kernels=False), n=n,
                               kernels=False))
    y = np.asarray(pipe.decode(pipe.encode(xj, kernels=False), n=n,
                               kernels=False))
    np.testing.assert_array_equal(y.view(np.uint32), y0.view(np.uint32),
                                  err_msg=spec)
    fin = np.isfinite(x)
    np.testing.assert_array_equal(x[~fin].view(np.uint32),
                                  y[~fin].view(np.uint32), err_msg=spec)
    if mode == "abs":
        assert np.abs(x[fin].astype(np.float64) - y[fin]).max() <= eb, spec
    else:
        m = fin & (x != 0)
        assert np.abs((x[m].astype(np.float64) - y[m])
                      / x[m].astype(np.float64)).max() <= eb, spec


def test_two_domain_grammar_fuzzer():
    pytest.importorskip("hypothesis")   # optional dev dep
    from hypothesis import given, settings, strategies as st

    n = 6000
    x = _mix(n)

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def run(data):
        preds = data.draw(st.lists(st.sampled_from(PRED_NAMES),
                                   max_size=2, unique=True))
        mode = data.draw(st.sampled_from(["abs", "rel"]))
        eb = data.draw(st.sampled_from([1e-3, 1e-2]))
        bits = data.draw(st.sampled_from([8, 16, 32]))
        words = [w for w in WORD_ORDER if data.draw(st.booleans())]
        _grammar_chain_is_transparent(preds, mode, eb, bits, words, x)

    run()


@pytest.mark.parametrize("preds,words", [
    ([], ["zero", "narrow"]),
    ([], ["shuffle", "zero", "narrow", "ent"]),
    (["delta"], []),
    (["delta"], ["narrow", "ent"]),
    (["lorenzo"], ["shuffle", "narrow"]),
    (["kvdelta"], ["zero", "narrow", "ent"]),
    (["delta", "kvdelta"], ["zero"]),
    (["kvdelta", "lorenzo"], ["shuffle", "zero", "narrow", "ent"]),
])
def test_two_domain_grammar_deterministic_sweep(preds, words):
    """Deterministic twin of the fuzzer (hypothesis is an optional dev
    dep): representative chains over both domains, every check shared."""
    x = _mix(6000)
    for mode, bits in [("abs", 8), ("rel", 16)]:
        _grammar_chain_is_transparent(preds, mode, 1e-3, bits, words, x)

LEGACY_CHAINS = [(m, bb, st) for m in ("abs", "rel") for bb in (8, 16)
                 for st in (None, "zero", "narrow")]


@pytest.mark.parametrize("mode,bin_bits,stage", LEGACY_CHAINS)
def test_pipeline_matches_legacy_chain(mode, bin_bits, stage):
    """Every chain expressible before the pipeline API must produce the
    bit-identical wire arrays, accounting, and decode."""
    n = 70_000
    x = jnp.asarray(_mix(n))
    cfg = QuantizerConfig(mode=mode, error_bound=1e-2, bin_bits=bin_bits)
    spec = f"{mode}:0.01|pack:{bin_bits}" + (f"|{stage}" if stage else "")
    pipe = parse_pipeline(spec)
    assert pipe.qcfg() == cfg
    enc = pipe.encode(x, kernels=False)

    ep = codec.encode_packed(x, cfg)
    if stage is None:
        legacy, hdr = ep, None
        np.testing.assert_array_equal(np.asarray(enc.payload),
                                      np.asarray(ep.words))
        assert pipe.wire_bits(enc, n) == ep.wire_bits()
    else:
        lc = codec.encode_lossless(ep, stage)
        np.testing.assert_array_equal(np.asarray(enc.payload),
                                      np.asarray(lc.payload))
        np.testing.assert_array_equal(np.asarray(enc.headers[0]),
                                      np.asarray(lc.header_words))
        assert int(enc.payload_len) == int(lc.payload_len)
        assert float(pipe.wire_bits(enc, n)) == float(lc.wire_bits())
    for field in ("out_idx", "out_payload", "n_outliers", "overflow"):
        np.testing.assert_array_equal(np.asarray(getattr(enc, field)),
                                      np.asarray(getattr(ep, field)),
                                      err_msg=field)
    if mode == "rel":
        np.testing.assert_array_equal(np.asarray(enc.sign_words),
                                      np.asarray(ep.sign_words))

    y_pipe = np.asarray(pipe.decode(enc, n=n, kernels=False))
    y_legacy = np.asarray(codec.decode_packed(ep, cfg, n=n))
    np.testing.assert_array_equal(y_pipe.view(np.uint32),
                                  y_legacy.view(np.uint32))


@pytest.mark.parametrize("spec", [
    "abs:0.01|pack:16", "abs:0.01|pack:8|narrow", "rel:0.01|pack:16|zero",
    "noa:0.001|pack:16|narrow",
])
def test_kernel_dispatch_matches_reference(spec):
    """The fused Pallas dispatch (interpret mode) must be bit-identical,
    field for field, to the jit reference fallback."""
    x = jnp.asarray(_mix(60_000))
    pipe = parse_pipeline(spec)
    a = pipe.encode(x, kernels=False)
    b = pipe.encode(x, kernels=True, interpret=True)
    for fa, fb, name in zip(a, b, Encoded._fields):
        if name == "headers":
            for ha, hb in zip(fa, fb):
                np.testing.assert_array_equal(np.asarray(ha),
                                              np.asarray(hb))
        elif fa is None:
            assert fb is None, name
        else:
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb),
                                          err_msg=name)
    ya = pipe.decode(a, n=x.size, kernels=False)
    yb = pipe.decode(b, n=x.size, kernels=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ya).view(np.uint32),
                                  np.asarray(yb).view(np.uint32))


def test_unknown_chain_falls_back_to_reference():
    pipe = parse_pipeline("rel:0.01|pack:16|shuffle|narrow")
    assert pipe.kernel_dispatch() is None
    x = jnp.asarray(_mix(30_000))
    a = pipe.encode(x, kernels=False)
    b = pipe.encode(x, kernels=True, interpret=True)   # falls back
    np.testing.assert_array_equal(np.asarray(a.payload),
                                  np.asarray(b.payload))


@pytest.mark.parametrize("spec", [
    "abs:0.01|pack:8|zero|narrow",           # stacked chunk stages
    "rel:0.01|pack:16|shuffle|narrow",
    "rel:0.01|pack:32|shuffle|zero|narrow",
    "noa:0.0001|pack:32|shuffle:32",
    "abs:0.01|pack:8|narrow|ent",            # entropy stage on top
    "rel:0.01|pack:16|shuffle|narrow|ent",
    "noa:0.0001|pack:32|ent",                # ent straight after pack
])
def test_novel_chain_roundtrip_holds_guarantee(spec):
    """Chains the forked surfaces could NOT express: decode must still be
    the exact inverse and the §1 bound must hold (specials bit-exact)."""
    n = 50_000
    x = _mix(n)
    pipe = parse_pipeline(spec)
    y = np.asarray(pipe.roundtrip(jnp.asarray(x), kernels=False))
    fin = np.isfinite(x)
    np.testing.assert_array_equal(x[~fin].view(np.uint32),
                                  y[~fin].view(np.uint32))
    eb = pipe.quant.eb
    if pipe.quant.mode == "abs":
        assert np.abs(x[fin].astype(np.float64) - y[fin]).max() <= eb
    elif pipe.quant.mode == "rel":
        m = fin & (x != 0)
        rel = np.abs((x[m].astype(np.float64) - y[m])
                     / x[m].astype(np.float64))
        assert rel.max() <= eb


# ----------------------------------------------------------- ent stage ----

def test_every_registry_preset_extended_with_ent_is_bit_transparent():
    """Appending `|ent` to ANY registry preset must leave the decoded
    stream bit-identical (the stage is an exact inverse) while the
    encode/decode dispatch still works end to end."""
    from repro.configs.registry import PIPELINES, get_pipeline

    n = 20_000
    x = jnp.asarray(_mix(n))
    for name in sorted(PIPELINES):
        spec = get_pipeline(name)
        if spec.endswith("|ent"):
            continue                      # already entropy-terminated
        base = parse_pipeline(spec)
        ext = parse_pipeline(spec + "|ent")
        eb = 1e-2 if base.quant.eb == 1.0 else None   # placeholder bounds
        y0 = np.asarray(base.decode(base.encode(x, eb=eb, kernels=False),
                                    n=n, kernels=False))
        y1 = np.asarray(ext.decode(ext.encode(x, eb=eb, kernels=False),
                                   n=n, kernels=False))
        np.testing.assert_array_equal(y0.view(np.uint32),
                                      y1.view(np.uint32), err_msg=name)


def test_ent_chain_falls_back_to_reference_dispatch():
    pipe = parse_pipeline("abs:0.01|pack:16|narrow|ent")
    assert pipe.kernel_dispatch() is None
    x = jnp.asarray(_mix(30_000))
    a = pipe.encode(x, kernels=False)
    b = pipe.encode(x, kernels=True, interpret=True)   # falls back
    np.testing.assert_array_equal(np.asarray(a.payload),
                                  np.asarray(b.payload))
    np.testing.assert_array_equal(np.asarray(a.headers[1]),
                                  np.asarray(b.headers[1]))


def test_ent_wire_accounting_counts_transmitted_prefix_only():
    """wire_bits must count payload_len words + header content + the
    length field — never the capacity padding — and stage_report's last
    row must mirror it exactly."""
    n = 1 << 17
    x = np.zeros(n, np.float32)
    x[: n // 16] = RNG.standard_normal(n // 16).astype(np.float32) * 3e-3
    pipe = parse_pipeline("abs:0.001|pack:16|narrow|ent")
    enc = pipe.encode(jnp.asarray(x), kernels=False)
    sizes = pipe.stage_sizes(n)
    hdr = sum(st.header_content_bits(sz)
              for st, sz in zip(pipe.stages, sizes[:-1]))
    base = 64 + enc.out_idx.shape[0] * 64      # header + outlier table
    want = 32 * int(enc.payload_len) + hdr + 32 + base
    assert float(pipe.wire_bits(enc, n)) == want
    assert float(pipe.wire_bits(enc)) == want      # capacity-idempotent
    rows = pipe.stage_report(jnp.asarray(x))
    assert float(rows[-1][1]) == want


# ------------------------------------------------------- shuffle stage ----

@pytest.mark.parametrize("width", [8, 16, 32])
@pytest.mark.parametrize("n", [1, 37, 128, codec.LC_CHUNK + 1, 5000])
def test_shuffle_words_roundtrip(width, n):
    w = jnp.asarray(RNG.integers(0, 1 << 32, n, dtype=np.uint32))
    s = codec.shuffle_words(w, width)
    assert s.shape[0] == codec.shuffle_word_count(n)
    back = codec.unshuffle_words(s, n, width)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


def test_shuffle_preserves_zero_streams():
    w = jnp.zeros(4 * codec.LC_CHUNK, jnp.uint32)
    assert not np.asarray(codec.shuffle_words(w, 16)).any()


def test_shuffle_makes_narrow_fire_on_mixed_sign_bins():
    """The stage's reason to exist: on mixed-sign REL bins, narrow alone
    sits at its ~1x floor (sign extension sets the high bits of every
    word); shuffle's zigzag fold unlocks the width codes."""
    x = jnp.asarray(_mixed_sign_rel(1 << 18))
    plain = parse_pipeline("rel:0.001|pack:32|narrow")
    shuf = parse_pipeline("rel:0.001|pack:32|shuffle|narrow")
    b_plain = float(plain.wire_bits(plain.encode(x, kernels=False), x.size))
    b_shuf = float(shuf.wire_bits(shuf.encode(x, kernels=False), x.size))
    assert b_shuf < 0.75 * b_plain, (b_plain, b_shuf)
    # and the decoded streams are still bit-identical to each other
    ya = plain.decode(plain.encode(x, kernels=False), n=x.size,
                      kernels=False)
    yb = shuf.decode(shuf.encode(x, kernels=False), n=x.size, kernels=False)
    np.testing.assert_array_equal(np.asarray(ya).view(np.uint32),
                                  np.asarray(yb).view(np.uint32))


def test_stage_report_decomposes_the_ratio():
    x = jnp.asarray(_mix(1 << 17))
    pipe = parse_pipeline("abs:0.01|pack:16|shuffle|narrow")
    rows = pipe.stage_report(x)
    labels = [r[0] for r in rows]
    assert labels == ["raw", "abs:0.01|pack:16", "shuffle:16", "narrow"]
    enc = pipe.encode(x, kernels=False)
    assert float(rows[-1][1]) == float(pipe.wire_bits(enc, x.size))


def test_stage_report_matches_wire_bits_on_every_prefix():
    """Each stage_report row must equal the prefix pipeline's wire_bits —
    the accessor compression_ratio(per_stage=True) reports from must not
    drift from the one the collectives are measured with, including
    static (non-length-transmitting) prefixes."""
    x = jnp.asarray(_mix(1 << 16))
    pipe = parse_pipeline("abs:0.01|pack:16|shuffle|narrow")
    rows = pipe.stage_report(x)
    for i in range(len(pipe.stages) + 1):
        prefix = Pipeline(pipe.quant, pipe.pack, pipe.stages[:i])
        enc = prefix.encode(x, kernels=False)
        assert float(rows[1 + i][1]) == float(prefix.wire_bits(enc, x.size))


def test_compression_ratio_per_stage():
    from repro.core import compression_ratio
    x = _mix(1 << 16)
    cfg = QuantizerConfig(mode="abs", error_bound=1e-2, bin_bits=16)
    dev = compression_ratio(x, cfg, wire="device",
                            pipeline="abs:0.01|pack:16|narrow")
    rows = compression_ratio(x, cfg, wire="device",
                             pipeline="abs:0.01|pack:16|narrow",
                             per_stage=True)
    assert rows[-1][0] == "narrow"
    assert rows[-1][1] == pytest.approx(dev)


# --------------------------------------------------- unified grad shard ---

def test_compressed_shard_unifies_the_fork():
    """One CompressedShard for every chain: legacy field views, measured
    accounting equal to the pre-pipeline formulas."""
    from repro.compression.grads import (GradCompressionConfig,
                                         compress_shard, wire_bytes)
    n = 1 << 16
    g = jnp.asarray(_mix(n))
    plain = GradCompressionConfig(bin_bits=16)
    shard, _ = compress_shard(g, plain)
    assert shard.nbytes() == wire_bytes(n, plain)
    np.testing.assert_array_equal(np.asarray(shard.words),
                                  np.asarray(shard.enc.payload))

    staged = GradCompressionConfig(
        bin_bits=16, pipeline="abs:1.0:cap=0.015625|pack:16|narrow")
    shard_lc, _ = compress_shard(g, staged)
    # legacy CompressedShardLC.nbytes formula, reproduced exactly
    n_chunks = shard_lc.payload.size // codec.LC_CHUNK
    want = (4.0 * float(shard_lc.payload_len)
            + codec.lc_header_content_words(n_chunks) * 4 + 4
            + shard_lc.out_idx.size * 4 + shard_lc.out_payload.size * 4
            + 4 + 4)
    assert float(shard_lc.nbytes()) == want
    assert float(shard_lc.nbytes()) <= shard_lc.capacity_nbytes()
    # .words view decodes the stage chain back to the §4 plane
    np.testing.assert_array_equal(np.asarray(shard_lc.words),
                                  np.asarray(shard.words))


@pytest.mark.parametrize("spec", ["abs:1.0:cap=0.015625|pack:8|narrow",
                                  "abs:1.0:cap=0.015625|pack:8|shuffle|zero",
                                  "abs:1.0:cap=0.015625|pack:8|narrow|ent",
                                  "delta|abs:1.0:cap=0.015625|pack:8|narrow"])
def test_compressed_mean_pipeline_transparent_under_shard_map(spec):
    """compressed_mean through ANY pipeline must produce the same mean
    and residual bits as the stage-free wire (stages are exact), under
    the same shard_map collective — the unified CompressedShard is
    shard_map-transparent."""
    from jax.sharding import PartitionSpec as P

    from conftest import shard_map_compat
    from repro.compression.grads import GradCompressionConfig, compressed_mean

    n = 8192
    g = np.zeros(n, np.float32)
    g[:256] = 0.01
    g[-1] = 50.0                                   # exact-outlier path too
    mesh = jax.make_mesh((1,), ("pod",))

    def run(cfg):
        mapped = shard_map_compat(lambda x: compressed_mean(x, cfg, "pod"),
                                  mesh, P(), (P(), P()))
        return jax.jit(mapped)(jnp.asarray(g))

    base = GradCompressionConfig(eb_rel=2.0 ** -6, bin_bits=8,
                                 outlier_cap_frac=1 / 64)
    mean0, resid0 = run(base)
    mean1, resid1 = run(base._replace(pipeline=spec))
    np.testing.assert_array_equal(np.asarray(mean0).view(np.uint32),
                                  np.asarray(mean1).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(resid0).view(np.uint32),
                                  np.asarray(resid1).view(np.uint32))
    assert np.asarray(mean1)[-1] == g[-1]          # outlier still exact


# ------------------------------------------------------ unified PackedKV --

def test_pack_kv_stage_chains_roundtrip():
    from repro.compression.kv import (kv_quantizer_config, pack_kv,
                                      quantize_kv, unpack_kv)
    x = RNG.standard_normal((2, 3, 256, 64)).astype(np.float32)
    x[:, :, 160:, :] = 0.0
    q = quantize_kv(jnp.asarray(x), kv_quantizer_config())
    pk = pack_kv(q)
    for stages in ("zero", "narrow", "shuffle|narrow", "narrow|ent",
                   "kvdelta|zero|narrow", "kvdelta|narrow|ent"):
        p = pack_kv(q, stages=stages)
        back = unpack_kv(p)
        for a, b in zip(q, back):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(p.wire_nbytes()) < pk.nbytes(), stages


# -------------------------------------------------------- config guards ---

def test_grad_config_rejects_non_abs_pipelines():
    """compressed_mean's gather/dequant is ABS-only (per-tensor rms
    bound, no sign plane) — a REL/NOA spec must fail loudly, not corrupt
    the mean silently."""
    from repro.compression.grads import GradCompressionConfig
    for spec in ("rel:0.001|pack:8|narrow", "noa:0.0001|pack:8"):
        with pytest.raises(ValueError, match="abs"):
            GradCompressionConfig(pipeline=spec).pipe()


def test_header_words_view_semantics():
    """The legacy header_words view is the chunk coder's width-code
    plane: stage-free shards have none (AttributeError, not IndexError),
    and a headerless shuffle stage ahead of the chunk stage is skipped."""
    from repro.compression.grads import GradCompressionConfig, compress_shard
    g = jnp.asarray(_mix(1 << 14))
    plain, _ = compress_shard(g, GradCompressionConfig(bin_bits=16))
    with pytest.raises(AttributeError, match="header"):
        plain.header_words
    cfg = GradCompressionConfig(
        bin_bits=16, pipeline="abs:1.0:cap=0.015625|pack:16|shuffle|narrow")
    shard, _ = compress_shard(g, cfg)
    assert shard.header_words.size > 0
    np.testing.assert_array_equal(np.asarray(shard.header_words),
                                  np.asarray(shard.enc.headers[1]))


def test_grad_config_default_fields_build_stage_free_chain():
    """The eb_rel/bin_bits/outlier_cap_frac fields (no spec) must build
    the same stage-free pipeline the equivalent spec does."""
    from repro.compression.grads import GradCompressionConfig
    pipe = GradCompressionConfig(bin_bits=8, outlier_cap_frac=1 / 64).pipe()
    assert pipe.stages == ()
    spec_pipe = GradCompressionConfig(
        pipeline="abs:1.0:cap=0.015625|pack:8").pipe()
    assert pipe == spec_pipe
