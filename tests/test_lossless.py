"""Device-side lossless stage (DESIGN.md §6): bit-exact roundtrips over
arbitrary word streams, Pallas-interpret vs jit-reference parity, and
honest wire accounting through the gradient and KV wires.

Everything here is a bit-equality test: the lossless stage sits between
quantize+pack and the collective, so ANY discrepancy — one word, one chunk
code — is a guarantee violation, not a quality regression."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compression.grads import (GradCompressionConfig, compress_shard,
                                     wire_bytes)
from repro.compression.kv import (kv_quantizer_config, pack_kv,
                                  quantize_kv, unpack_kv)
from repro.core import (ENT_MAX_LEN, LC_CHUNK, LC_STAGES, QuantizerConfig,
                        decode_lossless, decode_packed, decode_words_ent,
                        decode_words_lc, encode_lossless, encode_packed,
                        encode_words_ent, encode_words_lc, ent_header_words,
                        lc_header_words, packed_word_count)
from repro.core.codec import ent_code_lengths, ent_header_content_words, lc_chunk_count
from repro.kernels import lossless as klc

RNG = np.random.default_rng(61)

# odd lengths, sub-chunk, exact-chunk, and multi-chunk word streams
WORD_SIZES = [1, 37, LC_CHUNK - 1, LC_CHUNK, LC_CHUNK + 1, 4 * LC_CHUNK,
              10 * LC_CHUNK + 13]


def _stream(n, pattern):
    if pattern == "allzero":
        return np.zeros(n, np.uint32)
    if pattern == "dense":
        return RNG.integers(0, 1 << 32, n, dtype=np.uint32)
    if pattern == "bytes":
        return RNG.integers(0, 1 << 8, n, dtype=np.uint32)
    if pattern == "halves":
        return RNG.integers(0, 1 << 16, n, dtype=np.uint32)
    if pattern == "outlier_chunk":
        # one hot chunk in an otherwise all-zero stream
        w = np.zeros(n, np.uint32)
        lo = (n // 2 // LC_CHUNK) * LC_CHUNK
        w[lo:lo + min(LC_CHUNK, n - lo)] = RNG.integers(
            0, 1 << 32, min(LC_CHUNK, n - lo), dtype=np.uint32)
        return w
    if pattern == "mixed":
        # per-chunk width classes drawn independently
        n_chunks = -(-n // LC_CHUNK)
        hi = np.array([0, 1 << 8, 1 << 16, 1 << 32],
                      np.uint64)[RNG.integers(0, 4, n_chunks)]
        w = (RNG.integers(0, 1 << 32, n_chunks * LC_CHUNK, dtype=np.uint64)
             % np.maximum(np.repeat(hi, LC_CHUNK), 1))
        return w[:n].astype(np.uint32)
    raise AssertionError(pattern)


PATTERNS = ("allzero", "dense", "bytes", "halves", "outlier_chunk", "mixed")


# ------------------------------------------------- word-stream roundtrip --

@pytest.mark.parametrize("stage", LC_STAGES)
@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("n", WORD_SIZES)
def test_words_lc_roundtrip_bitexact(n, pattern, stage):
    w = _stream(n, pattern)
    hw, payload, plen = encode_words_lc(jnp.asarray(w), stage)
    assert hw.shape[0] == lc_header_words(n)
    assert int(plen) <= payload.shape[0]
    back = np.asarray(decode_words_lc(hw, payload, n))
    np.testing.assert_array_equal(back, w)


def test_words_lc_zero_stream_is_headers_only():
    w = jnp.zeros(8 * LC_CHUNK, jnp.uint32)
    for stage in LC_STAGES:
        _, _, plen = encode_words_lc(w, stage)
        assert int(plen) == 0


def test_words_lc_narrow_beats_zero_on_byte_stream():
    w = jnp.asarray(_stream(8 * LC_CHUNK, "bytes"))
    _, _, plen_zero = encode_words_lc(w, "zero")
    _, _, plen_narrow = encode_words_lc(w, "narrow")
    assert int(plen_narrow) == int(plen_zero) // 4 == 2 * LC_CHUNK


def test_words_lc_dense_stream_costs_only_headers():
    n = 4 * LC_CHUNK + 7
    w = jnp.asarray(_stream(n, "dense"))
    hw, payload, plen = encode_words_lc(w, "narrow")
    # no chunk compresses -> payload is the (chunk-padded) stream verbatim
    assert int(plen) == 5 * LC_CHUNK
    np.testing.assert_array_equal(np.asarray(payload[:n]), np.asarray(w))


@pytest.mark.parametrize("stage", LC_STAGES)
def test_words_lc_roundtrip_property(stage):
    pytest.importorskip("hypothesis")   # optional dev dep
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def run(data):
        n = data.draw(st.integers(1, 3 * LC_CHUNK), label="n")
        seed = data.draw(st.integers(0, 2 ** 32 - 1), label="seed")
        shift = data.draw(st.sampled_from([0, 8, 16, 24, 31]), label="shift")
        r = np.random.default_rng(seed)
        w = (r.integers(0, 1 << 32, n, dtype=np.uint32)
             >> np.uint32(shift)).astype(np.uint32)
        w[r.random(n) < 0.5] = 0           # mix in zero runs
        hw, payload, plen = encode_words_lc(jnp.asarray(w), stage)
        back = np.asarray(decode_words_lc(hw, payload, n))
        np.testing.assert_array_equal(back, w)

    run()


# ----------------------------------------------- ent word-stream stage ----


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("n", [1, 37, LC_CHUNK, LC_CHUNK + 1,
                               4 * LC_CHUNK + 13])
def test_words_ent_roundtrip_bitexact(n, pattern):
    w = _stream(n, pattern)
    hw, payload, plen = encode_words_ent(jnp.asarray(w))
    assert hw.shape[0] == ent_header_words(n)
    assert int(plen) <= payload.shape[0]
    back = np.asarray(decode_words_ent(hw, payload, n))
    np.testing.assert_array_equal(back, w)


def test_words_ent_zero_stream_is_headers_only():
    w = jnp.zeros(8 * LC_CHUNK, jnp.uint32)
    _, _, plen = encode_words_ent(w)
    assert int(plen) == 0


def test_words_ent_chunks_never_cost_more_than_raw():
    """No chunk may exceed its raw 512 payload words: uniform bytes code
    at exactly 8 bits/byte (the cap boundary), and when a skewed global
    codebook would push a chunk's rare bytes past the cap the mode-2
    escape stores it verbatim instead."""
    # uniform random bytes: 8-bit codes -> full chunks cost exactly raw
    n = 4 * LC_CHUNK
    w = jnp.asarray(_stream(n, "dense"))
    hw, payload, plen = encode_words_ent(w)
    assert int(plen) == 4 * LC_CHUNK
    np.testing.assert_array_equal(np.asarray(decode_words_ent(hw, payload,
                                                              n)),
                                  np.asarray(w))
    # skewed codebook + one dense chunk: its rare bytes would code past
    # 32 * LC_CHUNK bits -> verbatim escape, still exactly raw cost
    w2 = np.ones(5 * LC_CHUNK, np.uint32)
    w2[:LC_CHUNK] = _stream(LC_CHUNK, "dense")
    hw2, payload2, plen2 = encode_words_ent(jnp.asarray(w2))
    np.testing.assert_array_equal(np.asarray(payload2[:LC_CHUNK]),
                                  w2[:LC_CHUNK])     # stored untouched
    assert int(plen2) <= 5 * LC_CHUNK
    np.testing.assert_array_equal(
        np.asarray(decode_words_ent(hw2, payload2, w2.size)), w2)


def test_words_ent_beats_narrow_on_skewed_bytes():
    """The stage's reason to exist: narrow stops at whole-byte widths —
    a skewed byte distribution across all four byte planes leaves its
    width codes nothing to do, while ent codes it near entropy.  The
    transmitted wire (payload + header content + length) must come in
    far under narrow's."""
    n = 16 * LC_CHUNK
    b = RNG.choice([0, 1, 2], (n, 4), p=[.7, .2, .1]).astype(np.uint32)
    w = jnp.asarray(b[:, 0] | (b[:, 1] << 8) | (b[:, 2] << 16)
                    | (b[:, 3] << 24))
    nc = lc_chunk_count(n)
    _, _, plen_n = encode_words_lc(w, "narrow")
    bits_n = 32 * int(plen_n) + 32 * -(-nc // 16) + 32
    _, _, plen_e = encode_words_ent(w)
    bits_e = 32 * int(plen_e) + 32 * ent_header_content_words(nc) + 32
    assert bits_e < 0.25 * bits_n, (bits_e, bits_n)


def test_words_ent_roundtrip_property():
    pytest.importorskip("hypothesis")   # optional dev dep
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def run(data):
        n = data.draw(st.integers(1, 3 * LC_CHUNK), label="n")
        seed = data.draw(st.integers(0, 2 ** 32 - 1), label="seed")
        shift = data.draw(st.sampled_from([0, 8, 16, 24, 31]), label="shift")
        r = np.random.default_rng(seed)
        w = (r.integers(0, 1 << 32, n, dtype=np.uint32)
             >> np.uint32(shift)).astype(np.uint32)
        w[r.random(n) < 0.5] = 0           # mix in zero runs
        hw, payload, plen = encode_words_ent(jnp.asarray(w))
        back = np.asarray(decode_words_ent(hw, payload, n))
        np.testing.assert_array_equal(back, w)

    run()


def test_ent_code_lengths_kraft_feasible():
    """Every histogram — uniform, skewed, degenerate — must yield
    lengths in [1, ENT_MAX_LEN] with Kraft sum <= 1 (a canonical prefix
    code exists), including the empty histogram of an all-zero stream."""
    cases = [np.zeros(256, np.int64),
             np.ones(256, np.int64),
             np.eye(1, 256, 0, dtype=np.int64).ravel() * 1000,
             RNG.integers(0, 1000, 256).astype(np.int64),
             np.array([2 ** 20] + [1] * 255, np.int64)]
    for hist in cases:
        lens = np.asarray(ent_code_lengths(jnp.asarray(hist, jnp.int32)))
        assert lens.min() >= 1 and lens.max() <= ENT_MAX_LEN, lens
        assert np.sum(2.0 ** -lens) <= 1.0 + 1e-12, np.sum(2.0 ** -lens)


# ------------------------------------------------- EncodedLC end-to-end ---

def _mix(n):
    x = (RNG.standard_normal(n) * 3e-3).astype(np.float32)
    x[RNG.random(n) < 0.6] = 0.0
    if n >= 8:
        x[:8] = [np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-42,
                 np.finfo(np.float32).max, 5e-4]
    return x


@pytest.mark.parametrize("stage", LC_STAGES)
@pytest.mark.parametrize("bin_bits", [8, 16])
@pytest.mark.parametrize("mode", ["abs", "rel", "noa"])
def test_lossless_stage_is_transparent(mode, bin_bits, stage):
    """decode(decode_lossless(encode_lossless(encode_packed(x)))) must be
    bit-identical to decoding the packed form directly — the stage cannot
    touch the guarantee."""
    n = 70_000
    cfg = QuantizerConfig(mode=mode, error_bound=1e-2, bin_bits=bin_bits)
    x = jnp.asarray(_mix(n))
    enc = encode_packed(x, cfg)
    n_words = packed_word_count(n, cfg.bin_bits)
    dec = decode_lossless(encode_lossless(enc, stage), n_words)
    np.testing.assert_array_equal(np.asarray(dec.words),
                                  np.asarray(enc.words))
    y_ref = np.asarray(decode_packed(enc, cfg, n=n))
    y_lc = np.asarray(decode_packed(dec, cfg, n=n))
    np.testing.assert_array_equal(y_ref.view(np.uint32),
                                  y_lc.view(np.uint32))


def test_lossless_wire_bits_sparse_beats_packed():
    n = 1 << 20
    cfg = QuantizerConfig(mode="abs", error_bound=1e-4, bin_bits=16,
                          outlier_cap_frac=1 / 64)
    x = np.zeros(n, np.float32)
    x[: n // 64] = RNG.standard_normal(n // 64) * 3e-3   # 1/64 live prefix
    enc = encode_packed(jnp.asarray(x), cfg)
    lc = encode_lossless(enc, "zero")
    assert float(lc.wire_bits()) < 0.1 * enc.wire_bits()


def test_lossless_wire_bits_dense_floor_is_header_plane():
    """On incompressible words the stage may only cost the header plane
    and padding — never more."""
    n = 1 << 18
    cfg = QuantizerConfig(mode="abs", error_bound=1e-4, bin_bits=16)
    x = jnp.asarray((RNG.standard_normal(n) * 3e-3).astype(np.float32))
    enc = encode_packed(x, cfg)
    lc = encode_lossless(enc, "narrow")
    n_words = packed_word_count(n, 16)
    n_chunks = -(-n_words // LC_CHUNK)
    overhead = (32 * -(-n_chunks // 16)                # header content
                + 32 * (LC_CHUNK - 1)                  # chunk padding
                + 32)                                  # transmitted length
    assert float(lc.wire_bits()) <= enc.wire_bits() + overhead


# ------------------------------------------------- Pallas kernel parity ---

@pytest.mark.parametrize("stage", LC_STAGES)
@pytest.mark.parametrize("pattern", ["allzero", "mixed", "dense"])
@pytest.mark.parametrize("n", [1, LC_CHUNK + 1, 10 * LC_CHUNK + 13])
def test_kernel_words_lc_matches_reference(n, pattern, stage):
    w = jnp.asarray(_stream(n, pattern))
    ref = encode_words_lc(w, stage)
    ker = klc.encode_words_lc(w, stage, interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    back = np.asarray(klc.decode_words_lc(ref[0], ref[1], n,
                                          interpret=True))
    np.testing.assert_array_equal(back, np.asarray(w))


@pytest.mark.parametrize("stage", LC_STAGES)
@pytest.mark.parametrize("bin_bits", [8, 16, 32])
@pytest.mark.parametrize("mode", ["abs", "rel"])
def test_fused_kernel_matches_reference(mode, bin_bits, stage):
    """encode_packed_lc (ONE fused quantize+pack+narrow HBM pass) must be
    bit-identical to the staged jit reference, field for field."""
    cfg = QuantizerConfig(mode=mode, error_bound=1e-2, bin_bits=bin_bits)
    x = jnp.asarray(_mix(100_000))
    ref = encode_lossless(encode_packed(x, cfg), stage)
    ker = klc.encode_packed_lc(x, cfg, stage=stage, interpret=True)
    for a, b, name in zip(ref, ker, ref._fields):
        if a is None:
            assert b is None, name
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_fused_kernel_tiling_invariance():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3, bin_bits=16)
    x = jnp.asarray(_mix(200_000))
    ref = encode_lossless(encode_packed(x, cfg), "narrow")
    for rows in (64, 256, 512):
        ker = klc.encode_packed_lc(x, cfg, stage="narrow", rows=rows,
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(ref.payload),
                                      np.asarray(ker.payload))
        np.testing.assert_array_equal(np.asarray(ref.header_words),
                                      np.asarray(ker.header_words))


# ------------------------------------------------------- gradient wire ----

def test_grad_shard_lc_roundtrip_and_accounting():
    n = (1 << 18) + 349
    cfg = GradCompressionConfig(
        bin_bits=16, pipeline="abs:1.0:cap=0.015625|pack:16|zero")
    g = np.zeros(n, np.float32)
    g[: n // 32] = RNG.standard_normal(n // 32) * 3e-3
    shard_lc, _ = compress_shard(jnp.asarray(g), cfg)
    # independent stage-free reference: the coded wire must decode back
    # to exactly the §4 plane a stage-free pipeline ships
    shard, _ = compress_shard(
        jnp.asarray(g),
        cfg._replace(pipeline="abs:1.0:cap=0.015625|pack:16"))
    n_words = packed_word_count(n, 16)
    back = decode_words_lc(shard_lc.header_words, shard_lc.payload, n_words)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(shard.words))
    # measured transmitted bytes: far under the packed wire for sparse g,
    # and bounded by capacity
    assert float(shard_lc.nbytes()) < 0.25 * wire_bytes(n, cfg)
    assert float(shard_lc.nbytes()) <= shard_lc.capacity_nbytes()


@pytest.mark.parametrize("stage", ["zero", "narrow"])
def test_compressed_mean_lossless_stage_transparent(stage):
    """compressed_mean with the lossless stage enabled must produce the
    SAME mean and residual bits as without it (the stage is exact), under
    the same shard_map collective."""
    from jax.sharding import PartitionSpec as P

    from conftest import shard_map_compat
    from repro.compression.grads import compressed_mean

    n = 8192
    g = np.zeros(n, np.float32)
    g[:256] = 0.01
    g[-1] = 50.0                                   # exact-outlier path too
    mesh = jax.make_mesh((1,), ("pod",))

    def run(cfg):
        mapped = shard_map_compat(lambda x: compressed_mean(x, cfg, "pod"),
                                  mesh, P(), (P(), P()))
        return jax.jit(mapped)(jnp.asarray(g))

    base_cfg = GradCompressionConfig(eb_rel=2.0 ** -6, bin_bits=8,
                                     outlier_cap_frac=1 / 64)
    mean0, resid0 = run(base_cfg)
    mean1, resid1 = run(base_cfg._replace(
        pipeline=f"abs:1.0:cap=0.015625|pack:8|{stage}"))
    np.testing.assert_array_equal(np.asarray(mean0).view(np.uint32),
                                  np.asarray(mean1).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(resid0).view(np.uint32),
                                  np.asarray(resid1).view(np.uint32))
    assert np.asarray(mean1)[-1] == g[-1]          # outlier still exact


# ------------------------------------------------------------- KV wire ----

@pytest.mark.parametrize("stage", LC_STAGES)
def test_kv_lc_roundtrip_bitexact(stage):
    cfg = kv_quantizer_config()
    x = RNG.standard_normal((2, 3, 256, 64)).astype(np.float32)
    x[:, :, 160:, :] = 0.0                         # unwritten tail pages
    q = quantize_kv(jnp.asarray(x), cfg)
    lc = pack_kv(q, stages=stage)
    back = unpack_kv(lc)
    for a, b in zip(q, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # zero tail pages shrink the measured wire below the packed one
    pk = pack_kv(q)
    assert float(lc.wire_nbytes()) < pk.nbytes()
