"""Fault-tolerance runtime: checkpoint atomicity/retention/lossy codec,
restart-exact resume, straggler detection, elastic re-shard."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import QuantizerConfig
from repro.core.audit import AuditReport
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.runtime.train_loop import (AuditCounters, StragglerMonitor,
                                      TrainLoopConfig, run)
from repro.runtime import elastic


def _report(violations=0, nonfinite=0, overflow=0, max_err=0.0):
    return AuditReport(n=jnp.int32(128), violations=jnp.int32(violations),
                       max_err=jnp.float32(max_err),
                       n_nonfinite=jnp.int32(nonfinite),
                       n_outliers=jnp.int32(0),
                       overflow=jnp.asarray(bool(overflow)))


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (64, 64)),
            "b": jnp.zeros((64,)), "step": jnp.int32(0)}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = small_state()
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda x: x + step, state),
                 blocking=True)
    assert mgr.all_steps() == [20, 30]          # keep=2 retention
    restored, step = mgr.restore(state)
    assert step == 30
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"]) + 30)


def test_checkpoint_atomicity_partial_dir_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, small_state(), blocking=True)
    # simulate a torn write: a tmp dir without manifest
    os.makedirs(tmp_path / "step-000000000099")
    restored, step = mgr.restore(small_state())
    assert step == 5                             # torn dir skipped


def test_lossy_checkpoint_bounded(tmp_path):
    eb = 1e-5
    mgr = CheckpointManager(str(tmp_path), keep=2,
                            lossy=QuantizerConfig(mode="abs", error_bound=eb))
    state = {"w": jax.random.normal(jax.random.PRNGKey(1), (4096,))}
    mgr.save(1, state, blocking=True)
    restored, _ = mgr.restore(state)
    err = np.abs(np.asarray(state["w"], np.float64)
                 - np.asarray(restored["w"], np.float64))
    assert err.max() <= eb                       # the paper's guarantee
    # and it actually compressed
    files = list((tmp_path / "step-000000000001").glob("*.lc"))
    assert files and files[0].stat().st_size < 4096 * 4


def test_restart_exact_resume(tmp_path):
    """kill-anywhere recovery: resuming at step k replays the identical
    stream and state updates (pipeline is a pure function of step)."""
    pipe = TokenPipeline(DataConfig(vocab=101, seq_len=16, global_batch=4))

    def step_fn(state, batch):
        s = state["acc"] + jnp.sum(batch["tokens"]) + state["step"]
        return {"acc": s, "step": state["step"] + 1}, {}

    jstep = jax.jit(step_fn)
    batch_fn = lambda i: jax.tree.map(jnp.asarray, pipe.batch(i))

    mgr1 = CheckpointManager(str(tmp_path / "a"), keep=5)
    state = {"acc": jnp.float32(0), "step": jnp.int32(0)}
    cfg = TrainLoopConfig(total_steps=10, checkpoint_every=4, log_every=100)
    final, last, interrupted = run(jstep, state, batch_fn, mgr1, cfg)
    assert last == 10 and not interrupted

    # second run: crash at step 4 (simulated by restoring the checkpoint)
    mgr1.wait()
    restored, step = mgr1.restore(state, step=8)
    assert step == 8
    state2, last2, _ = run(jstep, restored, batch_fn, mgr1, cfg,
                           start_step=8)
    assert float(state2["acc"]) == float(final["acc"])  # bit-identical path


def test_audit_counters_fold_reports_and_lists():
    c = AuditCounters()
    c.fold({"loss": 1.0})                        # no audit key: no-op
    c.fold({"audit": _report(max_err=1e-4)})
    c.fold({"audit": [_report(violations=2, max_err=3e-4),
                      None,                      # verify=False steps
                      _report(nonfinite=1, overflow=1)]})
    d = c.as_dict()
    assert d["audit_reports"] == 3
    assert d["audit_violations"] == 2
    assert d["audit_nonfinite"] == 1
    assert d["audit_overflow"] == 1
    assert d["audit_max_err"] == pytest.approx(3e-4)


def test_train_loop_surfaces_cumulative_audit_metrics(tmp_path):
    """Step functions that encode with verify=True put reports under
    metrics['audit']; on_metrics must see the run-level accumulation."""
    def step_fn(state, batch):
        s = {"acc": state["acc"] + 1, "step": state["step"] + 1}
        return s, {"loss": 0.0, "audit": _report(violations=1)}

    seen = []
    mgr = CheckpointManager(str(tmp_path), keep=2)
    cfg = TrainLoopConfig(total_steps=6, checkpoint_every=100, log_every=2)
    run(step_fn, {"acc": jnp.float32(0), "step": jnp.int32(0)},
        lambda i: {"tokens": jnp.zeros((1,), jnp.int32)}, mgr, cfg,
        on_metrics=lambda step, m, dt, s: seen.append((step, m)))
    assert [s for s, _ in seen] == [2, 4, 6]
    cum = [m["audit_cumulative"] for _, m in seen]
    assert [c["audit_reports"] for c in cum] == [2, 4, 6]
    assert [c["audit_violations"] for c in cum] == [2, 4, 6]
    assert "audit" in seen[0][1]                 # raw metrics untouched


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(factor=3.0, warmup=2)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 1.0)                   # 10x EWMA -> straggler
    assert mon.events and mon.events[0][0] == 10
    assert not mon.record(11, 0.1)               # recovery


def test_elastic_reshard(tmp_path):
    """Checkpoint written under one topology restores onto another: the
    shardings are derived from rules, never persisted."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(3, state, blocking=True)

    mesh = elastic.make_mesh_for(jax.devices())   # 1 CPU device -> (1,1)
    def rules(m):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return {"w": NamedSharding(m, P("data", None))}
    restored, step, mesh2 = elastic.resize(mgr, state, rules)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
