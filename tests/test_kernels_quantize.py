"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracle in kernels/ref.py, swept over shapes and bounds.  Quantizers must be
BIT-exact (they are the guarantee); see test_kernel_attention.py for the
allclose-validated attention kernel."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import QuantizerConfig
from repro.core.bitops import float_to_bits
from repro.kernels import ops
from repro.kernels import ref

RNG = np.random.default_rng(11)

SHAPES = [(64,), (1000,), (4096,), (128, 128), (3, 5, 7), (32768,),
          (1, 1), (65537,)]


def _mix(shape):
    """Values spanning normals, specials, denormals, bin borders."""
    x = (RNG.standard_normal(shape) * 10).astype(np.float32)
    flat = x.reshape(-1)
    if flat.size >= 8:
        flat[0] = np.nan
        flat[1] = np.inf
        flat[2] = -np.inf
        flat[3] = 0.0
        flat[4] = -0.0
        flat[5] = 1e-42        # denormal
        flat[6] = np.finfo(np.float32).max
        flat[7] = 5e-4         # near a bin border for eb=1e-3
    return flat.reshape(shape)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("eb", [1e-2, 1e-5])
def test_quantize_abs_kernel_bit_exact(shape, eb):
    cfg = QuantizerConfig(mode="abs", error_bound=eb)
    x = jnp.asarray(_mix(shape))
    k = ops.quantize_abs(x, cfg, interpret=True)
    rb, ro, rr = ref.quantize_abs_ref(x, cfg)
    np.testing.assert_array_equal(np.asarray(k.bins), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(k.outlier), np.asarray(ro))
    np.testing.assert_array_equal(
        np.asarray(k.recon).view(np.uint32), np.asarray(rr).view(np.uint32))


@pytest.mark.parametrize("shape", [(4096,), (128, 128), (65537,)])
def test_quantize_abs_kernel_traced_eb(shape):
    cfg = QuantizerConfig(mode="abs", error_bound=1.0)  # placeholder
    x = jnp.asarray(_mix(shape))
    eb = jnp.float32(3.7e-3)   # per-tensor bound as a traced scalar
    k = ops.quantize_abs(x, cfg, eb=eb, interpret=True)
    rb, ro, rr = ref.quantize_abs_ref(x, cfg, eb=eb)
    np.testing.assert_array_equal(np.asarray(k.bins), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(k.outlier), np.asarray(ro))


def test_quantize_abs_kernel_degenerate_eb():
    cfg = QuantizerConfig(mode="abs", error_bound=1.0)
    x = jnp.asarray(_mix((2048,)))
    k = ops.quantize_abs(x, cfg, eb=jnp.float32(0.0), interpret=True)
    assert bool(jnp.all(k.outlier))      # below floor -> whole tensor lossless


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_quantize_rel_kernel_bit_exact(shape, eb):
    cfg = QuantizerConfig(mode="rel", error_bound=eb, bin_bits=32)
    x = jnp.asarray(_mix(shape))
    k = ops.quantize_rel(x, cfg, interpret=True)
    rb, ro, rr, rs = ref.quantize_rel_ref(x, cfg)
    np.testing.assert_array_equal(np.asarray(k.bins), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(k.outlier), np.asarray(ro))
    np.testing.assert_array_equal(
        np.asarray(k.recon).view(np.uint32), np.asarray(rr).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(k.sign), np.asarray(rs))


@pytest.mark.parametrize("shape", [(4096,), (128, 128), (65537,)])
@pytest.mark.parametrize("eb", [1e-2, 1e-5])
def test_dequantize_abs_kernel_roundtrip(shape, eb):
    cfg = QuantizerConfig(mode="abs", error_bound=eb)
    x = jnp.asarray(_mix(shape))
    k = ops.quantize_abs(x, cfg, interpret=True)
    payload = jnp.where(k.outlier, float_to_bits(x), 0)
    y = ops.dequantize_abs(k.bins, payload, k.outlier, cfg, interpret=True)
    r = ref.dequantize_abs_ref(k.bins, payload, k.outlier, cfg)
    np.testing.assert_array_equal(
        np.asarray(y).view(np.uint32), np.asarray(r).view(np.uint32))
    # end-to-end guarantee through the kernel pair
    xs = np.asarray(x).ravel()
    ys = np.asarray(y).ravel()
    fin = np.isfinite(xs)
    assert np.all(np.abs(xs[fin].astype(np.float64) - ys[fin]) <= eb)
    assert np.array_equal(xs[~fin].view(np.uint32), ys[~fin].view(np.uint32))


def test_kernel_block_shape_sweep():
    cfg = QuantizerConfig(mode="abs", error_bound=1e-3)
    x = jnp.asarray(_mix((100_000,)))
    base = None
    for rows in (8, 64, 256, 512):
        k = ops.quantize_abs(x, cfg, rows=rows, interpret=True)
        got = np.asarray(k.bins)
        if base is None:
            base = got
        else:
            np.testing.assert_array_equal(got, base)  # tiling-invariant
