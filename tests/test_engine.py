"""Continuous-batching decode engine (DESIGN.md §10): the acceptance pins.

  * Continuous batching with slot churn produces logits/tokens
    bit-identical to the sequential single-request `serve_step` path —
    the engine's vmapped step, slot insertion through the §7/§9 pack/
    unpack inverses, and evict→insert preemption may not move one bit.
  * Closed pages cross any boundary only as `PackedKV` wires, accounted
    through `Transport.bytes_moved` (prefill hand-off, eviction, and the
    per-page streaming-migration ledger on a real 2-device mesh).
  * `slice_pages`/`paste_pages` (the streaming unit) roundtrip exactly.
  * The committed BENCH_decode.json artifact carries the tokens/s,
    ms/step, and wire-vs-raw columns the perf trajectory is tracked by.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compression import kv as KVC
from repro.configs.base import ArchConfig
from repro.core.transport import TRANSPORT
from repro.models import build
from repro.models import engine as E
from repro.models import serve as S

REPO = Path(__file__).resolve().parent.parent
RNG = np.random.default_rng(97)

TINY = ArchConfig(name="tiny-engine", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
                  head_dim=16)
SEQ = 256       # 2 pages at PAGE=128


@pytest.fixture(scope="module")
def tiny():
    """One compiled tiny model + single-request reference step, shared by
    every in-process engine test (compile once, not per test)."""
    bundle = build(TINY)
    params = bundle.init(jax.random.PRNGKey(0))
    kv_cfg = KVC.kv_quantizer_config()
    step = jax.jit(lambda p, c, t, i: S.serve_step(TINY, p, c, t, i, None,
                                                   kv_cfg))
    return TINY, params, kv_cfg, step


def _prompt(n):
    return RNG.integers(0, TINY.vocab, size=n).astype(np.int32)


def _ref_decode(cfg, params, step, prompt, n_new, seq=SEQ):
    """Sequential batch-1 serve_step greedy decode — THE reference path.
    Returns (tokens, logits per generated position, final cache, pos)."""
    cache = S.make_quant_cache(cfg, 1, seq)
    logits = None
    for i, t in enumerate(prompt):
        logits, cache = step(params, cache, jnp.asarray(t).reshape(1, 1),
                             jnp.int32(i))
    toks, logs = [int(jnp.argmax(logits, -1).reshape(()))], [logits]
    pos = len(prompt)
    while len(toks) < n_new:
        logits, cache = step(params, cache,
                             jnp.asarray(toks[-1]).reshape(1, 1),
                             jnp.int32(pos))
        pos += 1
        toks.append(int(jnp.argmax(logits, -1).reshape(())))
        logs.append(logits)
    return toks, logs, cache, pos


def test_slice_paste_pages_roundtrip():
    """slice_pages -> pack -> unpack -> paste_pages restores every page of
    a quantized cache bit-exactly — the streaming-migration unit."""
    x = RNG.standard_normal((2, 3, SEQ, 16)).astype(np.float32)
    q = KVC.quantize_kv(jnp.asarray(x), KVC.kv_quantizer_config())
    empty = jax.tree.map(jnp.zeros_like, q)
    rebuilt = empty._replace(out_idx=jnp.full_like(q.out_idx, -1))
    for p in range(SEQ // S.PAGE):
        page = KVC.slice_pages(q, p)
        wire = KVC.pack_kv(page, stages="zero")
        back = KVC.unpack_kv(wire)
        for a, b in zip(back, page):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        rebuilt = KVC.paste_pages(rebuilt, back, p)
    for a, b in zip(rebuilt, q):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_continuous_batching_matches_sequential_serve_step(tiny):
    """Slot churn through the reference scheduler: more requests than
    slots, staggered lengths, greedy tokens must match the sequential
    single-request serve_step decode for every request."""
    cfg, params, kv_cfg, step = tiny
    prompts = [_prompt(130), _prompt(17), _prompt(140)]
    eng = E.DecodeEngine(cfg, params, n_slots=2, seq=SEQ, kv_cfg=kv_cfg)
    out = eng.run(prompts, max_new_tokens=5)
    assert eng.stats()["evictions"] == 0
    assert eng.stats()["inserts"] == 3          # 3 requests over 2 slots
    for rid, prompt in enumerate(prompts):
        ref, _, _, _ = _ref_decode(cfg, params, step, prompt, 5)
        assert out[rid] == ref, f"request {rid} diverged from serve_step"


def test_generate_step_logits_bit_identical_per_slot(tiny):
    """Drive the engine by hand (allocate/prefill/insert/generate_step)
    and compare per-slot logits bit-for-bit against the single-request
    path at every step, across a page boundary."""
    cfg, params, kv_cfg, step = tiny
    prompts = [_prompt(126), _prompt(40)]
    n_new = 4
    eng = E.DecodeEngine(cfg, params, n_slots=2, seq=SEQ, kv_cfg=kv_cfg)
    slots = {}
    for rid, prompt in enumerate(prompts):
        slot = eng.allocate()
        pre = eng.prefill(prompt)
        assert isinstance(pre.pages.k, KVC.PackedKV)
        eng.insert(slot, pre)
        slots[rid] = slot
    got = {rid: [] for rid in slots}
    for _ in range(n_new - 1):       # first token came from prefill
        logits, _ = eng.generate_step()
        for rid, slot in slots.items():
            got[rid].append(np.asarray(logits[slot]))
    for rid, prompt in enumerate(prompts):
        _, ref_logs, _, _ = _ref_decode(cfg, params, step, prompt, n_new)
        for k, mine in enumerate(got[rid]):
            ref = np.asarray(ref_logs[k + 1][0])
            np.testing.assert_array_equal(mine, ref)


def test_evict_insert_churn_is_bit_transparent(tiny):
    """Preemption: step a request, evict it to the PackedCache wire,
    re-insert into a DIFFERENT engine/slot, keep stepping — logits stay
    bit-identical to the uninterrupted single-request path, and both
    hand-offs are accounted as wires."""
    cfg, params, kv_cfg, step = tiny
    prompt = _prompt(130)
    eng = E.DecodeEngine(cfg, params, n_slots=2, seq=SEQ, kv_cfg=kv_cfg)
    pre = eng.prefill(prompt)
    eng.insert(0, pre)
    l1, _ = eng.generate_step()
    moved = eng.evict(0)
    assert isinstance(moved.pages.k, KVC.PackedKV)
    assert eng.allocate() == 0                  # the slot was freed
    eng2 = E.DecodeEngine(cfg, params, n_slots=2, seq=SEQ, kv_cfg=kv_cfg)
    eng2.insert(1, moved)
    l2, _ = eng2.generate_step()
    _, ref_logs, _, _ = _ref_decode(cfg, params, step, prompt, 3)
    np.testing.assert_array_equal(np.asarray(l1[0]),
                                  np.asarray(ref_logs[1][0]))
    np.testing.assert_array_equal(np.asarray(l2[1]),
                                  np.asarray(ref_logs[2][0]))
    # every hand-off went through bytes_moved accounting
    assert eng.stats()["sends"] == 2            # insert + evict
    assert eng2.stats()["sends"] == 1


def test_wire_accounting_matches_bytes_moved_and_beats_raw(tiny):
    """stats()['wire_bytes'] is exactly Transport.bytes_moved of the
    wires that crossed, and the per-slot wire stays below the raw-bf16
    slot footprint (the §10 claim the bench reports)."""
    cfg, params, kv_cfg, _ = tiny
    eng = E.DecodeEngine(cfg, params, n_slots=1, seq=SEQ, kv_cfg=kv_cfg)
    pre = eng.prefill(_prompt(140))
    expect = float(TRANSPORT.bytes_moved(pre.pages, op="send_pages"))
    eng.insert(0, pre)
    assert eng.stats()["wire_bytes"] == expect
    assert expect < eng.raw_slot_bytes()


def test_insert_refuses_live_slot_and_raw_planes(tiny):
    cfg, params, kv_cfg, _ = tiny
    eng = E.DecodeEngine(cfg, params, n_slots=1, seq=SEQ, kv_cfg=kv_cfg)
    pre = eng.prefill(_prompt(9))
    eng.insert(0, pre)
    with pytest.raises(AssertionError):
        eng.insert(0, pre)                      # live slot
    eng.release(0)
    raw = pre._replace(pages=pre.pages._replace(k=pre.pages.hot_k))
    with pytest.raises(AssertionError):
        eng.insert(0, raw)                      # raw plane is not a wire


def test_kv_page_chain_presets_resolve():
    """The engine page-chain presets split under the two-domain grammar
    (§9 fragments applied per page) and pack a page cleanly."""
    from repro.configs.registry import KV_PAGE_CHAINS, get_kv_chain

    for name in KV_PAGE_CHAINS:
        spec = get_kv_chain(name)
        pred, words = KVC._page_stages(spec)
        assert all(hasattr(p, "encode_bins") for p in pred)
        x = RNG.standard_normal((1, 1, S.PAGE, 16)).astype(np.float32)
        q = KVC.quantize_kv(jnp.asarray(x), KVC.kv_quantizer_config(),
                            page=S.PAGE)
        back = KVC.unpack_kv(KVC.pack_kv(q, page=S.PAGE, stages=spec),
                             page=S.PAGE)
        for a, b in zip(back, q):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bench_decode_artifact_is_committed():
    """BENCH_decode.json (the perf trajectory's first point) must exist,
    parse, and carry the tokens/s, ms/step, and wire-vs-raw columns in
    the roofline rows format."""
    path = REPO / "BENCH_decode.json"
    assert path.exists(), "BENCH_decode.json missing (benchmarks/" \
                          "engine_bench.py --smoke writes it)"
    rows = json.loads(path.read_text())
    assert isinstance(rows, list) and rows
    for row in rows:
        for key in ("bench", "arch", "n_slots", "seq", "tokens_per_s",
                    "ms_per_step", "wire_bytes_per_slot",
                    "raw_bf16_bytes_per_slot", "wire_vs_raw"):
            assert key in row, (key, sorted(row))
        assert row["tokens_per_s"] > 0
        assert row["ms_per_step"] > 0
        assert row["wire_bytes_per_slot"] < row["raw_bf16_bytes_per_slot"]


# ------------------------------------------- 2-device streaming migration ---

ENGINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.compression import kv as KVC
    from repro.configs.base import ArchConfig
    from repro.core.transport import TRANSPORT
    from repro.models import build
    from repro.models import engine as E
    from repro.models import serve as S

    cfg = ArchConfig(name="tiny-engine", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab=512, head_dim=16)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    SEQ = 256
    mesh = jax.make_mesh((2,), ("wire",))
    rng = np.random.default_rng(11)
    kv_cfg = KVC.kv_quantizer_config()
    step = jax.jit(lambda p, c, t, i: S.serve_step(cfg, p, c, t, i, None,
                                                   kv_cfg))

    def ref_decode(prompt, n_new):
        cache = S.make_quant_cache(cfg, 1, SEQ)
        logits = None
        for i, t in enumerate(prompt):
            logits, cache = step(params, cache,
                                 jnp.asarray(t).reshape(1, 1), jnp.int32(i))
        toks, logs = [int(jnp.argmax(logits, -1).reshape(()))], [logits]
        pos = len(prompt)
        while len(toks) < n_new:
            logits, cache = step(params, cache,
                                 jnp.asarray(toks[-1]).reshape(1, 1),
                                 jnp.int32(pos))
            pos += 1
            toks.append(int(jnp.argmax(logits, -1).reshape(())))
            logs.append(logits)
        return toks, logs, cache

    # prefill host = rank 0, decode host = rank 1: requests stream page
    # by page through Transport.send_pages while prefill continues
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (140, 135, 20)]
    eng = E.DecodeEngine(cfg, params, n_slots=2, seq=SEQ, kv_cfg=kv_cfg)

    def admit(slot, rid):
        sp = E.stream_prefill(cfg, params, prompts[rid], seq=SEQ,
                              mesh=mesh, axis="wire", src=0, dst=1,
                              kv_cfg=kv_cfg, stages="zero")
        # closed pages moved ONLY as PackedKV wires: re-derive each ledger
        # entry from an independent pack of the (bit-identical) received
        # pages and Transport.bytes_moved — the numbers must agree exactly
        for kind, p, nbytes in sp.stats["ledger"]:
            if kind != "PageWire":
                continue
            assert nbytes == float(TRANSPORT.bytes_moved(
                E.PageWire(
                    KVC.pack_kv(KVC.slice_pages(sp.cache.k, p),
                                stages="zero"),
                    KVC.pack_kv(KVC.slice_pages(sp.cache.v, p),
                                stages="zero")),
                op="send_pages")), (kind, p)
        n_closed = len(prompts[rid]) // S.PAGE
        assert sp.stats["pages_streamed"] == n_closed, sp.stats
        eng.insert_cache(slot, sp.cache, next_token=sp.next_token,
                         pos=sp.pos, request=rid)
        return [int(sp.next_token.reshape(()))]

    N_NEW = 4
    refs = {rid: ref_decode(p, N_NEW) for rid, p in enumerate(prompts)}
    got = {0: admit(0, 0), 1: admit(1, 1)}
    live = {0: 0, 1: 1}                       # slot -> rid
    print("STREAM_OK")

    churned = False
    while live:
        logits, toks = eng.generate_step()
        toks = np.asarray(toks)
        for slot, rid in list(live.items()):
            got[rid].append(int(toks[slot]))
            np.testing.assert_array_equal(
                np.asarray(logits[slot]),
                np.asarray(refs[rid][1][len(got[rid]) - 1][0]))
            if len(got[rid]) >= N_NEW:
                eng.release(slot)             # slot churn:
                del live[slot]
                if not churned:               # admit request 2 mid-flight
                    churned = True
                    got[2] = admit(slot, 2)
                    live[slot] = 2
    for rid in range(3):
        assert got[rid] == refs[rid][0], (rid, got[rid], refs[rid][0])
    print("CHURN_OK")
    print("BIT_IDENTICAL_OK")
""")


@pytest.mark.slow
def test_streaming_migration_engine_two_devices():
    """Acceptance: on a 2-device mesh, continuous batching with slot
    churn + per-page streaming migration produces logits bit-identical
    to sequential serve_step, and closed pages move only as PackedKV
    wires (each ledger entry re-derived through Transport.bytes_moved)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", ENGINE_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=str(REPO))
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("STREAM_OK", "CHURN_OK", "BIT_IDENTICAL_OK"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr)


def test_record_audit_folds_into_stats(tiny):
    """§12 observability: `record_audit` accumulates AuditReports into
    the cumulative audit_* counters of stats(), mirroring
    train_loop.AuditCounters on the serving side."""
    from repro.core.audit import AuditReport

    def rep(violations=0, nonfinite=0, overflow=0, max_err=0.0):
        return AuditReport(n=jnp.int32(64), violations=jnp.int32(violations),
                           max_err=jnp.float32(max_err),
                           n_nonfinite=jnp.int32(nonfinite),
                           n_outliers=jnp.int32(0),
                           overflow=jnp.asarray(bool(overflow)))

    cfg, params, kv_cfg, _ = tiny
    eng = E.DecodeEngine(cfg, params, n_slots=1, seq=256, kv_cfg=kv_cfg)
    st = eng.stats()
    assert st["audit_reports"] == 0 and st["audit_violations"] == 0

    eng.record_audit(rep(max_err=1e-4))
    eng.record_audit([rep(violations=1, max_err=5e-4), None,
                      rep(nonfinite=2, overflow=1)])
    st = eng.stats()
    assert st["audit_reports"] == 3
    assert st["audit_violations"] == 1
    assert st["audit_nonfinite"] == 2
    assert st["audit_overflow"] == 1
    assert st["audit_max_err"] == pytest.approx(5e-4)
