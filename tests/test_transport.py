"""Transport semantics (DESIGN.md §8): the one choke point that moves
compressed wires must be invisible in the bits.

  * `Transport.reduce_mean` vs the pre-transport gather+dequantize+reduce
    path, frozen verbatim below as `_legacy_gather_sum` — bit-identical
    on every registry pipeline preset (the acceptance pin).
  * The packed-domain ring vs the gather path on a real multi-device
    mesh (subprocess, like test_grad_compression) — bit-identical when
    the §8 compatibility rule fires, and reduce_sum agrees with the
    legacy path whether it rings or gathers.
  * serve.py prefill→decode roundtrip: pages cross only as PackedKV
    wires through `Transport.send_pages`, arrive bit-exact, and the
    reconstructed pages still meet the error bound.
  * `transport.wire_bytes` is the single accounting accessor:
    `CompressedShard.nbytes` / `PackedKV.wire_nbytes` delegate to it.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compression.grads import GradCompressionConfig, compress_shard
from repro.compression.kv import (kv_error_bound_holds, kv_quantizer_config,
                                  pack_kv, quantize_kv)
from repro.configs.registry import PIPELINES, get_pipeline
from repro.core import codec
from repro.core.bitops import bits_to_float
from repro.core.pipeline import parse_pipeline
from repro.core.quantizer import dequantize_abs
from repro.core.transport import (TRANSPORT, Transport, axis_size_static,
                                  wire_bytes)
from repro.models import serve

RNG = np.random.default_rng(83)


from conftest import shard_map_compat as _smap


def _legacy_gather_sum(enc, pipe, n, axis):
    """The pre-transport compressed_mean gather/dequantize path (ABS
    chains), frozen verbatim from the PR-3 grads.py as the parity
    reference — any bit moved by the Transport refactor fails here."""
    qc = pipe.qcfg()
    n_words = pipe.n_words(n)

    def dequant_one(w, e, ii, pp):
        bins = codec.unpack_words(w, n, qc.bin_bits)
        vals = dequantize_abs(bins, qc, eb=e, dtype=jnp.float32)
        exact = bits_to_float(pp.astype(jnp.int32), jnp.float32)
        return vals.at[ii].set(exact, mode="drop")

    eb_all = jax.lax.all_gather(enc.eb, axis)
    idx_all = jax.lax.all_gather(enc.out_idx, axis)
    pay_all = jax.lax.all_gather(enc.out_payload, axis)
    if pipe.stages:
        hdrs_all = jax.tree.map(
            lambda h: jax.lax.all_gather(h, axis), enc.headers)
        pw_all = jax.lax.all_gather(enc.payload, axis)
        words_all = jax.vmap(
            lambda hs, pw: pipe.decode_words(hs, pw, n_words))(
                hdrs_all, pw_all)
    else:
        words_all = jax.lax.all_gather(enc.payload, axis)
    return jnp.sum(jax.vmap(dequant_one)(words_all, eb_all, idx_all,
                                         pay_all), axis=0)


def _mix(n):
    x = (RNG.standard_normal(n) * 3e-3).astype(np.float32)
    x[RNG.random(n) < 0.5] = 0.0
    x[7] = 5.0                                     # an exact outlier
    return x


# -------------------------------------------- reduce_mean preset parity ---

@pytest.mark.parametrize("preset", sorted(PIPELINES))
def test_reduce_mean_matches_pre_refactor_path_on_presets(preset):
    """On every registry preset, Transport.reduce_mean under shard_map
    must be bit-identical to the pre-refactor decode: for ABS chains the
    frozen legacy gather+dequantize path, and for every chain the
    pipeline's own local decode (axis size 1 makes them comparable
    in-process; the multi-pod case is the subprocess test below)."""
    pipe = parse_pipeline(get_pipeline(preset))
    n = 20_000
    x = jnp.asarray(_mix(n))
    mesh = jax.make_mesh((1,), ("pod",))

    def eb_of(v):
        if pipe.quant.mode != "abs":
            return None
        rms = jnp.sqrt(jnp.mean(v * v))
        return jnp.float32(2.0 ** -6) * rms

    def run_transport(v):
        enc = pipe.encode(v, eb=eb_of(v), kernels=False)
        return TRANSPORT.reduce_mean(enc, pipe, n, "pod")

    mean = jax.jit(_smap(run_transport, mesh, P(), P()))(x)

    # reference 1: the pipeline's local decode (p == 1 -> mean == decode)
    enc = pipe.encode(x, eb=eb_of(x), kernels=False)
    ref = pipe.decode(enc, n=n, kernels=False).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(mean).view(np.uint32),
                                  np.asarray(ref).view(np.uint32))

    # reference 2 (ABS chains): the frozen legacy collective path — it
    # predates the value domain (§9), so pred-bearing presets pin against
    # reference 1 only: the legacy decoder would read folded residual
    # codes as raw bins
    if pipe.quant.mode == "abs" and not pipe.pred:
        def run_legacy(v):
            e = pipe.encode(v, eb=eb_of(v), kernels=False)
            return _legacy_gather_sum(e, pipe, n, "pod") / jax.lax.psum(
                1, "pod")

        legacy = jax.jit(_smap(run_legacy, mesh, P(), P()))(x)
        np.testing.assert_array_equal(np.asarray(mean).view(np.uint32),
                                      np.asarray(legacy).view(np.uint32))


def test_reduce_gather_transport_pins_reference_path():
    """Transport(reduce='gather') must produce the same bits as the
    default auto transport (which may ring) — here at p=1 both gather."""
    pipe = GradCompressionConfig(bin_bits=8).pipe()
    n = 8192
    x = jnp.asarray(_mix(n))
    mesh = jax.make_mesh((1,), ("pod",))

    def run(tp):
        def f(v):
            shard, _ = compress_shard(v, GradCompressionConfig(bin_bits=8))
            return tp.reduce_mean(shard.enc, pipe, n, "pod")
        return jax.jit(_smap(f, mesh, P(), P()))(x)

    a = run(TRANSPORT)
    b = run(Transport(reduce="gather"))
    np.testing.assert_array_equal(np.asarray(a).view(np.uint32),
                                  np.asarray(b).view(np.uint32))


def test_transport_rejects_unknown_reduce():
    with pytest.raises(ValueError, match="reduce"):
        Transport(reduce="tree")


def test_axis_size_static_outside_shard_map_is_none():
    assert axis_size_static("no-such-axis") is None


# ------------------------------------------- multi-pod ring bit-identity --

RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compression.grads import GradCompressionConfig, compress_shard
    from repro.core.transport import TRANSPORT, Transport, axis_size_static

    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((4,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((4,), ("pod",))

    if hasattr(jax, "shard_map"):
        def smap(f, in_specs, out_specs):
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names={"pod"},
                                 check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        def smap(f, in_specs, out_specs):
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

    cfg = GradCompressionConfig(eb_rel=2.0 ** -6, bin_bits=8,
                                outlier_cap_frac=1 / 16)
    pipe = cfg.pipe()
    n = 4096
    rng = np.random.default_rng(5)

    def paths(g):
        # explicit ring and gather on the same shard, plus the auto path
        shard, _ = compress_shard(g, cfg)
        p = axis_size_static("pod")
        assert p == 4, p
        ring = TRANSPORT._ring_sum(shard.enc, pipe.qcfg(), n, "pod", p)
        gather = TRANSPORT._gather_sum(shard.enc, pipe, n, "pod")
        auto = TRANSPORT.reduce_sum(shard.enc, pipe, n, "pod")
        pinned = Transport(reduce="gather").reduce_sum(
            shard.enc, pipe, n, "pod")
        return ring, gather, auto, pinned

    mapped = smap(paths, P("pod", None), (P("pod", None),) * 4)

    def run(g_global):
        gd = jax.device_put(jnp.asarray(g_global),
                            NamedSharding(mesh, P("pod", None)))
        out = jax.jit(mapped)(gd)
        return [np.asarray(o) for o in out]

    # CASE 1: identical shards -> identical eb, no outliers -> the §8
    # rule fires; ring must be bit-identical to gather (and auto to both)
    base = (rng.standard_normal(n) * 1e-2).astype(np.float32)
    g_same = np.broadcast_to(base, (4, n)).copy()
    ring, gather, auto, pinned = run(g_same)
    for i in range(4):
        assert np.array_equal(ring[i].view(np.uint32),
                              gather[i].view(np.uint32)), "ring != gather"
        assert np.array_equal(auto[i].view(np.uint32),
                              gather[i].view(np.uint32)), "auto != gather"
        assert np.array_equal(pinned[i].view(np.uint32),
                              gather[i].view(np.uint32))
    print("RING_OK")

    # CASE 2: different shards -> different per-tensor eb -> the runtime
    # rule must route auto to the gather path (ring output is NOT asserted
    # here: grids differ), still bit-identical to the pinned reference
    g_diff = (rng.standard_normal((4, n)) * 1e-2).astype(np.float32)
    g_diff[0, 7] = 9.0                      # outliers on pod 0 too
    _, gather, auto, pinned = run(g_diff)
    for i in range(4):
        assert np.array_equal(auto[i].view(np.uint32),
                              gather[i].view(np.uint32))
        assert np.array_equal(pinned[i].view(np.uint32),
                              gather[i].view(np.uint32))
    print("FALLBACK_OK")

    # CASE 3: compressed_mean end-to-end is transport-invariant
    from repro.compression.grads import compressed_mean
    m_auto = smap(lambda g: compressed_mean(g, cfg, "pod"),
                  P("pod", None), (P("pod", None),) * 2)
    m_pin = smap(lambda g: compressed_mean(
                     g, cfg, "pod", transport=Transport(reduce="gather")),
                 P("pod", None), (P("pod", None),) * 2)
    gd = jax.device_put(jnp.asarray(g_diff),
                        NamedSharding(mesh, P("pod", None)))
    (ma, ra) = jax.jit(m_auto)(gd)
    (mp, rp) = jax.jit(m_pin)(gd)
    assert np.array_equal(np.asarray(ma).view(np.uint32),
                          np.asarray(mp).view(np.uint32))
    assert np.array_equal(np.asarray(ra).view(np.uint32),
                          np.asarray(rp).view(np.uint32))
    print("MEAN_OK")
""")


@pytest.mark.slow
def test_packed_domain_ring_bit_identical_multipod():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", RING_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("RING_OK", "FALLBACK_OK", "MEAN_OK"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr)


TRANSFER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compression.kv import (kv_error_bound_holds,
                                      kv_quantizer_config, quantize_kv)
    from repro.models import serve

    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((2,), ("pod",))

    if hasattr(jax, "shard_map"):
        def smap(f, in_specs, out_specs):
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names={"pod"},
                                 check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        def smap(f, in_specs, out_specs):
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

    rng = np.random.default_rng(11)
    # token-correlated cache so the kvdelta residuals are genuinely small
    x = np.cumsum(rng.standard_normal((2, 1, 2, 256, 64)), axis=3)
    x = (x * 0.05).astype(np.float32)
    x[:, :, :, 160:, :] = 0.0                      # unwritten tail pages
    kv_cfg = kv_quantizer_config()
    qk = quantize_kv(jnp.asarray(x), kv_cfg)
    qv = quantize_kv(jnp.asarray(x * 0.5), kv_cfg)
    hot = jnp.zeros((2, 1, serve.PAGE, 2, 64), jnp.float32)
    cache = serve.QuantCache(qk, qv, hot, hot)
    leaves, treedef = jax.tree.flatten(cache)

    for st in ("kvdelta|zero|narrow", "kvdelta|narrow|ent"):
        def send(c, st=st):
            moved = serve.transfer_cache(c, 0, 1, "pod", stages=st)
            return tuple(jnp.expand_dims(l, 0)
                         for l in jax.tree.leaves(moved))

        out = jax.jit(smap(send, P(), (P("pod"),) * len(leaves)))(cache)
        # rank 1 received the cache bit-identically; rank 0 holds zeros
        for a, b in zip(leaves, out):
            got = np.asarray(b)
            assert np.array_equal(np.asarray(a), got[1]), st
            assert not got[0].any(), st
        recv = jax.tree.unflatten(treedef,
                                  [jnp.asarray(np.asarray(b)[1])
                                   for b in out])
        assert bool(kv_error_bound_holds(jnp.asarray(x), recv.k, kv_cfg))
        print("TRANSFER_OK", st)
""")


@pytest.mark.slow
def test_transfer_cache_kvdelta_bit_exact_across_two_devices():
    """Prefill→decode migration on a REAL 2-device mesh: the kvdelta
    page chains cross via Transport.send_pages and arrive bit-exact on
    the receiving device (decode-side, page-local prediction — §9)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", TRANSFER_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    for st in ("kvdelta|zero|narrow", "kvdelta|narrow|ent"):
        assert f"TRANSFER_OK {st}" in r.stdout, (st, r.stdout, r.stderr)


# -------------------------------------------- serve prefill→decode wire ---

def _toy_cache(l_=2, b=2, g_=2, s=256, hd=64):
    x = RNG.standard_normal((l_, b, g_, s, hd)).astype(np.float32)
    x[:, :, :, 160:, :] = 0.0                    # unwritten tail pages
    kv_cfg = kv_quantizer_config()
    qk = quantize_kv(jnp.asarray(x), kv_cfg)
    qv = quantize_kv(jnp.asarray(x * 0.5), kv_cfg)
    hot = jnp.zeros((l_, b, serve.PAGE, g_, hd), jnp.float32)
    return serve.QuantCache(qk, qv, hot, hot), x, kv_cfg


@pytest.mark.parametrize("stages", ["", "zero", "shuffle|narrow",
                                    "kvdelta|zero|narrow",
                                    "kvdelta|narrow|ent"])
def test_serve_transfer_cache_roundtrip_holds_bound(stages):
    """Prefill→decode disaggregation: the cache crosses the axis only as
    PackedKV wires via Transport.send_pages, arrives bit-identical, and
    the reconstructed pages still satisfy the §1 error bound."""
    cache, x, kv_cfg = _toy_cache()
    mesh = jax.make_mesh((1,), ("pod",))

    def send(c):
        moved = serve.transfer_cache(c, 0, 0, "pod", stages=stages)
        return moved

    received = jax.jit(_smap(send, mesh, P(), P()))(cache)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(received)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the bound survives the transfer (pack/send/unpack are exact)
    assert bool(kv_error_bound_holds(jnp.asarray(x), received.k, kv_cfg))


def test_transfer_wire_is_smaller_than_raw_pages():
    cache, _, _ = _toy_cache()
    wire = serve.pack_cache(cache, stages="zero")
    moved = float(TRANSPORT.bytes_moved(wire, op="send_pages"))
    raw = 2 * cache.k.bins.size * 4 + 2 * cache.hot_k.size * 4
    assert moved < 0.5 * raw, (moved, raw)
    # unwritten tail pages were dropped by the zero stage
    packed_only = float(TRANSPORT.bytes_moved(
        serve.pack_cache(cache), op="send_pages"))
    assert moved < packed_only


# --------------------------------------------------- unified accounting ---

def test_wire_bytes_is_the_single_accessor():
    n = 1 << 15
    g = jnp.asarray(_mix(n))
    cfg = GradCompressionConfig(
        bin_bits=16, pipeline="abs:1.0:cap=0.015625|pack:16|narrow")
    shard, _ = compress_shard(g, cfg)
    assert float(shard.nbytes()) == float(wire_bytes(shard))
    assert float(wire_bytes(shard.enc, pipe=shard.pipe, n=n)) == float(
        wire_bytes(shard))

    x = RNG.standard_normal((2, 256, 64)).astype(np.float32)
    q = quantize_kv(jnp.asarray(x), kv_quantizer_config())
    for stages in ((), "narrow"):
        pk = pack_kv(q, stages=stages)
        assert float(pk.wire_nbytes()) == float(wire_bytes(pk))

    cache, _, _ = _toy_cache(l_=1, b=1, g_=1, s=128)
    wire = serve.pack_cache(cache)
    parts = (float(wire_bytes(wire.k)) + float(wire_bytes(wire.v))
             + wire.hot_k.size * 4 + wire.hot_v.size * 4)
    assert float(wire_bytes(wire)) == parts

    arr = jnp.zeros((7, 3), jnp.float32)
    assert wire_bytes(arr) == 7 * 3 * 4
    with pytest.raises(TypeError):
        wire_bytes(object())
    with pytest.raises(TypeError):
        wire_bytes(shard.enc)                 # Encoded needs its pipe


def test_kv_wire_bytes_equals_per_page_pipeline_accounting():
    """Regression (per-page byte flooring): `_kv_wire_bytes` must agree
    bit-for-bit with summing each page's `Pipeline.wire_bytes` — bits
    accumulated across stages and pages, divided once — for staged
    chains including `ent`."""
    from repro.core.pipeline import (Encoded, PackStage, Pipeline,
                                     QuantStage)

    x = RNG.standard_normal((2, 256, 64)).astype(np.float32)
    x[:, 160:, :] = 0.0
    q = quantize_kv(jnp.asarray(x), kv_quantizer_config())
    table_bytes = (q.eb2.size * 4 + q.out_idx.size * 4
                   + q.out_val.size * 4 + q.overflow.size)
    none = jnp.zeros((0,), jnp.int32)
    for stages in ("zero", "narrow", "shuffle|narrow", "narrow|ent",
                   "kvdelta|narrow|ent"):
        pk = pack_kv(q, stages=stages)
        # pred stages live in pk.pred and ship 0 header bits per page, so
        # the word-stage Pipeline accounts the full wire
        pipe = Pipeline(QuantStage("abs", 1.0), PackStage(8), pk.stages)
        n_page = 128 * 64
        pages = pk.payload.reshape(-1, pk.payload.shape[-1])
        plens = pk.payload_len.reshape(-1)
        hdrs = [h.reshape(pages.shape[0], h.shape[-1]) for h in pk.headers]
        per_page = 0.0
        for i in range(pages.shape[0]):
            enc = Encoded(pages[i], plens[i],
                          tuple(h[i] for h in hdrs), none,
                          none.astype(jnp.uint32), jnp.int32(0),
                          jnp.bool_(False), None, None)
            # the page shares nothing with the §4 outlier/eb header —
            # subtract the empty-table base the Pipeline accessor adds
            per_page += float(pipe.wire_bytes(enc, n_page)) - 64 / 8
        assert float(wire_bytes(pk)) == per_page + table_bytes, stages


def test_kv_wire_bytes_keeps_sub_byte_header_content():
    """Regression: a stage whose transmitted header content is not a
    whole byte per page (the §7 contract allows any bit count) must not
    be floored to 0 bytes — bits accumulate and divide once."""
    from types import SimpleNamespace

    class TwoBitHeaderStage:
        """Contract-minimal stage: 2 bits of header content, length-
        variable payload."""
        transmits_len = True

        def header_content_bits(self, n_in):
            return 2

    pages, cap = 3, 8
    wire = SimpleNamespace(
        payload=jnp.zeros((pages, cap), jnp.uint32),
        payload_len=jnp.asarray([5, 0, 2], jnp.int32),
        stages=(TwoBitHeaderStage(),),
        eb2=jnp.zeros((pages,), jnp.float32),
        out_idx=jnp.zeros((pages, 0), jnp.int32),
        out_val=jnp.zeros((pages, 0), jnp.float32),
        overflow=jnp.zeros((pages,), bool))
    want = (pages * 2                       # 2 bits/page of header content
            + pages * 32                    # transmitted length fields
            + 32 * (5 + 0 + 2)              # payload words
            + pages * 32                    # eb2
            + pages * 8) / 8                # overflow bytes
    assert float(wire_bytes(wire)) == want


def test_kv_wire_bytes_exact_past_2p24_words():
    """Regression: the per-page f32 length sum silently rounded once the
    running total passed 2^24 words; the int32 word accumulation with
    one final conversion must stay exact."""
    from types import SimpleNamespace

    from repro.core.pipeline import parse_word_stages

    pages = 4096
    wire = SimpleNamespace(
        payload=jnp.zeros((pages, codec.LC_CHUNK), jnp.uint32),
        payload_len=jnp.full((pages,), 4097, jnp.int32),
        stages=parse_word_stages("narrow", 8),
        eb2=jnp.zeros((pages,), jnp.float32),
        out_idx=jnp.zeros((pages, 0), jnp.int32),
        out_val=jnp.zeros((pages, 0), jnp.float32),
        overflow=jnp.zeros((pages,), bool))
    total_words = pages * 4097                     # 2^24 + 2^12 > 2^24
    hdr_bits = pages * wire.stages[0].header_content_bits(codec.LC_CHUNK)
    want = (hdr_bits + pages * 32 + 32 * total_words
            + pages * 32 + pages * 8) / 8          # exact python int / 8
    got = float(wire_bytes(wire))
    assert got == want, (got, want)


def test_pipeline_wire_bits_exact_past_2p24_words():
    """Regression: Pipeline.wire_bits added the static header bits to a
    traced f32 bit total, which rounds past 2^24 words; the int32 word
    accumulation must stay exact (and provably differs from the old
    formula at this size)."""
    from repro.core.pipeline import Encoded, parse_pipeline

    pipe = parse_pipeline("abs:1.0|pack:8|narrow")
    n = 1 << 20                               # -> 512 chunks of header
    static_bits = (64 + pipe.stages[0].header_content_bits(
        pipe.n_words(n)) + 32)
    assert static_bits % 32 == 0
    # > 2^24 transmitted words; the exact total word count (payload +
    # static header words) is f32-representable, so the single final
    # conversion is lossless
    plen = (1 << 24) + 3
    enc = Encoded(jnp.zeros((0,), jnp.uint32), jnp.int32(plen),
                  (jnp.zeros((0,), jnp.uint32),),
                  jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.uint32),
                  jnp.int32(0), jnp.bool_(False), None, None)
    total_words = plen + static_bits // 32
    assert int(np.float32(float(total_words))) == total_words
    want = 32 * total_words                   # exact python int
    assert float(pipe.wire_bits(enc, n)) == want
    # the old bits-domain f32 arithmetic rounds away at this magnitude
    old = np.float32(32.0) * np.float32(float(plen)) + np.float32(
        static_bits)
    assert float(old) != want


def test_bytes_moved_per_op():
    x = RNG.standard_normal((2, 256, 64)).astype(np.float32)
    pk = pack_kv(quantize_kv(jnp.asarray(x), kv_quantizer_config()))
    w = float(wire_bytes(pk))
    assert float(TRANSPORT.bytes_moved(pk, op="send_pages")) == w
    assert float(TRANSPORT.bytes_moved(pk, op="all_gather",
                                       axis_size=4)) == 4 * 3 * w
    assert float(TRANSPORT.bytes_moved(pk, op="reduce_mean",
                                       axis_size=2)) == 2 * 1 * w
    with pytest.raises(ValueError, match="op"):
        TRANSPORT.bytes_moved(pk, op="broadcast")
    # a degenerate axis must error, not silently report 0 moved bytes
    with pytest.raises(ValueError, match="axis_size"):
        TRANSPORT.bytes_moved(pk, op="all_gather")


def test_all_gather_is_pytree_wide():
    """Transport.all_gather == lax.all_gather on every array leaf, with
    static aux (pipelines, stage chains) untouched."""
    x = RNG.standard_normal((2, 256, 64)).astype(np.float32)
    pk = pack_kv(quantize_kv(jnp.asarray(x), kv_quantizer_config()),
                 stages="narrow")
    mesh = jax.make_mesh((1,), ("pod",))

    def f(p):
        return TRANSPORT.all_gather(p, "pod")

    out = jax.jit(_smap(f, mesh, P(), P()))(pk)
    assert out.stages == pk.stages
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(pk)):
        assert a.shape == (1,) + b.shape
        np.testing.assert_array_equal(np.asarray(a)[0], np.asarray(b))
